"""Quickstart: train a P-EAGLE drafter against a (reduced) target model and
speculative-decode with it — verifying the lossless property and reporting
acceptance length.

    PYTHONPATH=src python examples/quickstart.py [--arch qwen2-1.5b]
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DrafterConfig, get_config
from repro.data import MTPPipeline, self_generated_corpus
from repro.models import get_model, make_extras
from repro.serving import Engine, EngineConfig
from repro.training import Trainer, TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--epochs", type=int, default=15)
    ap.add_argument("--k", type=int, default=4)
    args = ap.parse_args()

    print(f"== target: {args.arch} (reduced config, CPU) ==")
    tcfg = get_config(args.arch).reduced()
    model = get_model(tcfg)
    key = jax.random.PRNGKey(0)
    tparams = model.init(key)

    print("generating target-trace training corpus ...")
    extras_fn = ((lambda b: make_extras(tcfg, b, "prefill", key))
                 if tcfg.family in ("vlm", "encdec") else None)
    corpus = self_generated_corpus(model, tparams, seed=1, n_seqs=48,
                                   seq_len=40, prompt_len=4, batch=16,
                                   extras_fn=extras_fn)

    print("training P-EAGLE drafter (2 layers, K_train=6, COD r=0.8) ...")
    dcfg = DrafterConfig(n_layers=2, k_train=6, k_infer=args.k).resolve(tcfg)
    pipe = MTPPipeline(corpus, k_train=6, cod_rate=0.8, batch=16, seed=0)
    extras = (make_extras(tcfg, 16, "train", key)
              if tcfg.family in ("vlm", "encdec") else {})
    tr = Trainer(tcfg, dcfg, tparams,
                 TrainConfig(lr=3e-3, total_steps=args.epochs * 3),
                 extras=extras)
    log = tr.train(pipe, epochs=args.epochs, log_every=10)
    print(f"final: loss={log[-1]['loss']:.3f} mtp_acc={log[-1]['mtp_acc']:.3f}")

    print("speculative decoding (greedy; must match target exactly) ...")
    B, P, NEW = 4, 6, 24
    prompts = jnp.asarray(corpus[:B, :P])
    ex = (make_extras(tcfg, B, "prefill", key)
          if tcfg.family in ("vlm", "encdec") else {})
    base = Engine(tcfg, None, tparams, None,
                  EngineConfig(K=args.k, max_new_tokens=NEW,
                               drafter_mode="none", max_len=128), B
                  ).run(prompts, ex)
    spec = Engine(tcfg, dcfg, tparams, tr.dparams,
                  EngineConfig(K=args.k, max_new_tokens=NEW,
                               drafter_mode="parallel", max_len=128), B
                  ).run(prompts, ex)
    off = tcfg.vision_tokens if tcfg.family == "vlm" else 0
    lossless = np.array_equal(base["tokens"][:, off + P:off + P + NEW],
                              spec["tokens"][:, off + P:off + P + NEW])
    print(f"acceptance length : {spec['acceptance_length']:.2f} "
          f"(vanilla = 1.00, max = {args.k + 1})")
    print(f"lossless          : {lossless}")
    print(f"OTPS vanilla={base['otps']:.1f}  P-EAGLE={spec['otps']:.1f}  "
          f"speedup={spec['otps'] / base['otps']:.2f}x")


if __name__ == "__main__":
    main()
