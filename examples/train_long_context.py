"""Scalable long-context MTP training (paper §3): COD sampling + amortized
masks + Algorithm-1 sequence partitioning with within-sequence gradient
accumulation. Shows the peak-attention-memory reduction and that segmented
training reaches the same loss as whole-sequence training.

    PYTHONPATH=src python examples/train_long_context.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax

from repro.configs import DrafterConfig, get_config
from repro.core import cod, partition
from repro.data import MTPPipeline, markov_corpus
from repro.models import get_model
from repro.training import Trainer, TrainConfig


def main():
    n, K, r, S = 96, 6, 0.8, 4
    tcfg = get_config("qwen2-1.5b").reduced()
    model = get_model(tcfg)
    tparams = model.init(jax.random.PRNGKey(0))
    corpus = markov_corpus(0, 32, n, tcfg.vocab_size, branch=2)

    M = cod.expanded_length(n, K, r)
    rng = np.random.default_rng(0)
    pos, depth = cod.sample_cod(rng, n, K, r)
    segs = partition.build_segments(pos, depth, n, S)
    full_cells = M * M
    seg_cells = max(len(s.kv_pos) ** 2 for s in segs)
    print(f"seq n={n} K={K} r={r}: expanded M={M}")
    print(f"attention cells: whole={full_cells:,}  "
          f"max-segment (S={S})={seg_cells:,}  "
          f"reduction={full_cells / seg_cells:.1f}x")
    print(f"dependencies preserved: "
          f"{partition.check_dependencies_preserved(segs, pos, depth)}")

    dcfg = DrafterConfig(n_layers=1, k_train=K, cod_rate=r).resolve(tcfg)
    for segments, tag in ((1, "whole-sequence"), (S, f"segmented S={S}")):
        pipe = MTPPipeline(corpus, k_train=K, cod_rate=r, batch=8, seed=0,
                           segments=segments)
        tr = Trainer(tcfg, dcfg, tparams, TrainConfig(lr=2e-3,
                                                      total_steps=40))
        log = tr.train(pipe, epochs=8)
        print(f"{tag:20s}: loss {log[0]['loss']:.3f} -> {log[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
