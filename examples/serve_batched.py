"""End-to-end serving driver: batched speculative decoding under a request
queue — the paper's deployment scenario, comparing vanilla AR decoding,
AR EAGLE-3 drafting, and P-EAGLE parallel drafting at several speculation
depths, each under BOTH batching disciplines:

  round-based   — fixed batch, queue refilled only between full generation
                  rounds (every round waits for its slowest member); the
                  pre-scheduler baseline (serving.serve_round_based)
  continuous    — per-slot refill mid-stream via serving.Scheduler: a
                  finished slot is reused immediately

Requests get heterogeneous max_new_tokens budgets, so continuous batching's
straggler win is visible in the OTPS column. Three extra rows serve the
same mix through the paged-KV engine (incremental page growth), under
Poisson arrival times on the scheduler's virtual clock (queue-wait /
latency percentiles, lossless preemption when the pool runs dry), and as a
mixed-policy batch (half greedy, half seeded nucleus sampling via
per-request SamplingParams — one jitted step serves both).

    PYTHONPATH=src python examples/serve_batched.py [--requests 12]
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import longtail_budgets
from repro.configs import DrafterConfig, get_config
from repro.data import MTPPipeline, self_generated_corpus
from repro.models import get_model
from repro.serving import (Engine, EngineConfig, Request, SamplingParams,
                           Scheduler, serve_round_based)
from repro.training import Trainer, TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--sync-every", type=int, default=4,
                    help="scheduler iterations between host syncs")
    ap.add_argument("--mean-gap", type=float, default=2.0,
                    help="mean Poisson inter-arrival gap (virtual steps) "
                         "for the async row")
    args = ap.parse_args()

    tcfg = get_config("qwen2-1.5b").reduced()
    model = get_model(tcfg)
    key = jax.random.PRNGKey(0)
    tparams = model.init(key)
    corpus = self_generated_corpus(model, tparams, seed=1, n_seqs=48,
                                   seq_len=40, prompt_len=4, batch=16)

    print("training drafters (parallel + AR baseline) ...")
    dcfg_p = DrafterConfig(n_layers=2, k_train=6).resolve(tcfg)
    pipe = MTPPipeline(corpus, k_train=6, cod_rate=0.8, batch=16, seed=0)
    tr_p = Trainer(tcfg, dcfg_p, tparams, TrainConfig(lr=3e-3, total_steps=50))
    tr_p.train(pipe, epochs=12)
    dcfg_a = DrafterConfig(n_layers=1, parallel=False, ttt_steps=2,
                           k_train=1, cod_rate=0.99).resolve(tcfg)
    pipe_a = MTPPipeline(corpus, k_train=1, cod_rate=0.99, batch=16, seed=0)
    tr_a = Trainer(tcfg, dcfg_a, tparams, TrainConfig(lr=3e-3, total_steps=50))
    tr_a.train(pipe_a, epochs=12)

    rng = np.random.default_rng(7)
    rows = rng.choice(len(corpus), args.requests, replace=False)
    prompts = [np.asarray(corpus[i, :6]) for i in rows]
    # long-tail budgets (1/4 long, rest short — realistic request mix): the
    # straggler effect continuous batching removes; same mix as table11
    budgets = longtail_budgets(args.requests, args.max_new, rng)

    def make(mode, dcfg, dp, K):
        return Engine(tcfg, dcfg, tparams, dp,
                      EngineConfig(K=K, max_new_tokens=args.max_new,
                                   drafter_mode=mode, max_len=128),
                      args.batch)

    def bench(eng):
        """(round-based OTPS, continuous OTPS, continuous AL) — each measured
        on a warm second run so compile time isn't counted."""
        rb = co = None
        for _ in range(2):
            rb = serve_round_based(eng, prompts, budgets)
            co = Scheduler(eng, sync_every=args.sync_every).serve(
                [Request(p, max_new_tokens=b)
                 for p, b in zip(prompts, budgets)])
        return rb["otps"], co["otps"], co["mean_acceptance_length"]

    hdr = (f"{'engine':16s} {'round OTPS':>11s} {'cont OTPS':>11s} "
           f"{'cont/round':>10s} {'AL':>5s}")
    print(hdr + "\n" + "-" * len(hdr))

    rb0, co0, _ = bench(make("none", None, None, 0))
    print(f"{'vanilla AR':16s} {rb0:11.1f} {co0:11.1f} {co0 / rb0:9.2f}x"
          f" {'—':>5s}")
    for K in (3, 5, 7):
        rb_a, co_a, al_a = bench(make("ar", dcfg_a, tr_a.dparams, K))
        rb_p, co_p, al_p = bench(make("parallel", dcfg_p, tr_p.dparams, K))
        print(f"{f'AR-EAGLE K={K}':16s} {rb_a:11.1f} {co_a:11.1f} "
              f"{co_a / rb_a:9.2f}x {al_a:5.2f}")
        print(f"{f'P-EAGLE  K={K}':16s} {rb_p:11.1f} {co_p:11.1f} "
              f"{co_p / rb_p:9.2f}x {al_p:5.2f}   "
              f"(P/AR cont: {co_p / co_a:.2f}x, P/vanilla: {co_p / co0:.2f}x)")

    # paged KV: same pool bytes as the contiguous engine's batch x max_len
    # rows, but 2x the slots — incremental growth claims pages as slots
    # actually lengthen, so the long-tail mix keeps more requests resident
    # per byte (benchmarks/table12_paged.py quantifies this; losslessness
    # across layouts is a test invariant)
    paged = Engine(tcfg, dcfg_p, tparams, tr_p.dparams,
                   EngineConfig(K=5, max_new_tokens=args.max_new,
                                drafter_mode="parallel", max_len=128,
                                kv_layout="paged", page_size=16,
                                pool_pages=args.batch * 128 // 16),
                   2 * args.batch)
    pg = None
    for _ in range(2):
        reqs = [Request(p, max_new_tokens=b)
                for p, b in zip(prompts, budgets)]
        pg = Scheduler(paged, sync_every=args.sync_every).serve(reqs)
    print(f"{'P-EAGLE paged':16s} {'—':>11s} {pg['otps']:11.1f} "
          f"{'—':>10s} {pg['mean_acceptance_length']:5.2f}   "
          f"({2 * args.batch} slots on {args.batch}-slot pool bytes, "
          f"page_size=16, peak {paged.allocator.peak_used} pages)")

    # async arrivals: the same engine under Poisson request arrival times on
    # the scheduler's deterministic virtual clock — queue-wait and
    # end-to-end latency percentiles, with lossless preemption when the
    # pool runs dry (benchmarks/table13_async.py sweeps this properly)
    arrivals = np.cumsum(rng.exponential(args.mean_gap,
                                         size=args.requests)).tolist()
    asy = None
    for _ in range(2):
        asy = Scheduler(paged, sync_every=args.sync_every).serve(
            [Request(p, max_new_tokens=b, arrival_time=a)
             for p, b, a in zip(prompts, budgets, arrivals)])
    print(f"{'P-EAGLE async':16s} {'—':>11s} {asy['otps']:11.1f} "
          f"{'—':>10s} {asy['mean_acceptance_length']:5.2f}   "
          f"(Poisson gap {args.mean_gap}: latency p50/p99 "
          f"{asy['p50_latency_vt']:.0f}/{asy['p99_latency_vt']:.0f} vt, "
          f"wait p99 {asy['p99_wait_vt']:.0f} vt, "
          f"{asy['preemptions']} preemptions)")

    # prefix caching: the same long-tail mix, but every prompt now shares a
    # 32-token preamble (system-prompt shape). Admission hash-cons-matches
    # the preamble's full KV pages and maps them into the new request's
    # block-table row, prefilling only the tail; the report carries
    # per-request cached_tokens (hit == cold prefill token-for-token is a
    # test invariant; benchmarks/table16_prefix.py quantifies the gains)
    cached = Engine(tcfg, dcfg_p, tparams, tr_p.dparams,
                    EngineConfig(K=5, max_new_tokens=args.max_new,
                                 drafter_mode="parallel", max_len=128,
                                 kv_layout="paged", page_size=16,
                                 pool_pages=args.batch * 128 // 16,
                                 prefix_cache=True),
                    2 * args.batch)
    preamble = np.asarray(corpus[rows[0], :32])
    shared_prompts = [np.concatenate([preamble, p]) for p in prompts]
    px = None
    for _ in range(2):
        px = Scheduler(cached, sync_every=args.sync_every).serve(
            [Request(p, max_new_tokens=b)
             for p, b in zip(shared_prompts, budgets)])
    stats = cached.prefix_cache.stats
    print(f"{'P-EAGLE prefix':16s} {'—':>11s} {px['otps']:11.1f} "
          f"{'—':>10s} {px['mean_acceptance_length']:5.2f}   "
          f"(shared 32-tok preamble: {px['cache_hit_requests']}/"
          f"{args.requests} hit requests, {px['cache_hit_tokens']} prompt "
          f"tokens from cache, {stats['evictions']} LRU evictions)")

    # mixed-policy batch: per-request SamplingParams — even requests greedy
    # (exact argmax rows), odd requests seeded nucleus sampling — through
    # ONE engine and one compiled step; sampled rows are bitwise
    # reproducible (deterministic fold_in(seed, position) streams,
    # benchmarks/table15_sampling.py sweeps AL vs temperature)
    eng_m = make("parallel", dcfg_p, tr_p.dparams, 5)
    sps = [SamplingParams.greedy(seed=i) if i % 2 == 0 else
           SamplingParams(temperature=0.8, top_p=0.95, seed=i)
           for i in range(args.requests)]
    mx = None
    for _ in range(2):
        mx = Scheduler(eng_m, sync_every=args.sync_every).serve(
            [Request(p, max_new_tokens=b, sampling=sp)
             for p, b, sp in zip(prompts, budgets, sps)])
    print(f"{'P-EAGLE mixed':16s} {'—':>11s} {mx['otps']:11.1f} "
          f"{'—':>10s} {mx['mean_acceptance_length']:5.2f}   "
          f"(half greedy / half T=0.8 top-p 0.95, per-request seeds, "
          "one jitted step)")


if __name__ == "__main__":
    main()
