"""End-to-end serving driver: batched speculative decoding with a request
queue (continuous batching) — the paper's deployment scenario, comparing
vanilla AR decoding, AR EAGLE-3 drafting, and P-EAGLE parallel drafting at
several speculation depths.

    PYTHONPATH=src python examples/serve_batched.py [--requests 12]
"""
import argparse
import sys, os, time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DrafterConfig, get_config
from repro.data import MTPPipeline, self_generated_corpus
from repro.models import get_model
from repro.serving import Engine, EngineConfig
from repro.training import Trainer, TrainConfig


def serve_queue(eng, prompts_list, batch):
    """Continuous batching (lite): fixed batch slots, queue refills between
    generation rounds."""
    done, t0 = [], time.perf_counter()
    queue = list(prompts_list)
    while queue:
        cur = queue[:batch]
        queue = queue[batch:]
        while len(cur) < batch:           # pad final round
            cur.append(cur[-1])
        r = eng.run(jnp.stack(cur))
        done.append(r)
    wall = time.perf_counter() - t0
    toks = sum(r["new_tokens"] for r in done)
    al = float(np.mean([r["acceptance_length"] for r in done]))
    return toks / wall, al


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    tcfg = get_config("qwen2-1.5b").reduced()
    model = get_model(tcfg)
    key = jax.random.PRNGKey(0)
    tparams = model.init(key)
    corpus = self_generated_corpus(model, tparams, seed=1, n_seqs=48,
                                   seq_len=40, prompt_len=4, batch=16)

    print("training drafters (parallel + AR baseline) ...")
    dcfg_p = DrafterConfig(n_layers=2, k_train=6).resolve(tcfg)
    pipe = MTPPipeline(corpus, k_train=6, cod_rate=0.8, batch=16, seed=0)
    tr_p = Trainer(tcfg, dcfg_p, tparams, TrainConfig(lr=3e-3, total_steps=50))
    tr_p.train(pipe, epochs=12)
    dcfg_a = DrafterConfig(n_layers=1, parallel=False, ttt_steps=2,
                           k_train=1, cod_rate=0.99).resolve(tcfg)
    pipe_a = MTPPipeline(corpus, k_train=1, cod_rate=0.99, batch=16, seed=0)
    tr_a = Trainer(tcfg, dcfg_a, tparams, TrainConfig(lr=3e-3, total_steps=50))
    tr_a.train(pipe_a, epochs=12)

    rng = np.random.default_rng(7)
    rows = rng.choice(len(corpus), args.requests, replace=False)
    prompts = [jnp.asarray(corpus[i, :6]) for i in rows]

    def make(mode, dcfg, dp, K):
        return Engine(tcfg, dcfg, tparams, dp,
                      EngineConfig(K=K, max_new_tokens=args.max_new,
                                   drafter_mode=mode, max_len=128),
                      args.batch)

    otps0, _ = serve_queue(make("none", None, None, 0), prompts, args.batch)
    print(f"{'vanilla AR':16s} OTPS={otps0:7.1f}  (baseline)")
    for K in (3, 5, 7):
        o_a, al_a = serve_queue(make("ar", dcfg_a, tr_a.dparams, K),
                                prompts, args.batch)
        o_p, al_p = serve_queue(make("parallel", dcfg_p, tr_p.dparams, K),
                                prompts, args.batch)
        print(f"K={K}: AR-EAGLE OTPS={o_a:7.1f} (AL={al_a:.2f})   "
              f"P-EAGLE OTPS={o_p:7.1f} (AL={al_p:.2f})   "
              f"P/AR={o_p / o_a:.2f}x  P/van={o_p / otps0:.2f}x")


if __name__ == "__main__":
    main()
