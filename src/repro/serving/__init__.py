from repro.serving.engine import Engine, EngineConfig
from repro.serving.prefix_cache import PrefixCache
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import (LLMEngine, Request, Scheduler,
                                     serve_round_based)
from repro.serving import cache_ops
from repro.serving.cache_ops import BlockAllocator

__all__ = ["BlockAllocator", "Engine", "EngineConfig", "LLMEngine",
           "PrefixCache", "Request", "SamplingParams", "Scheduler",
           "serve_round_based", "cache_ops"]
