from repro.serving.engine import Engine, EngineConfig
from repro.serving.prefix_cache import PrefixCache
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import (ABORTED, FINISHED, LLMEngine, Request,
                                     Scheduler, serve_round_based)
from repro.serving.speculation import SpeculationConfig, SpeculationController
from repro.serving.streaming import (AsyncEngine, StreamHandle,
                                     virtual_twin_report)
from repro.serving import cache_ops
from repro.serving.cache_ops import BlockAllocator, HostPagePool

__all__ = ["ABORTED", "AsyncEngine", "BlockAllocator", "Engine",
           "EngineConfig", "FINISHED", "HostPagePool", "LLMEngine",
           "PrefixCache", "Request", "SamplingParams", "Scheduler",
           "SpeculationConfig", "SpeculationController", "StreamHandle",
           "serve_round_based", "virtual_twin_report", "cache_ops"]
