from repro.serving.engine import Engine, EngineConfig
from repro.serving import cache_ops

__all__ = ["Engine", "EngineConfig", "cache_ops"]
