from repro.serving.engine import Engine, EngineConfig
from repro.serving.scheduler import Request, Scheduler, serve_round_based
from repro.serving import cache_ops

__all__ = ["Engine", "EngineConfig", "Request", "Scheduler",
           "serve_round_based", "cache_ops"]
