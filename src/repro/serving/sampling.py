"""Per-request decoding policy: :class:`SamplingParams` and its device-side
batch form.

This is the serving stack's vLLM-style front-end contract: every request
carries its own ``temperature`` / ``top_k`` / ``top_p`` / ``seed`` /
``stop_token_ids`` / ``max_new_tokens``, and the engine verifies drafts
against each request's *warped* target distribution losslessly (greedy rows
— ``temperature == 0`` — take the exact argmax prefix-match path inside the
same jitted step). There is no engine-global sampling mode and no shared
RNG: ``EngineConfig(greedy=...)`` survives only as a deprecated alias that
constructs a default ``SamplingParams``.

Deterministic PRNG streams
--------------------------
Each request owns a counter-based key stream derived from its ``seed``:
the key for the operation that determines the token(s) starting at cache
position ``pos`` is ``fold_in(PRNGKey(seed), pos)``. Keys are re-derived
from the base key every step — nothing is split-and-carried — so the
sampled continuation is a pure function of ``(seed, committed prefix)``:

- identical across runs, batch compositions, slot indices, KV layouts and
  mesh sizes (verification is per-row; neighbours never touch the stream);
- recompute-prefill preemption is token-for-token lossless for seeded
  sampling too: the resumed slot restarts a verify step at the same
  committed prefix the uninterrupted run had a step boundary at, re-derives
  the same ``fold_in`` counter, and therefore replays the same tokens
  (see ``Engine.prefill_into_slot(resume=True)`` and docs/serving.md).

The batch form (:func:`batch_sampling_state`) lives inside the decode state
as the ``"sampling"`` subtree of per-slot arrays, so admission scatters a
request's policy into its slot through the same ``cache_ops.write_slot``
surgery as every other per-slot leaf, and one jitted step serves any mix of
greedy and sampled rows.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclass(frozen=True)
class SamplingParams:
    """Decoding policy of ONE request (immutable, hashable).

    Attributes:
      temperature: softmax temperature. ``0.0`` selects greedy decoding
        (exact argmax, no randomness consumed); must be ``>= 0``.
      top_k: keep only the ``top_k`` highest-probability tokens before
        renormalizing (``0`` disables). Ties at the k-th value are all kept,
        so the warp is deterministic.
      top_p: nucleus sampling — keep the smallest prefix of the
        probability-sorted vocabulary whose mass reaches ``top_p``, then
        renormalize. ``1.0`` disables; must be in ``(0, 1]``.
      seed: base of the request's deterministic PRNG stream (see module
        docstring). Same seed ⇒ bitwise-identical continuation.
      stop_token_ids: per-request stop tokens; generation is trimmed at the
        first occurrence (inclusive), in addition to the scheduler-level
        ``eos_id``.
      max_new_tokens: per-request generation budget; ``None`` defers to
        ``Request.max_new_tokens`` and then the engine default.
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    stop_token_ids: Tuple[int, ...] = ()
    max_new_tokens: Optional[int] = None

    def __post_init__(self):
        if not (self.temperature >= 0.0 and math.isfinite(self.temperature)):
            raise ValueError(f"temperature must be >= 0 and finite, got "
                             f"{self.temperature!r}")
        if not isinstance(self.top_k, int) or self.top_k < 0:
            raise ValueError(f"top_k must be an int >= 0, got {self.top_k!r}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p!r}")
        if not isinstance(self.seed, int):
            raise ValueError(f"seed must be an int, got {self.seed!r}")
        if self.max_new_tokens is not None and self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {self.max_new_tokens!r}")
        object.__setattr__(self, "stop_token_ids",
                           tuple(int(t) for t in self.stop_token_ids))

    @property
    def is_greedy(self) -> bool:
        """Greedy rows take the argmax verify path and consume no PRNG."""
        return self.temperature == 0.0

    @classmethod
    def greedy(cls, **kw) -> "SamplingParams":
        """The pre-redesign default: exact greedy decoding."""
        return cls(temperature=0.0, **kw)

    def base_key(self) -> Array:
        """(2,) uint32 base PRNG key of this request's stream."""
        return jax.random.PRNGKey(self.seed)


def batch_sampling_state(sp: SamplingParams, batch: int) -> dict:
    """Device-side batch form: per-slot policy arrays, every slot filled
    with ``sp``. The ``"sampling"`` subtree of the decode state."""
    return {
        "temperature": jnp.full((batch,), sp.temperature, jnp.float32),
        "top_k": jnp.full((batch,), sp.top_k, jnp.int32),
        "top_p": jnp.full((batch,), sp.top_p, jnp.float32),
        "key": jnp.tile(sp.base_key()[None, :], (batch, 1)),
    }


def blank_sampling_state(batch: int) -> dict:
    """The inert all-zero policy row of a blank/freed slot — what
    ``cache_ops.reset_slot`` (zero fill) restores, so freed slots compare
    equal to a fresh blank state. temperature 0 keeps the row on the greedy
    path (no randomness consumed); the degenerate top_p 0 is harmless (the
    warp always keeps the top-1 token) and admission overwrites the whole
    row before the slot ever goes active."""
    return {
        "temperature": jnp.zeros((batch,), jnp.float32),
        "top_k": jnp.zeros((batch,), jnp.int32),
        "top_p": jnp.zeros((batch,), jnp.float32),
        "key": jnp.zeros((batch, 2), jnp.uint32),
    }


def sampling_state_sds(batch: int) -> dict:
    """jax.ShapeDtypeStruct twin of :func:`batch_sampling_state` for
    abstract (eval_shape) prefill templates."""
    s = jax.ShapeDtypeStruct
    return {
        "temperature": s((batch,), jnp.float32),
        "top_k": s((batch,), jnp.int32),
        "top_p": s((batch,), jnp.float32),
        "key": s((batch, 2), jnp.uint32),
    }


def step_keys(samp: dict, pos: Array) -> Array:
    """Per-row keys for the operation determining the token(s) at cache
    position ``pos`` (B,): ``fold_in(base_key, pos)`` — the counter-based
    stream that makes the continuation a pure function of
    ``(seed, committed prefix)``."""
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32),
                           samp["key"].shape[:1])
    return jax.vmap(jax.random.fold_in)(samp["key"], pos)


# Salt separating the DRAFT-sampling key stream from the verify stream at
# the same position counter. Verification consumes ``step_keys(samp, pos)``
# directly; drafting at the same committed prefix folds this constant in
# first, so the two streams never alias while both remain pure functions of
# ``(seed, committed prefix)``.
DRAFT_SALT = 0x5EED_D12A


def draft_keys(samp: dict, pos: Array, K: int) -> Array:
    """(B, K, 2) uint32 — per-row, per-draft-slot keys for sampling K draft
    tokens at committed prefix position ``pos``.

    Derivation: ``split(fold_in(step_keys(samp, pos), DRAFT_SALT), K)``.
    Like the verify keys, the result depends only on ``(seed, committed
    prefix)`` — never on batch composition, slot index, layout or mesh —
    which is what keeps warped-proposal drafting bitwise reproducible
    across all of those axes and across preempt/resume."""
    salted = jax.vmap(
        lambda k: jax.random.fold_in(k, DRAFT_SALT))(step_keys(samp, pos))
    return jax.vmap(lambda k: jax.random.split(k, K))(salted)
