"""Batched speculative-decoding engine (the framework's vLLM analogue).

Static-shape, jit-compiled draft→verify→commit iterations over a fixed batch
of request slots. The request-lifecycle layer on top — per-slot admission
into a live batch, immediate slot free on EOS/budget, per-request metrics —
is serving/scheduler.py; this module supplies the per-slot primitives
(``prefill_into_slot``, ``free_slot``, ``step`` with an active mask).
Three drafter modes:

  "parallel" — P-EAGLE: one drafter forward drafts K tokens (paper §2/§5.3)
  "ar"       — AR EAGLE-3 baseline: K sequential drafter forwards
  "none"     — vanilla autoregressive decoding (1 target forward per token)

Verification is greedy (prefix match) or lossless rejection sampling.
Greedy + "parallel"/"ar" reproduces target-greedy output exactly — the
losslessness property tests rely on this.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DrafterConfig, ModelConfig
from repro.core import drafter as D
from repro.core import spec_decode as SD
from repro.models import get_model
from repro.serving import cache_ops

Array = jax.Array


@dataclass(frozen=True)
class EngineConfig:
    K: int = 5                       # speculation depth (drafted tokens/iter)
    max_new_tokens: int = 64
    greedy: bool = True
    drafter_mode: str = "parallel"   # parallel | ar | none
    cache_dtype: str = "float32"     # bfloat16 on accelerators
    max_len: int = 512               # total positions per slot


def make_decode_state(model, tcfg: ModelConfig, dcfg: Optional[DrafterConfig],
                      ecfg: EngineConfig, batch: int, *,
                      cache_dtype=None, taps_dtype=None,
                      last_fill: int = 0, new_count_fill: int = 1,
                      rng: Optional[Array] = None) -> dict:
    """The ONE definition of the decode-state skeleton (keys + shapes).

    Engine prefill, Engine.blank_state, and the dry-run's serve_step state
    template (launch/steps.py) all build from this, so a new state leaf added
    for speculative_step can't silently go missing at one of the sites."""
    cdt = jnp.dtype(ecfg.cache_dtype) if cache_dtype is None else cache_dtype
    state = {
        "tokens": jnp.zeros((batch, ecfg.max_len), jnp.int32),
        "last": jnp.full((batch,), last_fill, jnp.int32),
        "taps_last": jnp.zeros((batch, 3 * tcfg.d_model),
                               taps_dtype if taps_dtype is not None else cdt),
        "tcache": model.make_cache(batch, ecfg.max_len, dtype=cdt),
        "new_count": jnp.full((batch,), new_count_fill, jnp.int32),
        "slot_iters": jnp.zeros((batch,), jnp.int32),
        "iters": jnp.zeros((), jnp.int32),
        "row_iters": jnp.zeros((), jnp.int32),
        "committed": jnp.zeros((), jnp.int32),
        "rng": rng if rng is not None else jax.random.PRNGKey(0),
    }
    if ecfg.drafter_mode != "none":
        state["dcache"] = D.make_cache(dcfg, batch, ecfg.max_len, dtype=cdt)
    return state


class Engine:
    def __init__(self, tcfg: ModelConfig, dcfg: Optional[DrafterConfig],
                 tparams: dict, dparams: Optional[dict], ecfg: EngineConfig,
                 batch: int):
        self.tcfg, self.dcfg, self.ecfg = tcfg, dcfg, ecfg
        self.tparams, self.dparams = tparams, dparams
        self.batch = batch
        self.model = get_model(tcfg)
        self.pos_offset = (tcfg.vision_tokens
                           if tcfg.family == "vlm" else 0)
        self._step = jax.jit(self._step_impl)
        self._prefill = jax.jit(self._prefill_impl)
        self._sched_step = jax.jit(self._sched_step_impl)
        self._admit = jax.jit(self._admit_impl)
        self._free = jax.jit(self._free_impl)
        self._slot_axes = None

    # ------------------------------------------------------------------
    # prefill
    # ------------------------------------------------------------------
    def _prefill_impl(self, tparams, dparams, prompts, extras, rng):
        B, P = prompts.shape
        state = make_decode_state(self.model, self.tcfg, self.dcfg,
                                  self.ecfg, B, rng=rng)
        out = self.model.forward(tparams, prompts, mode="prefill",
                                 cache=state["tcache"], collect_taps=True,
                                 head_last_only=True, **extras)
        fused = P + self.pos_offset          # positions 0..fused-1 committed
        first = jnp.argmax(out.logits[:, -1], axis=-1).astype(jnp.int32)

        tokens = state["tokens"]
        tokens = tokens.at[:, self.pos_offset:self.pos_offset + P].set(prompts)
        tokens = tokens.at[:, fused].set(first)

        state.update(
            tokens=tokens,
            last=jnp.full((B,), fused, jnp.int32),
            taps_last=out.taps[:, -1],
            tcache=out.cache,
        )
        if self.ecfg.drafter_mode != "none":
            dcache = state["dcache"]
            if P > 1:
                pos = (jnp.arange(P - 1, dtype=jnp.int32)[None]
                       + self.pos_offset)
                pos = jnp.broadcast_to(pos, (B, P - 1))
                # taps at fused positions offset..offset+P-2 (text region)
                dcache = D.extend(self.dcfg, self.tcfg, dparams, dcache,
                                  prompts[:, 1:], out.taps[:, -P:-1], pos)
            state["dcache"] = dcache
        return state

    def prefill(self, prompts: Array, extras: Optional[dict] = None,
                rng: Optional[Array] = None):
        return self._prefill(self.tparams, self.dparams, prompts,
                             extras or {}, rng if rng is not None
                             else jax.random.PRNGKey(0))

    # ------------------------------------------------------------------
    # one speculative iteration
    # ------------------------------------------------------------------
    def _step_impl(self, tparams, dparams, state):
        return speculative_step(self.model, self.tcfg, self.dcfg, self.ecfg,
                                tparams, dparams, state)

    # ------------------------------------------------------------------
    # per-slot lifecycle (continuous batching; serving/scheduler.py)
    # ------------------------------------------------------------------
    @property
    def slot_axes(self):
        """Per-leaf batch axis of the decode state, inferred structurally
        (cache_ops.batch_axes) from abstract prefills at batch 1 vs 2.
        Computed once; static thereafter (required: axes feed lax slicing)."""
        if self._slot_axes is None:
            def pf(b):
                return jax.eval_shape(
                    self._prefill_impl, self.tparams, self.dparams,
                    jax.ShapeDtypeStruct((b, 4), jnp.int32), {},
                    jax.ShapeDtypeStruct((2,), jnp.uint32))
            self._slot_axes = cache_ops.batch_axes(pf(1), pf(2))
        return self._slot_axes

    def blank_state(self, rng: Optional[Array] = None) -> dict:
        """An all-idle batch state: empty caches (positions -1), zero tokens,
        every slot frozen (new_count == max_new_tokens so the budget check
        keeps it inert). Slots come alive via ``prefill_into_slot``."""
        sds = jax.eval_shape(
            self._prefill_impl, self.tparams, self.dparams,
            jax.ShapeDtypeStruct((self.batch, 4), jnp.int32), {},
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        return make_decode_state(
            self.model, self.tcfg, self.dcfg, self.ecfg, self.batch,
            taps_dtype=sds["taps_last"].dtype,
            new_count_fill=self.ecfg.max_new_tokens, rng=rng)

    def prefill_into_slot(self, state: dict, prompt, slot: int,
                          extras: Optional[dict] = None,
                          rng: Optional[Array] = None):
        """Admit one request into batch row ``slot`` of a live state: prefill
        the prompt as a batch-1 state, then scatter every batched leaf's row
        into the slot (cache_ops.write_slot). Neighbor slots are untouched —
        rows are independent through attention, caches, and verification, so
        mid-stream admission cannot perturb already-decoding requests.

        Returns (new_state, first_token, last_pos): the prefill already
        commits one token (new_count starts at 1 for the slot)."""
        prompt = jnp.asarray(prompt, jnp.int32)[None]
        src = self._prefill(self.tparams, self.dparams, prompt, extras or {},
                            rng if rng is not None else jax.random.PRNGKey(0))
        state = self._admit(state, src, jnp.asarray(slot, jnp.int32))
        last = int(src["last"][0])
        first = int(src["tokens"][0, last])
        return state, first, last

    def _admit_impl(self, dst, src, slot):
        return cache_ops.write_slot(dst, src, slot, self.slot_axes)

    def free_slot(self, state: dict, slot: int) -> dict:
        """Reset one slot's cache/token/taps rows to blank (positions -1) and
        refreeze it (new_count = max_new_tokens) so it idles until the next
        admission. Functionally optional — an inactive slot's garbage is fully
        overwritten on admit — but keeps freed rows inert and cheap to audit."""
        return self._free(state, jnp.asarray(slot, jnp.int32))

    def _free_impl(self, state, slot):
        return cache_ops.reset_slot(
            state, slot, self.slot_axes,
            fills={"new_count": self.ecfg.max_new_tokens})

    def step(self, state: dict, active: Optional[Array] = None,
             max_new: Optional[Array] = None) -> dict:
        """One jitted speculative iteration. Without arguments this is the
        legacy whole-batch step; the scheduler passes ``active`` (B,) bool and
        per-slot ``max_new`` (B,) int32."""
        if active is None and max_new is None:
            return self._step(self.tparams, self.dparams, state)
        B = state["tokens"].shape[0]
        if active is None:
            active = jnp.ones((B,), bool)
        if max_new is None:
            max_new = jnp.full((B,), self.ecfg.max_new_tokens, jnp.int32)
        return self._sched_step(self.tparams, self.dparams, state,
                                jnp.asarray(active),
                                jnp.asarray(max_new, jnp.int32))

    def _sched_step_impl(self, tparams, dparams, state, active, max_new):
        return speculative_step(self.model, self.tcfg, self.dcfg, self.ecfg,
                                tparams, dparams, state,
                                active_mask=active, max_new=max_new)

    # ------------------------------------------------------------------
    # loops & metrics
    # ------------------------------------------------------------------
    def run(self, prompts: Array, extras: Optional[dict] = None,
            max_iters: int = 10_000) -> Dict[str, Any]:
        t0 = time.perf_counter()
        state = self.prefill(prompts, extras)
        jax.block_until_ready(state["tokens"])
        t_prefill = time.perf_counter() - t0

        iters = 0
        t0 = time.perf_counter()
        while iters < max_iters:
            state = self._step(self.tparams, self.dparams, state)
            iters += 1
            if iters % 8 == 0 or iters < 2:
                if bool(np.all(np.asarray(state["new_count"])
                               >= self.ecfg.max_new_tokens)):
                    break
        jax.block_until_ready(state["tokens"])
        t_decode = time.perf_counter() - t0

        new_tok = int(np.sum(np.asarray(state["new_count"])))
        it = max(int(state["iters"]), 1)
        row_iters = max(int(state["row_iters"]), 1)
        return {
            "state": state,
            "tokens": np.asarray(state["tokens"]),
            "new_tokens": new_tok,
            "iterations": it,
            "acceptance_length": int(state["committed"]) / row_iters,
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "otps": new_tok / max(t_decode, 1e-9),
        }


def speculative_step(model, tcfg: ModelConfig, dcfg: Optional[DrafterConfig],
                 ecfg: EngineConfig, tparams, dparams, state,
                 active_mask: Optional[Array] = None,
                 max_new: Optional[Array] = None):
    """One speculative iteration: draft K → verify K+1 → accept → commit.

    Pure function of (params, state) — shared by the Engine and by the
    dry-run's ``serve_step`` lowering (launch/steps.py).

    ``active_mask`` (B,) bool and ``max_new`` (B,) int32 are the continuous-
    batching hooks: the scheduler masks out free/finished slots and supplies
    per-request token budgets. Both default to the legacy whole-batch
    behavior (all slots live, shared ``ecfg.max_new_tokens`` budget), so
    existing callers are unchanged. A masked row commits nothing and its
    last/taps/counters are frozen; its cache rows receive only garbage that
    the next ``Engine.prefill_into_slot`` fully overwrites."""
    B = state["tokens"].shape[0]
    K = ecfg.K if ecfg.drafter_mode != "none" else 0
    c = state["last"]
    tok_next = jnp.take_along_axis(state["tokens"], c[:, None], axis=1)[:, 0]
    rng, vrng = jax.random.split(state["rng"])

    if ecfg.drafter_mode == "parallel":
        drafts, dlogits, dcache = D.draft_parallel(
            dcfg, tcfg, dparams, state["dcache"], tok_next,
            state["taps_last"], c - 1, K)
    elif ecfg.drafter_mode == "ar":
        drafts, dlogits, dcache = D.draft_ar(
            dcfg, tcfg, dparams, state["dcache"], tok_next,
            state["taps_last"], c - 1, K)
    else:
        drafts = jnp.zeros((B, 0), jnp.int32)
        dlogits, dcache = None, None

    # target verify over [t_last, d_1..d_K] at positions c..c+K
    vt = jnp.concatenate([tok_next[:, None], drafts], axis=1)
    positions = c[:, None] + jnp.arange(K + 1, dtype=jnp.int32)[None]
    tout = model.forward(tparams, vt, mode="decode",
                              positions=positions, cache=state["tcache"],
                              collect_taps=ecfg.drafter_mode != "none")

    if K == 0:
        accept_len = jnp.zeros((B,), jnp.int32)
        t_star = jnp.argmax(tout.logits, axis=-1).astype(jnp.int32)
    elif ecfg.greedy:
        accept_len, t_star = SD.greedy_verify(drafts, tout.logits)
    else:
        accept_len, t_star = SD.rejection_verify(
            vrng, drafts, jax.nn.softmax(dlogits, axis=-1),
            jax.nn.softmax(tout.logits, axis=-1))

    budget = jnp.asarray(ecfg.max_new_tokens, jnp.int32) \
        if max_new is None else max_new
    active = state["new_count"] < budget
    if active_mask is not None:
        active &= active_mask
    accept_len = jnp.where(active, accept_len, 0)

    # commit target cache (invalidate stale attention slots / select
    # recurrent snapshots at the last accepted token)
    tcache = cache_ops.commit(tout.cache, tout.aux.get("snapshots"),
                              c + accept_len, accept_len)

    # append committed tokens t_star[0..accept_len]
    idx = c[:, None] + 1 + jnp.arange(K + 1, dtype=jnp.int32)[None]
    keep = jnp.arange(K + 1)[None] <= accept_len[:, None]
    keep &= active[:, None]
    safe_idx = jnp.where(keep, idx, state["tokens"].shape[1])
    tokens = jax.vmap(lambda t, i, v: t.at[i].set(v, mode="drop"))(
        state["tokens"], safe_idx, t_star)

    new_last = jnp.where(active, c + accept_len + 1, c)
    taps_last = state["taps_last"]
    if ecfg.drafter_mode != "none":
        taps_new = jnp.take_along_axis(
            tout.taps, accept_len[:, None, None], axis=1)[:, 0]
        taps_last = jnp.where(active[:, None], taps_new, taps_last)
        # extend drafter cache across the verified block (stale tail is
        # auto-invalidated by the next positional write)
        dcache = D.extend(dcfg, tcfg, dparams, dcache, t_star, tout.taps,
                          positions)

    ncommit = jnp.where(active, accept_len + 1, 0)
    new_state = dict(
        tokens=tokens,
        last=new_last,
        taps_last=taps_last,
        tcache=tcache,
        new_count=state["new_count"] + ncommit,
        slot_iters=state["slot_iters"] + active.astype(jnp.int32),
        iters=state["iters"] + jnp.any(active).astype(jnp.int32),
        row_iters=state["row_iters"] + jnp.sum(active.astype(jnp.int32)),
        committed=state["committed"] + jnp.sum(ncommit),
        rng=rng,
    )
    if ecfg.drafter_mode != "none":
        new_state["dcache"] = dcache
    return new_state

