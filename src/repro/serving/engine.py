"""Batched speculative-decoding engine (the framework's vLLM analogue).

Static-shape, jit-compiled draft→verify→commit iterations over a fixed batch
of request slots. The request-lifecycle layer on top — per-slot admission
into a live batch, immediate slot free on EOS/budget, per-request metrics —
is serving/scheduler.py; this module supplies the per-slot primitives
(``prefill_into_slot``, ``free_slot``, ``step`` with an active mask).
Three drafter modes:

  "parallel" — P-EAGLE: one drafter forward drafts K tokens (paper §2/§5.3)
  "ar"       — AR EAGLE-3 baseline: K sequential drafter forwards
  "none"     — vanilla autoregressive decoding (1 target forward per token)

Verification policy is PER REQUEST (serving/sampling.py): every slot
carries its own ``SamplingParams`` row — temperature / top-k / top-p and a
deterministic PRNG stream derived from the request's seed — and one jitted
step runs greedy prefix matching for ``temperature == 0`` rows and seeded
lossless rejection sampling against the row-warped target distribution for
the rest (core/spec_decode.mixed_verify). Greedy rows + "parallel"/"ar"
reproduce target-greedy output exactly, and sampled rows are a pure
function of ``(seed, committed prefix)`` — the losslessness and
determinism property tests rely on both. There is no engine-global
verification RNG.

Model sharding (``EngineConfig(shard_model=True)``) spreads the engine's
resident state — weights and full-length KV, contiguous rows or page pools
alike — over a 1-D ``("model",)`` device mesh while the scheduler's host
loop is unchanged. Every jitted entry point carries explicit NamedSharding
in/out shardings, and each compute step gathers the sharded storage at a
replication boundary (sharding/utils.replicate_tree) before running
bit-identically to the single-device engine; see docs/sharding.md for the
losslessness argument and layout table.
"""
from __future__ import annotations

import functools
import time
import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import DrafterConfig, ModelConfig
from repro.core import drafter as D
from repro.core import spec_decode as SD
from repro.models import get_model
from repro.serving import cache_ops
from repro.serving.prefix_cache import PrefixCache
from repro.serving.sampling import (SamplingParams, batch_sampling_state,
                                    blank_sampling_state, draft_keys,
                                    sampling_state_sds, step_keys)
from repro.sharding import rules as shard_rules
from repro.sharding.utils import replicate_tree, serving_mesh

Array = jax.Array


@dataclass(frozen=True)
class EngineConfig:
    """Static configuration of a serving :class:`Engine`.

    Attributes:
      K: speculation depth — tokens drafted per iteration (ignored when
        ``drafter_mode == "none"``).
      max_new_tokens: default per-request generation budget; the scheduler
        may override it per request (``Request.max_new_tokens`` /
        ``SamplingParams.max_new_tokens``).
      sampling: default :class:`SamplingParams` for slots/requests that do
        not carry their own (whole-batch ``prefill``/``run``, and
        ``Request``s without an explicit policy). The default is greedy
        verification — token-for-token lossless vs target-greedy decoding.
      greedy: DEPRECATED alias (emits ``DeprecationWarning``): ``True``
        constructs ``SamplingParams.greedy()``, ``False`` a temperature-1.0
        seeded ``SamplingParams``. Pass per-request ``SamplingParams``
        instead.
      drafter_mode: "parallel" (P-EAGLE), "ar" (EAGLE-3 baseline) or "none"
        (vanilla AR decoding, one target forward per token).
      cache_dtype: KV/state cache dtype ("bfloat16" on accelerators).
      max_len: total cache positions per slot (prompt + generation + K+1
        speculative overshoot must fit).
    """
    K: int = 5                       # speculation depth (drafted tokens/iter)
    max_new_tokens: int = 64
    greedy: Optional[bool] = None    # DEPRECATED → sampling (see below)
    drafter_mode: str = "parallel"   # parallel | ar | none
    cache_dtype: str = "float32"     # bfloat16 on accelerators
    max_len: int = 512               # total positions per slot
    # --- KV layout -------------------------------------------------------
    # "contiguous": every slot owns a max_len cache row (the baseline).
    # "paged": full-length attention KV lives in a shared pool of fixed-size
    # position pages behind per-slot block tables (cache_ops); admission
    # allocates ceil(need/page_size) pages instead of a max_len row.
    kv_layout: str = "contiguous"
    page_size: int = 16              # positions per page (paged layout)
    pool_pages: int = 0              # pool size; 0 = batch * max_len/page_size
    # "incremental": admission claims only the pages the prompt (plus one
    # speculative block) occupies; `ensure_capacity` grows the slot's
    # allocation page-by-page as decode crosses page boundaries, so the pool
    # holds requests by their *current* length, not their worst case.
    # "upfront": PR-2 behavior — admission reserves prompt+budget+overshoot
    # for the request's whole lifetime (the static-admission baseline
    # benchmarks/table13_async.py compares against).
    kv_growth: str = "incremental"
    # Cross-request prefix caching (serving/prefix_cache.py): pages of
    # committed prompt/generation streams stay indexed by token-prefix
    # chain after their request finishes (or is preempted), and admission
    # of a request whose prompt walks a cached chain maps those pages into
    # its block-table row — prefilling only the uncached suffix — instead
    # of recomputing them. Paged-only. Dense attention targets take the
    # fast path; recurrent families (ssm/hybrid) carry per-slot state no
    # page holds, so they serve unchanged with the cache structurally
    # idle. A hit is token-for-token lossless vs a cold prefill
    # (tests/test_prefix_cache.py), and cached pages are reclaimed LRU
    # under pool pressure (pages live slots map are pinned).
    prefix_cache: bool = False
    # Power-of-two bucketing for per-slot admission prefills, so a stream of
    # distinct prompt lengths compiles O(log2 max_len) traces instead of one
    # per length. Append-only attention families right-pad to the bucket
    # (pads are causally inert; their cache entries are invalidated);
    # recurrent families (ssm/hybrid) and targets with ring sliding-window
    # KV — where pads would corrupt the recurrence / wrap over live window
    # entries — split the prompt into its MSB-first power-of-two chunks.
    # Exactness across both paths is pinned by the cross-layout tests.
    bucket_prefill: bool = True
    # --- model sharding --------------------------------------------------
    # shard_model=True spreads weights and full-length KV (contiguous rows
    # or page pools) over ``mesh`` — a 1-D ("model",) jax Mesh, defaulting
    # to sharding/utils.serving_mesh() over every local device. Storage
    # shards; compute stays replicated behind an explicit gather boundary,
    # which is what keeps the sharded engine token-for-token identical to
    # the single-device one (docs/sharding.md). Block tables and the
    # BlockAllocator stay host-side/replicated, so incremental page growth
    # and preemption never relayout the sharded pools.
    shard_model: bool = False
    mesh: Any = None                 # jax Mesh; None = serving_mesh()
    # Engine-default decoding policy; per-request SamplingParams override it
    # slot-by-slot through the scheduler. None = SamplingParams.greedy().
    sampling: Optional[SamplingParams] = None
    # Warped-proposal drafting: rows with temperature > 0 SAMPLE their K
    # drafts from the row-warped drafter distribution (one salted
    # counter-based key per slot — sampling.draft_keys) instead of taking
    # the drafter argmax, and verification receives that distribution as
    # the rejection proposal q. Greedy rows stay bitwise on the argmax
    # path. Off by default: the one-hot argmax proposal is the
    # pre-adaptive behavior.
    draft_sampling: bool = False
    # --- swap-to-host preemption -----------------------------------------
    # swap="host": on preemption the victim slot's state — every KV page it
    # exclusively owns (refcount == 1) plus its per-slot rows (recurrent
    # stream state, tokens/logprobs, sampling policy, taps) — is copied to
    # a host-side cache_ops.HostPagePool, and resume becomes a device
    # scatter (swap_in_slot) instead of a recompute-prefill: bitwise the
    # state the victim had at its eviction step boundary. Pages shared
    # with the prefix cache (or another slot) stay resident — the swap
    # handle keeps the slot's reference, pinning them — and are re-mapped
    # on swap-in. Paged-only. host_pool_bytes caps the host snapshot
    # budget (0 = unbounded); when it can't hold a victim, the scheduler
    # falls back to lossless recompute-prefill preemption.
    swap: str = "none"               # none | host
    host_pool_bytes: int = 0         # host snapshot budget; 0 = unbounded

    def __post_init__(self):
        if self.greedy is not None:
            warnings.warn(
                "EngineConfig(greedy=...) is deprecated: decoding policy is "
                "per-request now — pass SamplingParams (e.g. "
                "Request(sampling=SamplingParams(temperature=0.8, seed=1)) "
                "or EngineConfig(sampling=...)) instead",
                DeprecationWarning, stacklevel=2)
            if self.sampling is None:
                object.__setattr__(
                    self, "sampling",
                    SamplingParams.greedy() if self.greedy
                    else SamplingParams(temperature=1.0))
        if self.sampling is None:
            object.__setattr__(self, "sampling", SamplingParams.greedy())
        # keep reads of .greedy meaningful for stragglers (no warning)
        object.__setattr__(self, "greedy", self.sampling.is_greedy)


def make_decode_state(model, tcfg: ModelConfig, dcfg: Optional[DrafterConfig],
                      ecfg: EngineConfig, batch: int, *,
                      cache_dtype=None, taps_dtype=None,
                      last_fill: int = 0, new_count_fill: int = 1,
                      sampling: Optional[dict] = None) -> dict:
    """The ONE definition of the decode-state skeleton (keys + shapes).

    Engine prefill, Engine.blank_state, and the dry-run's serve_step state
    template (launch/steps.py) all build from this, so a new state leaf added
    for speculative_step can't silently go missing at one of the sites.

    ``sampling`` is the per-slot decoding-policy subtree
    (serving/sampling.batch_sampling_state); None fills every slot with the
    engine-default ``ecfg.sampling``."""
    cdt = jnp.dtype(ecfg.cache_dtype) if cache_dtype is None else cache_dtype
    state = {
        "tokens": jnp.zeros((batch, ecfg.max_len), jnp.int32),
        # log p(token) under the RAW target softmax at each committed
        # position (the verification distribution, before any
        # temperature/top-k/top-p warp) — one uniform convention for greedy
        # and sampled rows, harvested alongside "tokens". Prompt positions
        # are never written and read as 0.
        "logprobs": jnp.zeros((batch, ecfg.max_len), jnp.float32),
        "last": jnp.full((batch,), last_fill, jnp.int32),
        "taps_last": jnp.zeros((batch, 3 * tcfg.d_model),
                               taps_dtype if taps_dtype is not None else cdt),
        "tcache": model.make_cache(batch, ecfg.max_len, dtype=cdt),
        "new_count": jnp.full((batch,), new_count_fill, jnp.int32),
        "slot_iters": jnp.zeros((batch,), jnp.int32),
        "iters": jnp.zeros((), jnp.int32),
        "row_iters": jnp.zeros((), jnp.int32),
        "committed": jnp.zeros((), jnp.int32),
        "sampling": (sampling if sampling is not None
                     else batch_sampling_state(ecfg.sampling, batch)),
    }
    if ecfg.drafter_mode != "none":
        state["dcache"] = D.make_cache(dcfg, batch, ecfg.max_len, dtype=cdt)
    return state


@dataclass
class _SwapHandle:
    """One swapped-out request's host-side snapshot (HostPagePool entry).

    ``snap`` is the device_get of ``cache_ops.extract_slot`` trimmed to
    what must actually move: per-slot rows in full, paged-leaf views cut
    down to the spans of the ``host_idx`` pages (zero-size placeholders
    elsewhere — swap-in rebuilds the full-width view around them and its
    scatter mask drops the placeholder spans). ``page_row`` is the slot's
    ordered page list at eviction; pages NOT in ``host_idx`` stayed
    resident on device — the handle kept the slot's allocator reference
    for them, which pins them against prefix-cache LRU eviction until
    swap-in remaps or drop_swap releases them."""
    snap: dict
    page_row: List[int]       # ordered pages at eviction time
    host_idx: List[int]       # row indices whose pages moved to host
    last: int                 # committed step-boundary position
    sampled: bool             # _slot_sampled mirror to restore
    nbytes: int


class Engine:
    """Batched speculative-decoding engine over ``batch`` request slots.

    Args:
      tcfg: target-model config (any family in the model zoo).
      dcfg: drafter config, or None when ``ecfg.drafter_mode == "none"``.
      tparams / dparams: target / drafter parameter pytrees. Under
        ``ecfg.shard_model`` they are re-placed storage-sharded over the
        serving mesh at construction.
      ecfg: static engine configuration (see :class:`EngineConfig`).
      batch: number of decode slots (the fixed batch dimension of the
        decode state; the Scheduler admits requests into free slots).
    """

    def __init__(self, tcfg: ModelConfig, dcfg: Optional[DrafterConfig],
                 tparams: dict, dparams: Optional[dict], ecfg: EngineConfig,
                 batch: int):
        self.tcfg, self.dcfg, self.ecfg = tcfg, dcfg, ecfg
        self.tparams, self.dparams = tparams, dparams
        self.batch = batch
        self.model = get_model(tcfg)
        self.pos_offset = (tcfg.vision_tokens
                           if tcfg.family == "vlm" else 0)
        if ecfg.kv_layout not in ("contiguous", "paged"):
            raise ValueError(f"unknown kv_layout {ecfg.kv_layout!r}")
        if ecfg.kv_growth not in ("incremental", "upfront"):
            raise ValueError(f"unknown kv_growth {ecfg.kv_growth!r}")
        self.paged = ecfg.kv_layout == "paged"
        self.incremental = self.paged and ecfg.kv_growth == "incremental"
        if self.paged:
            if ecfg.max_len % ecfg.page_size:
                raise ValueError(
                    f"max_len {ecfg.max_len} must be a multiple of "
                    f"page_size {ecfg.page_size}")
            self.pages_per_slot = ecfg.max_len // ecfg.page_size
            self.pool_pages = ecfg.pool_pages or batch * self.pages_per_slot
            self.allocator = cache_ops.BlockAllocator(self.pool_pages)
            self._slot_pages: List[List[int]] = [[] for _ in range(batch)]
        if ecfg.prefix_cache and not self.paged:
            raise ValueError(
                "prefix_cache requires kv_layout='paged' (pages are the "
                "sharing unit)")
        self.prefix_cache = (PrefixCache(ecfg.page_size)
                             if self.paged and ecfg.prefix_cache else None)
        if ecfg.swap not in ("none", "host"):
            raise ValueError(f"unknown swap {ecfg.swap!r}")
        if ecfg.swap == "host" and not self.paged:
            raise ValueError(
                "swap='host' requires kv_layout='paged' (pages are the "
                "swap unit)")
        self.swap_enabled = ecfg.swap == "host"
        self.host_pool = (cache_ops.HostPagePool(ecfg.host_pool_bytes)
                          if self.swap_enabled else None)
        # bytes the most recent swap_out_slot / swap_in_slot moved — the
        # scheduler reads this right after the call to charge its clock
        # (same read-after-call idiom as last_hit_tokens)
        self.swap_last_bytes = 0
        self._b1_tpl = None          # cached batch-1 contiguous eval_shape
        self._swap_sizes = None      # cached (row bytes, per-page bytes)
        # the previous serving session's final state — cached page content
        # lives in its pool arrays, so serve_state() resumes from it
        self._serve_state: Optional[dict] = None
        # tokens the most recent prefill_into_slot served from cached pages
        # (0 on a cold admission) — the scheduler reads this right after the
        # call to account per-request hit stats
        self.last_hit_tokens = 0
        # raw-target logprob of the token the most recent fresh (non-resume)
        # prefill_into_slot committed — the scheduler pairs it with the
        # returned first token (same read-after-call idiom as
        # last_hit_tokens); 0.0 after a resume (nothing committed)
        self.last_logprob = 0.0
        # host-side mirror of each slot's policy (sampled vs greedy) — set
        # at admission, cleared on free; lets step() pick the greedy-only
        # trace when nothing in the batch samples (purely a perf choice)
        self._slot_sampled = [False] * batch
        self._slot_axes = None
        self._paged_axes = None
        self._pspec = None
        self._pad_unsafe = None
        self._contig_tpl = None
        self._contig_sh = None
        self._paged_sh = None
        # --- model sharding (storage-sharded, replicated compute) ---------
        self.mesh = None
        if ecfg.shard_model:
            self.mesh = ecfg.mesh if ecfg.mesh is not None else serving_mesh()
            self._repl = NamedSharding(self.mesh, P())
            self._tparam_sh = self._named(
                shard_rules.serve_param_specs(tparams, self.mesh))
            self.tparams = jax.device_put(tparams, self._tparam_sh)
            self._dparam_sh = self._repl
            if dparams is not None:
                self._dparam_sh = self._named(
                    shard_rules.serve_param_specs(dparams, self.mesh))
                self.dparams = jax.device_put(dparams, self._dparam_sh)
        self._build_jits()

    # ------------------------------------------------------------------
    # jit wiring (plain on one device; explicit NamedSharding in/out
    # shardings under shard_model, so every entry point — steps, admission
    # prefills, slot frees, block-table growth — keeps storage sharded at
    # rest and never relies on sharding propagation across host calls)
    # ------------------------------------------------------------------
    def _named(self, specs):
        """PartitionSpec pytree → NamedSharding pytree on the engine mesh."""
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs)

    def _rep(self, tree):
        """The exactness boundary: gather storage-sharded leaves so compute
        downstream runs with single-device tensor shapes (bit-identical to
        the unsharded engine). No-op without a mesh."""
        return tree if self.mesh is None else replicate_tree(tree, self.mesh)

    @staticmethod
    def _greedy_twins(fn, **jit_kwargs):
        """{greedy_only: jitted fn} — every step entry point gets a
        greedy-only twin (static greedy_only=True trace: no warp sorts, no
        categorical draws, the pre-SamplingParams per-step cost);
        ``Engine.step`` picks a twin host-side per call. Both twins emit
        identical tokens for greedy rows, so the choice is purely perf."""
        return {g: jax.jit(functools.partial(fn, greedy_only=g),
                           **jit_kwargs) for g in (False, True)}

    def _build_jits(self):
        if self.mesh is None:
            self._step = self._greedy_twins(self._step_impl)
            self._prefill = jax.jit(self._prefill_impl)
            self._prefill_pad = jax.jit(self._prefill_pad_impl)
            self._chunk = jax.jit(self._chunk_impl)
            self._sched_step = self._greedy_twins(self._sched_step_impl)
            self._paged_step = self._greedy_twins(self._paged_step_impl)
            self._admit = jax.jit(self._admit_impl)
            self._paged_admit = jax.jit(self._paged_admit_impl)
            self._free = jax.jit(self._free_impl)
            self._paged_free = jax.jit(self._paged_free_impl)
            # prefix-cache hit path (invoked only with ecfg.prefix_cache):
            # page ids / start positions are traced, so each entry point
            # costs one trace (plus one per pow2 suffix chunk width)
            self._blank_row = jax.jit(self._blank_row_impl)
            self._copy_page = jax.jit(self._copy_page_impl)
            self._hit_seed = jax.jit(self._hit_seed_impl)
            self._hit_chunk = jax.jit(self._hit_chunk_impl)
            # one trace for every (slot, page-count) combination: slot and
            # the full-width block-table row are both traced, so decode-time
            # growth never recompiles (pinned by tests/test_cache_ops.py)
            self._set_table_row = jax.jit(
                lambda bt, slot, row: bt.at[slot].set(row))
            if self.paged:
                # swap-to-host: one gather trace serves every (slot, row)
                # pair; scatter is the admit trace minus the resume fixup
                self._swap_gather = jax.jit(self._swap_gather_impl)
                self._swap_scatter = jax.jit(self._swap_scatter_impl)
            return
        rp, tp, dp = self._repl, self._tparam_sh, self._dparam_sh
        # contiguous decode-state sharding: full-length k/v leaves sharded
        # over the KV-head axis (head_dim fallback), the rest replicated —
        # the same tree serves every batch size (specs touch trailing dims)
        csh = self.state_shardings
        jj = jax.jit
        self._step = self._greedy_twins(self._step_impl,
                                        in_shardings=(tp, dp, csh),
                                        out_shardings=csh)
        self._prefill = jj(self._prefill_impl,
                           in_shardings=(tp, dp, rp, rp, rp),
                           out_shardings=csh)
        self._prefill_pad = jj(self._prefill_pad_impl,
                               in_shardings=(tp, dp, rp, rp, rp, rp),
                               out_shardings=csh)
        self._chunk = jj(self._chunk_impl,
                         in_shardings=(tp, dp, csh, rp, rp),
                         out_shardings=csh)
        self._sched_step = self._greedy_twins(
            self._sched_step_impl, in_shardings=(tp, dp, csh, rp, rp, rp),
            out_shardings=csh)
        self._admit = jj(self._admit_impl,
                         in_shardings=(csh, csh, rp, rp, rp),
                         out_shardings=csh)
        self._free = jj(self._free_impl, in_shardings=(csh, rp),
                        out_shardings=csh)
        if self.paged:
            # paged state: k/v *pools* shard on the same trailing axes;
            # positions pools, block tables, per-slot rows replicate —
            # admission/free/growth are then sharded-local data movement
            psh = self.paged_state_shardings
            self._paged_step = self._greedy_twins(
                self._paged_step_impl, in_shardings=(tp, dp, psh, rp, rp, rp),
                out_shardings=psh)
            self._paged_admit = jj(self._paged_admit_impl,
                                   in_shardings=(psh, csh, rp, rp, rp, rp,
                                                 rp),
                                   out_shardings=psh)
            self._paged_free = jj(self._paged_free_impl,
                                  in_shardings=(psh, rp), out_shardings=psh)
            # prefix-cache hit path: pool-to-pool data movement stays
            # sharded (blank/copy); the seeded batch-1 view comes out in
            # the contiguous state sharding and chunk prefills cross the
            # usual replication boundary inside _hit_chunk_impl
            self._blank_row = jj(self._blank_row_impl,
                                 in_shardings=(psh, rp), out_shardings=psh)
            self._copy_page = jj(self._copy_page_impl,
                                 in_shardings=(psh, rp, rp),
                                 out_shardings=psh)
            self._hit_seed = jj(self._hit_seed_impl,
                                in_shardings=(psh, rp, rp, rp, rp),
                                out_shardings=csh)
            self._hit_chunk = jj(self._hit_chunk_impl,
                                 in_shardings=(tp, dp, csh, rp, rp),
                                 out_shardings=csh)
            # swap-to-host: the gathered batch-1 snapshot replicates (it is
            # heading to host memory), and swap-in re-scatters a replicated
            # host payload back into the sharded pools
            self._swap_gather = jj(self._swap_gather_impl,
                                   in_shardings=(psh, rp, rp),
                                   out_shardings=rp)
            self._swap_scatter = jj(self._swap_scatter_impl,
                                    in_shardings=(psh, rp, rp, rp, rp),
                                    out_shardings=psh)
        self._set_table_row = jj(lambda bt, slot, row: bt.at[slot].set(row),
                                 in_shardings=(rp, rp, rp), out_shardings=rp)

    # ------------------------------------------------------------------
    # prefill
    # ------------------------------------------------------------------
    def _prefill_impl(self, tparams, dparams, prompts, extras, samp):
        tparams, dparams = self._rep(tparams), self._rep(dparams)
        B, P = prompts.shape
        state = make_decode_state(self.model, self.tcfg, self.dcfg,
                                  self.ecfg, B, sampling=samp)
        out = self.model.forward(tparams, prompts, mode="prefill",
                                 cache=state["tcache"], collect_taps=True,
                                 head_last_only=True, **extras)
        fused = P + self.pos_offset          # positions 0..fused-1 committed
        # first generated token: argmax for greedy rows; for sampled rows a
        # seeded draw from the warped target distribution, keyed by the
        # position it determines (fold_in(seed, fused) — see sampling.py)
        first = SD.sample_token(step_keys(samp, fused), out.logits[:, -1],
                                samp["temperature"], samp["top_k"],
                                samp["top_p"])

        tokens = state["tokens"]
        tokens = tokens.at[:, self.pos_offset:self.pos_offset + P].set(prompts)
        tokens = tokens.at[:, fused].set(first)

        state.update(
            tokens=tokens,
            logprobs=state["logprobs"].at[:, fused].set(
                _token_logprob(out.logits[:, -1], first)),
            last=jnp.full((B,), fused, jnp.int32),
            taps_last=out.taps[:, -1],
            tcache=out.cache,
        )
        if self.ecfg.drafter_mode != "none":
            dcache = state["dcache"]
            if P > 1:
                pos = (jnp.arange(P - 1, dtype=jnp.int32)[None]
                       + self.pos_offset)
                pos = jnp.broadcast_to(pos, (B, P - 1))
                # taps at fused positions offset..offset+P-2 (text region)
                dcache = D.extend(self.dcfg, self.tcfg, dparams, dcache,
                                  prompts[:, 1:], out.taps[:, -P:-1], pos)
            state["dcache"] = dcache
        # pin the result replicated: the out_shardings reshard is then pure
        # data movement and can't propagate sharding back into the compute
        return self._rep(state)

    def prefill(self, prompts: Array, extras: Optional[dict] = None,
                sampling: Optional[SamplingParams] = None):
        """Whole-batch prefill: build a fresh decode state for ``prompts``
        (B, P), committing one generated token per row.

        Args:
          prompts: (B, P) int32 token batch — equal lengths; per-request
            admission with varied lengths goes through ``prefill_into_slot``.
          extras: optional modality inputs (vision/encoder embeds, leading
            batch axis B) forwarded to the target's prefill.
          sampling: decoding policy applied to every row (default: the
            engine's ``ecfg.sampling``). Per-request policies go through
            the Scheduler (``Request(sampling=...)``).

        Returns:
          A decode-state dict (see ``make_decode_state``) ready for
          ``step``; under shard_model its KV leaves are placed sharded."""
        B = prompts.shape[0]
        samp = batch_sampling_state(sampling or self.ecfg.sampling, B)
        return self._prefill(self.tparams, self.dparams, prompts,
                             extras or {}, samp)

    # ------------------------------------------------------------------
    # bucketed admission prefill (one trace per power-of-two bucket)
    # ------------------------------------------------------------------
    def _prefill_pad_impl(self, tparams, dparams, prompts, true_len, extras,
                          samp):
        """Attention-family bucketed prefill: ``prompts`` (B, Pb) is the
        prompt right-padded to a power-of-two bucket, ``true_len`` the traced
        real length. Causal attention makes right-pads inert for every real
        position; the pads' cache entries are invalidated afterwards (same
        position-based mechanism as speculative rollback), and logits/taps
        are gathered at the true last position instead of -1."""
        tparams, dparams = self._rep(tparams), self._rep(dparams)
        B, Pb = prompts.shape
        state = make_decode_state(self.model, self.tcfg, self.dcfg,
                                  self.ecfg, B, sampling=samp)
        fused = true_len + self.pos_offset       # positions 0..fused-1 real
        hp = jnp.broadcast_to(fused - 1, (B,)).astype(jnp.int32)
        out = self.model.forward(tparams, prompts, mode="prefill",
                                 cache=state["tcache"], collect_taps=True,
                                 head_positions=hp, **extras)
        first = SD.sample_token(step_keys(samp, fused), out.logits[:, 0],
                                samp["temperature"], samp["top_k"],
                                samp["top_p"])
        taps_last = jnp.take_along_axis(out.taps, hp[:, None, None],
                                        axis=1)[:, 0]

        tokens = state["tokens"]
        tokens = tokens.at[:, self.pos_offset:self.pos_offset + Pb].set(
            prompts)
        tokens = tokens.at[jnp.arange(B), fused].set(first)

        cp = jnp.broadcast_to(fused - 1, (B,))
        zero = jnp.zeros((B,), jnp.int32)
        state.update(
            tokens=tokens,
            logprobs=state["logprobs"].at[jnp.arange(B), fused].set(
                _token_logprob(out.logits[:, 0], first)),
            last=jnp.broadcast_to(fused, (B,)).astype(jnp.int32),
            taps_last=taps_last,
            tcache=cache_ops.commit(out.cache, None, cp, zero),
        )
        if self.ecfg.drafter_mode != "none":
            dcache = state["dcache"]
            if Pb > 1:
                pos = (jnp.arange(Pb - 1, dtype=jnp.int32)[None]
                       + self.pos_offset)
                pos = jnp.broadcast_to(pos, (B, Pb - 1))
                dcache = D.extend(self.dcfg, self.tcfg, dparams, dcache,
                                  prompts[:, 1:], out.taps[:, -Pb:-1], pos)
                # pad pairs wrote drafter positions beyond the real prompt
                dcache = cache_ops.commit(dcache, None, cp - 1, zero)
            state["dcache"] = dcache
        return self._rep(state)

    def _chunk_impl(self, tparams, dparams, state, chunk, start):
        """Recurrent-family bucketed prefill step: feed ``chunk`` (B, c) of
        the prompt through a decode-mode forward at positions ``start..``.
        Exact for SSM/RG-LRU state (pads would corrupt the recurrence, so
        chunking replaces padding); each chunk size is a power of two, so a
        length-P prompt costs popcount(P) cached traces."""
        tparams, dparams = self._rep(tparams), self._rep(dparams)
        state = self._rep(state)
        B, c = chunk.shape
        off = self.pos_offset
        positions = jnp.broadcast_to(
            (start + off + jnp.arange(c, dtype=jnp.int32))[None], (B, c))
        out = self.model.forward(tparams, chunk, mode="decode",
                                 positions=positions, cache=state["tcache"],
                                 collect_taps=True, head_last_only=True)
        fused = start + off + c
        samp = state["sampling"]
        first = SD.sample_token(step_keys(samp, fused), out.logits[:, -1],
                                samp["temperature"], samp["top_k"],
                                samp["top_p"])
        tokens = jax.lax.dynamic_update_slice(state["tokens"], chunk,
                                              (0, start + off))
        tokens = tokens.at[jnp.arange(B), fused].set(first)
        new = dict(state)
        new.update(
            tokens=tokens,
            logprobs=state["logprobs"].at[jnp.arange(B), fused].set(
                _token_logprob(out.logits[:, -1], first)),
            last=jnp.broadcast_to(fused, (B,)).astype(jnp.int32),
            taps_last=out.taps[:, -1],
            tcache=out.cache,
        )
        if self.ecfg.drafter_mode != "none":
            # drafter pair at position p pairs (taps[p], token[p+1]): the
            # chunk supplies tokens start..start+c-1, so taps come from the
            # previous chunk's last tap followed by this chunk's first c-1
            taps = jnp.concatenate([state["taps_last"][:, None],
                                    out.taps[:, :-1]], axis=1)
            dpos = jnp.broadcast_to(
                (start - 1 + off + jnp.arange(c, dtype=jnp.int32))[None],
                (B, c))
            new["dcache"] = D.extend(self.dcfg, self.tcfg, dparams,
                                     state["dcache"], chunk, taps, dpos)
        return self._rep(new)

    @staticmethod
    def prefill_buckets(length: int) -> List[int]:
        """MSB-first power-of-two decomposition of a prompt length — the
        chunk sizes of a bucketed recurrent-family prefill. (Attention
        families instead right-pad to the next power of two: one forward.)"""
        return [1 << b for b in range(length.bit_length() - 1, -1, -1)
                if length >> b & 1]

    def _chunk_only(self) -> bool:
        """Bucketing strategy: padding is only sound when every cache
        position is append-only. Recurrent state (ssm/hybrid) would fold the
        pads into the recurrence, and ring (sliding-window) KV wraps on
        write — a pad past the window evicts live prompt entries — so both
        take the MSB-chunking path; pure append-only attention pads."""
        if self._pad_unsafe is None:
            tpl = jax.eval_shape(
                self._prefill_impl, self.tparams, self.dparams,
                jax.ShapeDtypeStruct((1, 4), jnp.int32), {},
                sampling_state_sds(1))
            self._pad_unsafe = (
                self.tcfg.family in ("ssm", "hybrid")
                or cache_ops.has_ring_cache(tpl["tcache"], self.ecfg.max_len))
        return self._pad_unsafe

    def _admission_prefill(self, prompt, extras, samp):
        """Batch-1 prefill for slot admission, bucketed per EngineConfig.
        ``samp`` is the request's device-side sampling row
        (batch_sampling_state at batch 1)."""
        P = int(prompt.shape[1])
        if not self.ecfg.bucket_prefill:
            return self._prefill(self.tparams, self.dparams, prompt, extras,
                                 samp)
        if self._chunk_only():
            sizes = self.prefill_buckets(P)
            state = self._prefill(self.tparams, self.dparams,
                                  prompt[:, :sizes[0]], extras, samp)
            start = sizes[0]
            for c in sizes[1:]:
                state = self._chunk(self.tparams, self.dparams, state,
                                    prompt[:, start:start + c],
                                    jnp.asarray(start, jnp.int32))
                start += c
            return state
        Pb = 1 << max(P - 1, 0).bit_length()     # next power of two >= P
        if self.pos_offset + Pb >= self.ecfg.max_len:
            # bucket would pad past the cache (long recompute-prefill
            # resumes, vlm offsets): take the exact-length trace instead
            return self._prefill(self.tparams, self.dparams, prompt, extras,
                                 samp)
        padded = jnp.pad(prompt, ((0, 0), (0, Pb - P)))
        return self._prefill_pad(self.tparams, self.dparams, padded,
                                 jnp.asarray(P, jnp.int32), extras, samp)

    # ------------------------------------------------------------------
    # one speculative iteration
    # ------------------------------------------------------------------
    def _step_impl(self, tparams, dparams, state, greedy_only=False):
        tparams, dparams = self._rep(tparams), self._rep(dparams)
        out = speculative_step(self.model, self.tcfg, self.dcfg, self.ecfg,
                               tparams, dparams, self._rep(state),
                               greedy_only=greedy_only)
        return self._rep(out)

    # ------------------------------------------------------------------
    # per-slot lifecycle (continuous batching; serving/scheduler.py)
    # ------------------------------------------------------------------
    @property
    def slot_axes(self):
        """Per-leaf batch axis of the decode state, inferred structurally
        (cache_ops.batch_axes) from abstract prefills at batch 1 vs 2.
        Computed once; static thereafter (required: axes feed lax slicing)."""
        if self._slot_axes is None:
            def pf(b):
                return jax.eval_shape(
                    self._prefill_impl, self.tparams, self.dparams,
                    jax.ShapeDtypeStruct((b, 4), jnp.int32), {},
                    sampling_state_sds(b))
            self._slot_axes = cache_ops.batch_axes(pf(1), pf(2))
        return self._slot_axes

    def _abstract_state(self):
        """Cached abstract (jax.eval_shape) contiguous decode state at the
        engine batch — the ONE template pspec / state_shardings /
        blank_state all derive from, so the full prefill is abstract-traced
        once per Engine, not once per consumer."""
        if self._contig_tpl is None:
            self._contig_tpl = jax.eval_shape(
                self._prefill_impl, self.tparams, self.dparams,
                jax.ShapeDtypeStruct((self.batch, 4), jnp.int32), {},
                sampling_state_sds(self.batch))
        return self._contig_tpl

    @property
    def pspec(self):
        """Paged-layout leaf tags (cache_ops.paged_spec) over the decode
        state: which leaves live in the page pool vs per-slot rows."""
        if self._pspec is None:
            self._pspec = cache_ops.paged_spec(self._abstract_state(),
                                               self.ecfg.max_len)
        return self._pspec

    @property
    def paged_axes(self):
        """batch_axes of the *paged* state: pool leaves have no batch axis,
        so write_slot/reset_slot skip them automatically and only touch
        per-slot rows."""
        if self._paged_axes is None:
            def blank(b):
                return jax.eval_shape(lambda: cache_ops.paged_state(
                    make_decode_state(self.model, self.tcfg, self.dcfg,
                                      self.ecfg, b),
                    self.pspec, self.ecfg.page_size, self.pool_pages))
            self._paged_axes = cache_ops.batch_axes(blank(1), blank(2))
        return self._paged_axes

    @property
    def state_shardings(self):
        """NamedSharding pytree of the contiguous decode state (shard_model
        only): attention k/v leaves (full-length rows and ring windows)
        storage-shard over the KV-head axis ("model"), everything else
        replicates (sharding/rules.serve_state_specs). One tree serves
        every batch size — the sharded axes are trailing (KV, hd) dims
        that batch doesn't touch."""
        if self._contig_sh is None:
            self._contig_sh = self._named(shard_rules.serve_state_specs(
                self._abstract_state(), self.mesh))
        return self._contig_sh

    @property
    def paged_state_shardings(self):
        """NamedSharding pytree of the paged decode state (shard_model
        only): k/v page *pools* shard over the same trailing (KV, hd) axes,
        position pools / block tables / per-slot rows replicate — so page
        growth, admission scatters, and preemption frees are sharded-local
        data movement, never a pool relayout."""
        if self._paged_sh is None:
            tpl = jax.eval_shape(lambda: cache_ops.paged_state(
                make_decode_state(self.model, self.tcfg, self.dcfg,
                                  self.ecfg, self.batch),
                self.pspec, self.ecfg.page_size, self.pool_pages))
            tpl["block_table"] = jax.ShapeDtypeStruct(
                (self.batch, self.pages_per_slot), jnp.int32)
            self._paged_sh = self._named(
                shard_rules.serve_state_specs(tpl, self.mesh))
        return self._paged_sh

    def blank_state(self) -> dict:
        """An all-idle batch state: empty caches (positions -1), zero tokens,
        every slot frozen (new_count == max_new_tokens so the budget check
        keeps it inert). Slots come alive via ``prefill_into_slot``, which
        also scatters the request's per-slot sampling-policy row. In the
        paged layout, full-length KV leaves are page pools and the state
        carries a per-slot ``block_table`` (B, max_len/page_size), all -1."""
        sds = self._abstract_state()
        state = make_decode_state(
            self.model, self.tcfg, self.dcfg, self.ecfg, self.batch,
            taps_dtype=sds["taps_last"].dtype,
            new_count_fill=self.ecfg.max_new_tokens,
            sampling=blank_sampling_state(self.batch))
        if self.paged:
            state = cache_ops.paged_state(state, self.pspec,
                                          self.ecfg.page_size,
                                          self.pool_pages)
            state["block_table"] = jnp.full(
                (self.batch, self.pages_per_slot), -1, jnp.int32)
        if self.mesh is not None:
            state = jax.device_put(state, self.paged_state_shardings
                                   if self.paged else self.state_shardings)
        return state

    def serve_state(self) -> dict:
        """Decode state to START a serving session with. Cache-off engines
        always start blank; a prefix-cache engine resumes from the previous
        session's retained state — cached page CONTENT lives in the state's
        pool arrays (the host-side index only maps page ids), so starting
        from a fresh blank pool would orphan every index entry onto zeroed
        pages. The retained state has every slot freed (block-table rows
        -1, counters inert); only held pages carry meaningful bytes."""
        if self.prefix_cache is None or self._serve_state is None:
            return self.blank_state()
        return self._serve_state

    def retain_state(self, state: dict) -> None:
        """Hand a serving session's final state back for cross-session page
        reuse (no-op without a prefix cache). Scheduler.serve calls this
        after draining; between sessions the engine keeps exactly one state
        alive, so pool memory is not duplicated."""
        if self.prefix_cache is not None:
            self._serve_state = state

    @property
    def commit_stride(self) -> int:
        """Max positions one speculative iteration writes into the cache
        (K drafted + 1 bonus; 1 for vanilla AR): the capacity headroom a
        slot needs beyond its last committed position before it may step."""
        return (self.ecfg.K if self.ecfg.drafter_mode != "none" else 0) + 1

    def pages_for(self, length: int) -> int:
        """Pages covering ``length`` cache positions (capped at max_len)."""
        if not self.paged:
            return 0
        return -(-min(max(length, 1), self.ecfg.max_len)
                 // self.ecfg.page_size)

    def pages_needed(self, prompt_len: int,
                     max_new: Optional[int] = None) -> int:
        """KV pages one request occupies for its whole lifetime: prompt +
        budget + worst-case speculative overshoot, in page units."""
        if not self.paged:
            return 0
        budget = self.ecfg.max_new_tokens if max_new is None else max_new
        return self.pages_for(prompt_len + self.pos_offset + budget
                              + self.ecfg.K + 1)

    def initial_pages(self, prompt_len: int,
                      max_new: Optional[int] = None, *,
                      resume: bool = False) -> int:
        """Pages admission claims up front. Upfront growth reserves the
        whole lifetime (``pages_needed``); incremental growth claims only
        the prompt plus one speculative block — ``ensure_capacity`` grows
        the allocation as the slot's length actually crosses page
        boundaries during decode.

        ``resume`` (incremental only): a no-commit recompute-prefill of a
        preempted SAMPLED stream needs one position LESS than a fresh
        admission of the same length. A fresh prefill of ``prompt_len``
        tokens commits one extra token (last = prompt_len + offset), so the
        next step writes positions up to last + K and the claim must cover
        ``prompt_len + offset + K + 1``. A resume forces the stream's final
        token at position ``prompt_len - 1 + offset`` without committing
        past it, so the next step tops out one position earlier — claiming
        the fresh-size block would over-reserve a page whenever
        ``prompt_len + offset + K`` lands on a page boundary."""
        if not self.paged:
            return 0
        if not self.incremental:
            return self.pages_needed(prompt_len, max_new)
        return self.pages_for(prompt_len + self.pos_offset
                              + self.commit_stride - (1 if resume else 0))

    def can_admit(self, prompt_len: int, max_new: Optional[int] = None,
                  full: bool = False, tokens=None,
                  resume: bool = False) -> bool:
        """Whether the pool can admit one more request of this shape right
        now (always True for the contiguous layout — a free slot is a free
        max_len row). ``full`` gates on the whole-lifetime need even under
        incremental growth — the scheduler uses it when re-admitting a
        preempted request, so a resumed victim cannot be immediately
        re-evicted by the same pressure that evicted it.

        With a prefix cache, cache-only pages count as reclaimable (they
        are evicted LRU on allocation pressure, so a full pool of cold
        cache entries never wedges admission), and passing the prompt
        ``tokens`` gates on the EFFECTIVE post-hit need: pages the prompt
        will map from the cache don't have to come off the free list.

        ``resume`` must mirror the ``prefill_into_slot(resume=...)`` flag of
        the admission being gated, so the gate prices exactly the pages the
        claim will take (see :meth:`initial_pages` — a no-commit resume
        claims one position less)."""
        if not self.paged:
            return True
        need = (self.pages_needed(prompt_len, max_new) if full
                else self.initial_pages(prompt_len, max_new, resume=resume))
        avail = self.allocator.n_free
        if self.prefix_cache is not None:
            pinned = ()
            if tokens is not None and self._hits_ok():
                shared, cow = self.prefix_cache.probe(tokens)
                need -= len(shared)
                # the hit itself pins its shared pages (and CoW source), so
                # they can't double as eviction headroom for the fresh ones
                pinned = shared + ([cow] if cow is not None else [])
            avail += self.prefix_cache.evictable(self.allocator, pinned)
        return need <= avail

    def slot_capacity(self, slot: int) -> int:
        """Cache positions the slot's current page allocation covers."""
        if not self.paged:
            return self.ecfg.max_len
        return len(self._slot_pages[slot]) * self.ecfg.page_size

    def ensure_capacity(self, state: dict, slot: int, length: int):
        """Grow ``slot``'s page allocation to cover ``length`` positions,
        claiming pages from the pool only when the slot's length actually
        crossed a page boundary. Returns ``(state, ok)`` — ``ok`` False
        when the pool is exhausted (the caller preempts or stalls the
        slot; stepping a slot without capacity would silently drop KV
        writes beyond its pages). No-op (always ok) for contiguous
        layouts and upfront growth, where capacity was reserved at
        admission."""
        if not self.incremental:
            return state, True
        need = self.pages_for(length)
        have = len(self._slot_pages[slot])
        if need <= have:
            return state, True
        got = self._alloc_pages(need - have)
        if got is None:
            return state, False
        self._slot_pages[slot].extend(got)
        # blank-on-alloc: a recycled page may carry the previous owner's
        # stale positions, and growth splices it into the table without
        # the full overwrite an admission scatter does — blank BEFORE the
        # table maps it, so it can never read as attendable history
        grow = np.full((self.pages_per_slot,), -1, np.int32)
        grow[:len(got)] = got
        state = self._blank_row(state, jnp.asarray(grow))
        row = np.full((self.pages_per_slot,), -1, np.int32)
        row[:len(self._slot_pages[slot])] = self._slot_pages[slot]
        state = dict(state)
        state["block_table"] = self._set_table_row(
            state["block_table"], jnp.asarray(slot, jnp.int32),
            jnp.asarray(row))
        return state, True

    def prefill_into_slot(self, state: dict, prompt, slot: int,
                          extras: Optional[dict] = None,
                          sampling: Optional[SamplingParams] = None,
                          max_new: Optional[int] = None,
                          resume: bool = False):
        """Admit one request into batch row ``slot`` of a live state: prefill
        the prompt as a batch-1 state (bucketed to power-of-two lengths when
        ``bucket_prefill``), then scatter every batched leaf's row into the
        slot (cache_ops.write_slot) — including the request's per-slot
        ``sampling`` policy row. Neighbor slots are untouched — rows are
        independent through attention, caches, and verification, so
        mid-stream admission cannot perturb already-decoding requests.

        In the paged layout the slot additionally claims
        ``initial_pages(len(prompt), max_new)`` pages from the pool (callers
        gate on ``can_admit``) and the prefilled KV is scattered into those
        pages instead of a contiguous row; under incremental growth the
        claim covers only prompt + one speculative block, and the scheduler
        calls ``ensure_capacity`` before each step as the slot grows.

        With ``EngineConfig(prefix_cache=True)`` (dense targets), the
        prompt is first matched against the engine's
        :class:`~repro.serving.prefix_cache.PrefixCache`: cached pages are
        mapped (refcount-shared) into the slot's block-table row, a
        divergent partial page is copied-on-write, and only the uncached
        suffix is prefilled — token-for-token identical to the cold path.
        ``Engine.last_hit_tokens`` reports how many positions the admission
        served from cache (0 when cold).

        ``resume=False`` (fresh admission): the prefill commits one token —
        greedy rows by argmax, sampled rows by a seeded draw from the warped
        target distribution — and returns ``(new_state, first_token,
        last_pos)`` with new_count starting at 1.

        ``resume=True`` (recompute-prefill of a preempted SAMPLED request,
        ``prompt`` = original prompt + tokens generated before eviction):
        the engine prefills ``prompt[:-1]`` like a fresh admission but
        FORCES the committed token to ``prompt[-1]`` — already known, not
        re-sampled — and starts the slot's committed count at 0. The slot
        then holds exactly the state an uninterrupted run has at a step
        boundary (caches forwarded through the second-to-last prefix token,
        the final token committed-but-not-yet-verified), so the next
        speculative step restarts verification at the same committed prefix
        and re-derives the same ``fold_in(seed, position)`` keys — replaying
        the uninterrupted tokens exactly. Returns ``(new_state, None,
        last_pos)``. (Greedy resumes don't need this: their
        prefill-committed argmax token equals the verify path's token by
        construction.)"""
        prompt = jnp.asarray(prompt, jnp.int32)[None]
        res_tok = jnp.asarray(0, jnp.int32)
        if resume:
            prompt, res_tok = prompt[:, :-1], prompt[0, -1]
        sp = sampling or self.ecfg.sampling
        self._slot_sampled[slot] = not sp.is_greedy
        samp = batch_sampling_state(sp, 1)
        res = jnp.asarray(1 if resume else 0, jnp.int32)
        self.last_hit_tokens = 0
        self.last_logprob = 0.0
        if not self.paged:
            src = self._admission_prefill(prompt, extras or {}, samp)
            state = self._admit(state, src, jnp.asarray(slot, jnp.int32),
                                res, res_tok)
        else:
            if self._slot_pages[slot]:
                raise RuntimeError(f"slot {slot} still holds pages; "
                                   "free_slot it before re-admission")
            n = self.initial_pages(int(prompt.shape[1]) + (1 if resume
                                                           else 0), max_new,
                                   resume=resume)
            hit = None
            if self._hits_ok(extras):
                shared, cow = self.prefix_cache.match(np.asarray(prompt[0]))
                if shared or cow is not None:
                    hit = (shared, cow)
            if hit is not None:
                state, src = self._hit_admission(state, prompt, slot, n,
                                                 hit[0], hit[1], samp, res,
                                                 res_tok)
            else:
                pages = self._alloc_pages(n)
                if pages is None:
                    raise RuntimeError(
                        f"page pool exhausted ({n} needed, "
                        f"{self.allocator.n_free} free); gate on can_admit")
                self._slot_pages[slot] = pages
                row = np.full((self.pages_per_slot,), -1, np.int32)
                row[:n] = pages
                src = self._admission_prefill(prompt, extras or {}, samp)
                state = self._paged_admit(state, src,
                                          jnp.asarray(slot, jnp.int32),
                                          jnp.asarray(row), jnp.asarray(row),
                                          res, res_tok)
                if self._hits_ok(extras):
                    self.prefix_cache.insert_stream(np.asarray(prompt[0]),
                                                    pages, self.allocator)
                    self.prefix_cache.note_admission(0, False)
        last = int(src["last"][0])
        if resume:
            self.last_logprob = 0.0
            return state, None, last
        first = int(src["tokens"][0, last])
        self.last_logprob = float(src["logprobs"][0, last])
        return state, first, last

    @staticmethod
    def _resume_fixup(src, resume, res_tok):
        """Turn a batch-1 admission prefill into a step-boundary resume when
        ``resume`` (traced 0/1) is set: the token committed at ``last`` is
        forced to ``res_tok`` (the prefix's final, already-emitted token —
        the prefill's sampled/argmax draw is discarded) and the committed
        count starts at 0, so nothing is harvested twice and the next step
        verifies the prefix's true continuation."""
        src = dict(src)
        last = src["last"][0]
        keep = src["tokens"][0, last]
        src["tokens"] = src["tokens"].at[0, last].set(
            jnp.where(resume > 0, res_tok, keep))
        src["new_count"] = src["new_count"] * (1 - resume)
        return src

    def _admit_impl(self, dst, src, slot, resume, res_tok):
        return cache_ops.write_slot(
            dst, self._resume_fixup(src, resume, res_tok), slot,
            self.slot_axes)

    def _paged_admit_impl(self, dst, src, slot, row, scatter_row, resume,
                          res_tok):
        """``row`` is the slot's full block-table mapping; ``scatter_row``
        selects which of those pages receive the prefilled view (equal on a
        cold admission; a prefix-cache hit masks its shared prefix pages to
        -1 so only freshly owned suffix/CoW pages are written — shared
        pages already hold exactly the bytes the view carries for them)."""
        core = {k: v for k, v in dst.items() if k != "block_table"}
        core = cache_ops.admit_pages(
            core, self._resume_fixup(src, resume, res_tok), slot, row,
            self.paged_axes, self.pspec, scatter_row=scatter_row)
        core["block_table"] = dst["block_table"].at[slot].set(row)
        return core

    # ------------------------------------------------------------------
    # prefix caching (serving/prefix_cache.py; EngineConfig.prefix_cache)
    # ------------------------------------------------------------------
    def _hits_ok(self, extras: Optional[dict] = None) -> bool:
        """Whether prefix-cache sharing applies to this admission. Pages
        hold the full per-position state only for dense attention targets:
        recurrent families (ssm/hybrid) carry per-slot state outside the
        pools, vlm/encdec condition on per-request extras / position
        offsets, and moe couples batch rows — all of those serve unchanged
        with the cache structurally idle (no matches, no inserts)."""
        return (self.prefix_cache is not None
                and self.tcfg.family == "dense"
                and not extras
                and self.pos_offset == 0)

    def _alloc_pages(self, n: int) -> Optional[List[int]]:
        """``allocator.alloc`` with prefix-cache pressure relief: on
        exhaustion, evict least-recently-used cache-only pages (pinned
        pages — refcount > 1 — are skipped) and retry once."""
        got = self.allocator.alloc(n)
        if got is None and self.prefix_cache is not None:
            self.prefix_cache.evict(n - self.allocator.n_free,
                                    self.allocator)
            got = self.allocator.alloc(n)
        return got

    def _blank_row_impl(self, state, row):
        core = {k: v for k, v in state.items() if k != "block_table"}
        core = cache_ops.blank_pages(core, row, self.pspec)
        core["block_table"] = state["block_table"]
        return core

    def _copy_page_impl(self, state, src_page, dst_page):
        core = {k: v for k, v in state.items() if k != "block_table"}
        core = cache_ops.copy_page(core, src_page, dst_page, self.pspec)
        core["block_table"] = state["block_table"]
        return core

    def _hit_seed_impl(self, state, row, tokens_row, start, samp):
        """Seed the batch-1 contiguous state of a prefix-cache hit: gather
        the slot's mapped row (shared prefix pages + CoW copy + fresh
        suffix pages) into the per-slot view and blank every view index >=
        ``start`` — fresh pages may carry a previous owner's stale
        positions, and the CoW page's final drafter entry belongs to a
        different lookahead token. Indices below ``start`` are cached
        content, valid by the full-key invariant (prefix_cache.py). The
        suffix chunks (``_hit_chunk`` then ``_chunk``) then recompute
        positions ``start..P-1`` exactly as a cold prefill would."""
        src = make_decode_state(self.model, self.tcfg, self.dcfg, self.ecfg,
                                1, sampling=samp)
        table = row[None]
        idx = jnp.arange(self.ecfg.max_len, dtype=jnp.int32)

        def seed(blank, pooled, tag):
            if tag == cache_ops.NOT_PAGED:
                return blank
            view = cache_ops.gather_pages(pooled, table, tag)
            if tag == cache_ops.PAGED_POS:
                view = jnp.where(idx >= start, -1, view)
            return view

        keys = (("tcache", "dcache") if self.ecfg.drafter_mode != "none"
                else ("tcache",))
        for key in keys:
            src[key] = jax.tree.map(seed, src[key], state[key],
                                    self.pspec[key])
        src["tokens"] = tokens_row
        src["last"] = jnp.full((1,), start, jnp.int32)
        return src

    def _hit_chunk_impl(self, tparams, dparams, state, chunk, start):
        """First suffix chunk of a prefix-cache hit: identical to
        ``_chunk_impl`` except the drafter pair at position ``start - 1``
        is SKIPPED — it pairs the cached tap at start-1 with the chunk's
        first token, and the full-key scheme guarantees the cached page
        already committed exactly that entry (the lookahead token is part
        of the page's identity), while the tap itself was never recomputed
        here. Later chunks have taps_last and take ``_chunk``."""
        tparams, dparams = self._rep(tparams), self._rep(dparams)
        state = self._rep(state)
        B, c = chunk.shape
        off = self.pos_offset
        positions = jnp.broadcast_to(
            (start + off + jnp.arange(c, dtype=jnp.int32))[None], (B, c))
        out = self.model.forward(tparams, chunk, mode="decode",
                                 positions=positions, cache=state["tcache"],
                                 collect_taps=True, head_last_only=True)
        fused = start + off + c
        samp = state["sampling"]
        first = SD.sample_token(step_keys(samp, fused), out.logits[:, -1],
                                samp["temperature"], samp["top_k"],
                                samp["top_p"])
        tokens = jax.lax.dynamic_update_slice(state["tokens"], chunk,
                                              (0, start + off))
        tokens = tokens.at[jnp.arange(B), fused].set(first)
        new = dict(state)
        new.update(
            tokens=tokens,
            logprobs=state["logprobs"].at[jnp.arange(B), fused].set(
                _token_logprob(out.logits[:, -1], first)),
            last=jnp.broadcast_to(fused, (B,)).astype(jnp.int32),
            taps_last=out.taps[:, -1],
            tcache=out.cache,
        )
        if self.ecfg.drafter_mode != "none" and c > 1:
            dpos = jnp.broadcast_to(
                (start + off + jnp.arange(c - 1, dtype=jnp.int32))[None],
                (B, c - 1))
            new["dcache"] = D.extend(self.dcfg, self.tcfg, dparams,
                                     state["dcache"], chunk[:, 1:],
                                     out.taps[:, :-1], dpos)
        return self._rep(new)

    def _hit_admission(self, state, prompt, slot, n, shared, cow, samp,
                       res, res_tok):
        """Admission fast path when ``prompt`` matched cached pages: map
        the shared pages into the slot's block-table row (incref — the
        cache and the slot now co-own them), copy-on-write the divergent
        partial page if any, and prefill only the uncached suffix through
        decode-mode chunks. Reference-order matters: matched pages and the
        CoW source are pinned BEFORE the fresh allocation so the eviction
        that allocation may trigger can never reclaim them."""
        ps = self.ecfg.page_size
        self.allocator.incref(shared)
        if cow is not None:
            self.allocator.incref([cow])
        fresh = self._alloc_pages(n - len(shared))
        if fresh is None:
            self.allocator.free(shared)
            if cow is not None:
                self.allocator.free([cow])
            raise RuntimeError(
                f"page pool exhausted ({n - len(shared)} needed, "
                f"{self.allocator.n_free} free); gate on can_admit")
        start = len(shared) * ps
        if cow is not None:
            # fresh[0] becomes the slot-owned copy; everything in it is
            # valid except the final drafter entry, so the suffix restarts
            # one position early to recompute it
            state = self._copy_page(state, jnp.asarray(cow, jnp.int32),
                                    jnp.asarray(fresh[0], jnp.int32))
            self.allocator.free([cow])          # unpin the source
            start += ps - 1
        row_pages = shared + fresh
        self._slot_pages[slot] = row_pages
        row = np.full((self.pages_per_slot,), -1, np.int32)
        row[:len(row_pages)] = row_pages
        scat = row.copy()
        scat[:len(shared)] = -1     # never write pages other owners hold
        ptoks = np.asarray(prompt[0])
        tokens_row = np.zeros((1, self.ecfg.max_len), np.int32)
        tokens_row[0, :ptoks.size] = ptoks
        src = self._hit_seed(state, jnp.asarray(row), jnp.asarray(tokens_row),
                             jnp.asarray(start, jnp.int32), samp)
        sizes = self.prefill_buckets(int(prompt.shape[1]) - start)
        src = self._hit_chunk(self.tparams, self.dparams, src,
                              prompt[:, start:start + sizes[0]],
                              jnp.asarray(start, jnp.int32))
        pos = start + sizes[0]
        for c in sizes[1:]:
            src = self._chunk(self.tparams, self.dparams, src,
                              prompt[:, pos:pos + c],
                              jnp.asarray(pos, jnp.int32))
            pos += c
        state = self._paged_admit(state, src, jnp.asarray(slot, jnp.int32),
                                  jnp.asarray(row), jnp.asarray(scat), res,
                                  res_tok)
        # insert-on-admit: the verifiable prompt prefix — including a
        # diverged CoW page, whose full key now carries THIS lookahead
        self.prefix_cache.insert_stream(ptoks, row_pages, self.allocator)
        self.last_hit_tokens = start
        self.prefix_cache.note_admission(start, cow is not None)
        return state, src

    def free_slot(self, state: dict, slot: int,
                  final_tokens=None) -> dict:
        """Reset one slot's per-slot rows to blank (positions -1) and
        refreeze it (new_count = max_new_tokens) so it idles until the next
        admission. In the paged layout this also releases the slot's page
        references — a page returns to the pool at refcount zero, while
        pages the prefix cache (or a sharing slot) still holds survive
        intact — and blanks its block-table row. Mandatory for paged
        engines, or the pool leaks; cosmetic for contiguous (admission
        fully overwrites).

        ``final_tokens`` (prefix-cache engines): the request's committed
        stream — prompt + generated tokens, trimmed to what was actually
        emitted. Every full page the stream verifies (its lookahead token
        included) is indexed before the release, so the NEXT request
        sharing the prefix — including this very request resuming after a
        preemption — admits against cached pages."""
        self._slot_sampled[slot] = False
        if self.paged:
            pages = self._slot_pages[slot]
            if final_tokens is not None and pages and self._hits_ok():
                self.prefix_cache.insert_stream(
                    np.asarray(final_tokens, np.int32).reshape(-1), pages,
                    self.allocator)
            self.allocator.free(pages)
            self._slot_pages[slot] = []
            return self._paged_free(state, jnp.asarray(slot, jnp.int32))
        return self._free(state, jnp.asarray(slot, jnp.int32))

    def _free_impl(self, state, slot):
        return cache_ops.reset_slot(
            state, slot, self.slot_axes,
            fills={"new_count": self.ecfg.max_new_tokens})

    def _paged_free_impl(self, state, slot):
        core = {k: v for k, v in state.items() if k != "block_table"}
        # NO page blanking here: the freed pages may still be mapped by the
        # prefix cache or by sharing slots, and their content must survive.
        # The blank-on-recycle invariant moved to the acquisition side —
        # ensure_capacity blanks growth pages, admission scatters fully
        # overwrite claimed pages (cache_ops.blank_pages docstring).
        core = cache_ops.reset_slot(
            core, slot, self.paged_axes,
            fills={"new_count": self.ecfg.max_new_tokens})
        core["block_table"] = state["block_table"].at[slot].set(
            jnp.full((self.pages_per_slot,), -1, jnp.int32))
        return core

    # ------------------------------------------------------------------
    # swap-to-host preemption (EngineConfig.swap="host")
    # ------------------------------------------------------------------
    def _swap_gather_impl(self, state, slot, row):
        """Batch-1 contiguous snapshot of ``slot``: per-slot rows sliced,
        paged leaves gathered through ``row`` — one jit, the device half
        of swap-out (cache_ops.extract_slot)."""
        core = {k: v for k, v in state.items() if k != "block_table"}
        return cache_ops.extract_slot(core, slot, row, self.paged_axes,
                                      self.pspec)

    def _swap_scatter_impl(self, dst, src, slot, row, scatter_row):
        """Swap-in: ``_paged_admit_impl`` minus the resume fixup — the
        snapshot already IS a step-boundary state, so re-admitting it
        verbatim restores the victim bitwise. ``scatter_row`` masks pages
        that never left the device (-1: dropped by scatter_pages)."""
        core = {k: v for k, v in dst.items() if k != "block_table"}
        core = cache_ops.admit_pages(core, src, slot, row, self.paged_axes,
                                     self.pspec, scatter_row=scatter_row)
        core["block_table"] = dst["block_table"].at[slot].set(row)
        return core

    def _b1_template(self):
        """Cached abstract batch-1 contiguous state (the swap snapshot's
        shapes/dtypes; also the skeleton swap-in rebuilds around)."""
        if self._b1_tpl is None:
            self._b1_tpl = jax.eval_shape(
                self._prefill_impl, self.tparams, self.dparams,
                jax.ShapeDtypeStruct((1, 4), jnp.int32), {},
                sampling_state_sds(1))
        return self._b1_tpl

    def _swap_layout(self):
        """Cached ``(row_bytes, page_bytes)``: host bytes of one slot's
        per-slot rows, and of one page's payload summed across every paged
        leaf — ``swap_bytes_estimate`` prices a victim without touching
        the device."""
        if self._swap_sizes is None:
            row_b = page_b = 0
            for t, ax, tag in zip(jax.tree.leaves(self._b1_template()),
                                  jax.tree.leaves(self.paged_axes),
                                  jax.tree.leaves(self.pspec)):
                n = int(np.prod(t.shape, dtype=np.int64)) * t.dtype.itemsize
                if tag != cache_ops.NOT_PAGED:
                    page_b += n // self.pages_per_slot
                elif ax >= 0:
                    row_b += n
            self._swap_sizes = (row_b, page_b)
        return self._swap_sizes

    @staticmethod
    def _host_span(host_idx: List[int], page: int):
        """View indices (along the W axis) of the pages in ``host_idx``."""
        return np.concatenate([np.arange(i * page, (i + 1) * page)
                               for i in host_idx])

    def swap_bytes_estimate(self, slot: int) -> int:
        """Host bytes swapping ``slot`` out would store right now: its
        per-slot rows plus one page payload per page it exclusively owns
        (refcount == 1; shared pages stay resident)."""
        row_b, page_b = self._swap_layout()
        n_host = sum(1 for p in self._slot_pages[slot]
                     if self.allocator.refcount(p) == 1)
        return row_b + page_b * n_host

    def swap_out_slot(self, state: dict, slot: int, rid):
        """Preempt ``slot`` by copying its state to the host pool under key
        ``rid`` instead of discarding it. Returns ``(state, ok)``: on
        ``ok`` the slot is freed (device pages of refcount 1 recycled,
        shared pages left resident under the handle's reference) and
        ``swap_last_bytes`` holds the bytes parked; ``ok`` False means the
        host pool couldn't take the snapshot — NOTHING changed, the caller
        falls back to recompute-prefill preemption.

        Called only at a harvest/sync boundary (where the scheduler
        preempts): there the slot's state is self-consistent — caches
        forwarded through ``last - 1``, the token at ``last`` committed
        but not yet verified — so restoring it bitwise (swap_in_slot)
        continues the run token-for-token, greedy and seeded-sampled rows
        alike. The committed counters are zeroed in the snapshot to match
        the scheduler's resume convention (``_prev_new = 0``)."""
        if not self.swap_enabled:
            return state, False
        pages = self._slot_pages[slot]
        host_idx = [i for i, p in enumerate(pages)
                    if self.allocator.refcount(p) == 1]
        row_b, page_b = self._swap_layout()
        if not self.host_pool.can_store(row_b + page_b * len(host_idx)):
            return state, False
        ps = self.ecfg.page_size
        row = np.full((self.pages_per_slot,), -1, np.int32)
        row[:len(pages)] = pages
        src = jax.device_get(self._swap_gather(
            state, jnp.asarray(slot, jnp.int32), jnp.asarray(row)))
        span = (self._host_span(host_idx, ps) if host_idx else None)
        ph = np.zeros((0,), np.int8)     # structure-keeping placeholder

        def trim(leaf, ax, tag):
            if tag != cache_ops.NOT_PAGED:
                if span is None:
                    return ph
                w_ax = cache_ops.view_width_axis(leaf.ndim, tag)
                return np.ascontiguousarray(np.take(leaf, span, axis=w_ax))
            return np.asarray(leaf) if ax >= 0 else ph

        snap = jax.tree.map(trim, src, self.paged_axes, self.pspec)
        # committed counters restart at 0 on resume (scheduler convention:
        # _prev_new = 0, budget rebased to the remaining tokens) — the
        # budget arithmetic is shift-invariant, so tokens are unchanged
        snap["new_count"] = np.zeros_like(snap["new_count"])
        snap["slot_iters"] = np.zeros_like(snap["slot_iters"])
        nbytes = sum(leaf.nbytes for leaf in jax.tree.leaves(snap))
        h = _SwapHandle(snap=snap, page_row=list(pages), host_idx=host_idx,
                        last=int(snap["last"][0]),
                        sampled=self._slot_sampled[slot], nbytes=nbytes)
        if not self.host_pool.put(rid, h, nbytes):
            return state, False
        # release only the exclusive pages; the handle keeps the slot's
        # reference on the shared remainder (pinning it against eviction)
        self.allocator.free([pages[i] for i in host_idx])
        self._slot_pages[slot] = []
        self._slot_sampled[slot] = False
        self.swap_last_bytes = nbytes
        return self._paged_free(state, jnp.asarray(slot, jnp.int32)), True

    def has_swap(self, rid) -> bool:
        """Whether a host snapshot is parked under ``rid``."""
        return self.swap_enabled and rid in self.host_pool

    def can_swap_in(self, rid, prompt_len: Optional[int] = None,
                    max_new: Optional[int] = None,
                    full: bool = False) -> bool:
        """Admission gate for a swapped resume, priced at its DEVICE-page
        need only: the handle's host pages want fresh device pages; its
        resident pages are already on device. ``full`` (the scheduler's
        anti-thrash re-admission gate) additionally covers the remaining
        lifetime growth beyond what the restore maps, mirroring
        ``can_admit(full=True)`` for recompute resumes."""
        h = self.host_pool.get(rid) if self.swap_enabled else None
        if h is None:
            return False
        need = len(h.host_idx)
        if full and prompt_len is not None:
            need += max(0, self.pages_needed(prompt_len, max_new)
                        - len(h.page_row))
        avail = self.allocator.n_free
        if self.prefix_cache is not None:
            avail += self.prefix_cache.evictable(self.allocator, h.page_row)
        return need <= avail

    def swap_in_slot(self, state: dict, slot: int, rid):
        """Resume a swapped-out request into (empty) ``slot``: allocate
        fresh device pages for the host spans, rebuild the full-width
        batch-1 view around the host payload, and scatter it back with the
        still-resident pages masked out of the write. Returns ``(state,
        last)`` — the restored committed position; the slot then holds
        BITWISE the state it had at eviction (device→host→device
        round-trips preserve bytes, and resident pages were never
        touched). Callers gate on ``can_swap_in``."""
        h = self.host_pool.get(rid) if self.swap_enabled else None
        if h is None:
            raise KeyError(f"no swap handle for request {rid!r}")
        if self._slot_pages[slot]:
            raise RuntimeError(f"slot {slot} still holds pages; "
                               "free_slot it before swap-in")
        fresh = self._alloc_pages(len(h.host_idx)) if h.host_idx else []
        if fresh is None:
            raise RuntimeError(
                f"page pool exhausted ({len(h.host_idx)} needed, "
                f"{self.allocator.n_free} free); gate on can_swap_in")
        pages = list(h.page_row)
        scat = np.full((self.pages_per_slot,), -1, np.int32)
        for i, p in zip(h.host_idx, fresh):
            pages[i] = p
            scat[i] = p
        row = np.full((self.pages_per_slot,), -1, np.int32)
        row[:len(pages)] = pages
        src = self._swap_src(h)
        state = self._swap_scatter(state, src, jnp.asarray(slot, jnp.int32),
                                   jnp.asarray(row), jnp.asarray(scat))
        self._slot_pages[slot] = pages
        self._slot_sampled[slot] = h.sampled
        self.host_pool.pop(rid)
        self.swap_last_bytes = h.nbytes
        return state, h.last

    def _swap_src(self, h: _SwapHandle) -> dict:
        """Full-width batch-1 state around the handle's payload: per-slot
        rows verbatim, paged views zero-filled except the host spans
        (swap-in's scatter mask drops everything else, so the fill value
        is never read), leaves write_slot ignores zero-filled for shape."""
        ps = self.ecfg.page_size
        span = (self._host_span(h.host_idx, ps) if h.host_idx else None)

        def build(t, s, ax, tag):
            if tag != cache_ops.NOT_PAGED:
                full = np.zeros(t.shape, t.dtype)
                if span is not None:
                    sl = [slice(None)] * len(t.shape)
                    sl[cache_ops.view_width_axis(len(t.shape), tag)] = span
                    full[tuple(sl)] = s
                return full
            if ax < 0:
                return np.zeros(t.shape, t.dtype)
            return s

        return jax.tree.map(build, self._b1_template(), h.snap,
                            self.paged_axes, self.pspec)

    def drop_swap(self, rid) -> bool:
        """Release ``rid``'s host snapshot without resuming it: frees the
        host-pool bytes immediately and drops the handle's reference on
        its resident pages (abort of a swapped request, or the scheduler
        falling a swapped resume back to recompute-prefill). False when
        nothing was parked."""
        if not self.has_swap(rid):
            return False
        h = self.host_pool.pop(rid)
        on_host = set(h.host_idx)
        resident = [p for i, p in enumerate(h.page_row) if i not in on_host]
        if resident:
            self.allocator.free(resident)
        return True

    def reset_stats(self) -> None:
        """Restart the allocator's and host pool's ``peak_used`` high-water
        marks at current usage — multi-phase benchmarks (tables 13/19)
        call this between warm-up and measured passes so each phase
        reports its own honest peak."""
        if self.paged:
            self.allocator.reset_stats()
        if self.host_pool is not None:
            self.host_pool.reset_stats()

    def _mixed_policy(self) -> bool:
        """Whether the next step needs the sampled verification lane: any
        admitted slot carries a sampled policy, or the engine default is
        sampled (whole-batch prefill states fill every row with it). False
        selects the greedy-only trace — same tokens, pre-redesign cost."""
        return any(self._slot_sampled) or not self.ecfg.sampling.is_greedy

    def step(self, state: dict, active: Optional[Array] = None,
             max_new: Optional[Array] = None,
             k_row: Optional[Array] = None) -> dict:
        """One jitted speculative iteration. Without arguments this is the
        legacy whole-batch step; the scheduler passes ``active`` (B,) bool and
        per-slot ``max_new`` (B,) int32. The paged layout always routes
        through the gather→step→scatter wrapper. Host-side, the engine picks
        the mixed-policy or greedy-only trace of the step (``_mixed_policy``;
        output-identical, the greedy twin just skips the sampled lane's
        warps and draws).

        ``k_row`` (B,) int32 is the adaptive-speculation max-K mask: each
        row's effective draft length this iteration, in ``[0, K]``. It is a
        TRACED argument of the same jitted step — varying it never
        recompiles — and ``None`` (= full K everywhere) is bitwise
        identical to the pre-adaptive step."""
        g = not self._mixed_policy()              # twin key: greedy_only
        B = state["tokens"].shape[0]
        if self.paged:
            if "block_table" not in state:
                raise ValueError(
                    "paged Engine.step needs a paged state (blank_state + "
                    "prefill_into_slot); whole-batch prefill states are "
                    "contiguous-only — use a kv_layout='contiguous' engine "
                    "for whole-batch loops like serve_round_based")
            if active is None:
                active = jnp.ones((B,), bool)
            if max_new is None:
                max_new = jnp.full((B,), self.ecfg.max_new_tokens, jnp.int32)
            if k_row is None:
                k_row = jnp.full((B,), self.ecfg.K, jnp.int32)
            return self._paged_step[g](self.tparams, self.dparams, state,
                                       jnp.asarray(active),
                                       jnp.asarray(max_new, jnp.int32),
                                       jnp.asarray(k_row, jnp.int32))
        if active is None and max_new is None and k_row is None:
            return self._step[g](self.tparams, self.dparams, state)
        if active is None:
            active = jnp.ones((B,), bool)
        if max_new is None:
            max_new = jnp.full((B,), self.ecfg.max_new_tokens, jnp.int32)
        if k_row is None:
            k_row = jnp.full((B,), self.ecfg.K, jnp.int32)
        return self._sched_step[g](self.tparams, self.dparams, state,
                                   jnp.asarray(active),
                                   jnp.asarray(max_new, jnp.int32),
                                   jnp.asarray(k_row, jnp.int32))

    def _sched_step_impl(self, tparams, dparams, state, active, max_new,
                         k_row, greedy_only=False):
        tparams, dparams = self._rep(tparams), self._rep(dparams)
        out = speculative_step(self.model, self.tcfg, self.dcfg, self.ecfg,
                               tparams, dparams, self._rep(state),
                               active_mask=active, max_new=max_new,
                               k_row=k_row, greedy_only=greedy_only)
        return self._rep(out)

    def _paged_step_impl(self, tparams, dparams, state, active, max_new,
                         k_row, greedy_only=False):
        """Paged twin of _sched_step_impl: reassemble each slot's pages into
        the contiguous per-slot view the step consumes (cache_ops.gather),
        run the identical speculative iteration, scatter the updated view
        back through the block table. All inside one jit, so rollback
        invalidation and snapshot commit are bit-identical across layouts —
        the cross-layout equivalence tests pin this.

        Under shard_model the gathered view (and the weights) cross the
        replication boundary before the step — the all-gather of each
        slot's pages — and the stepped view is pinned replicated again
        before ``scatter_state`` writes it back into the sharded pools, so
        the speculative iteration itself computes with single-device
        shapes (the losslessness invariant) while pools stay sharded at
        rest across the host round-trip."""
        tparams, dparams = self._rep(tparams), self._rep(dparams)
        table = state["block_table"]
        core = {k: v for k, v in state.items() if k != "block_table"}
        view = self._rep(cache_ops.gather_state(core, table, self.pspec))
        view = speculative_step(self.model, self.tcfg, self.dcfg, self.ecfg,
                                tparams, dparams, view,
                                active_mask=active, max_new=max_new,
                                k_row=k_row, greedy_only=greedy_only)
        view = self._rep(view)
        core = cache_ops.scatter_state(core, view, table, self.pspec)
        core["block_table"] = table
        return core

    # ------------------------------------------------------------------
    # loops & metrics
    # ------------------------------------------------------------------
    def run(self, prompts: Array, extras: Optional[dict] = None,
            max_iters: int = 10_000) -> Dict[str, Any]:
        if self.paged:
            raise ValueError(
                "Engine.run is the whole-batch contiguous loop; drive a "
                "paged engine through serving.Scheduler (per-slot admission "
                "is what allocates pages)")
        t0 = time.perf_counter()
        state = self.prefill(prompts, extras)
        jax.block_until_ready(state["tokens"])
        t_prefill = time.perf_counter() - t0

        iters = 0
        g = self.ecfg.sampling.is_greedy        # whole-batch default policy
        t0 = time.perf_counter()
        while iters < max_iters:
            state = self._step[g](self.tparams, self.dparams, state)
            iters += 1
            if iters % 8 == 0 or iters < 2:
                if bool(np.all(np.asarray(state["new_count"])
                               >= self.ecfg.max_new_tokens)):
                    break
        jax.block_until_ready(state["tokens"])
        t_decode = time.perf_counter() - t0

        new_tok = int(np.sum(np.asarray(state["new_count"])))
        it = max(int(state["iters"]), 1)
        row_iters = max(int(state["row_iters"]), 1)
        return {
            "state": state,
            "tokens": np.asarray(state["tokens"]),
            "new_tokens": new_tok,
            "iterations": it,
            "acceptance_length": int(state["committed"]) / row_iters,
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "otps": new_tok / max(t_decode, 1e-9),
        }


def _token_logprob(logits, tok):
    """log p(tok) under the raw softmax of ``logits`` at each position.

    This is the engine's per-token logprob convention (see
    make_decode_state): the RAW target distribution — what verification
    scores against — not the warped sampling distribution, so greedy and
    sampled rows report comparable values and the number is independent of
    the request's temperature/top-k/top-p knobs. Broadcasts over leading
    axes: (B, V) + (B,) -> (B,), (B, K+1, V) + (B, K+1) -> (B, K+1)."""
    lp = jax.nn.log_softmax(logits, axis=-1)
    return jnp.take_along_axis(
        lp, tok[..., None].astype(jnp.int32), axis=-1)[..., 0]


def speculative_step(model, tcfg: ModelConfig, dcfg: Optional[DrafterConfig],
                 ecfg: EngineConfig, tparams, dparams, state,
                 active_mask: Optional[Array] = None,
                 max_new: Optional[Array] = None,
                 k_row: Optional[Array] = None,
                 greedy_only: bool = False):
    """One speculative iteration: draft K → verify K+1 → accept → commit.

    Pure function of (params, state) — shared by the Engine and by the
    dry-run's ``serve_step`` lowering (launch/steps.py).

    ``active_mask`` (B,) bool and ``max_new`` (B,) int32 are the continuous-
    batching hooks: the scheduler masks out free/finished slots and supplies
    per-request token budgets. Both default to the legacy whole-batch
    behavior (all slots live, shared ``ecfg.max_new_tokens`` budget), so
    existing callers are unchanged. A masked row commits nothing and its
    last/taps/counters are frozen; its cache rows receive only garbage that
    the next ``Engine.prefill_into_slot`` fully overwrites.

    Verification policy is per row (``state["sampling"]``, see
    serving/sampling.py): ``temperature == 0`` rows take the exact greedy
    argmax path on the raw target logits; the rest run seeded rejection
    sampling against the row-warped drafter/target distributions, with the
    row's key re-derived every step as ``fold_in(base_key, c + 1)`` — the
    position of the first token the step determines — so a row's stream
    depends only on its own ``(seed, committed prefix)``, never on batch
    composition, slot index, or an engine-global RNG.

    With ``ecfg.draft_sampling`` the sampled rows' K drafts are themselves
    DRAWN from the row-warped drafter distribution (keys: a DRAFT_SALT-
    separated fold_in stream at the same position counter — sampling.py)
    and the rejection proposal q is that distribution instead of the argmax
    one-hot; greedy rows keep the argmax drafts bitwise.

    ``k_row`` (B,) int32 caps each row's effective draft length this
    iteration (adaptive K, ``None`` = full K): a max-K mask inside
    verification — slots past k_row are force-rejected losslessly — so the
    scheduler's controller varies speculation depth per row with zero
    retraces. The drafter still emits K slots; the cap costs nothing and
    changes nothing when ``k_row == K``.

    ``greedy_only`` (STATIC) traces the verification without the sampled
    lane at all — no warping, no categorical draws — restoring the
    pre-SamplingParams per-step cost. The Engine selects this trace
    host-side whenever no admitted request is sampled; it is output-
    identical to the mixed trace for all-greedy rows (the mixed trace's
    greedy lane is the same argmax on the same raw logits)."""
    B = state["tokens"].shape[0]
    K = ecfg.K if ecfg.drafter_mode != "none" else 0
    c = state["last"]
    tok_next = jnp.take_along_axis(state["tokens"], c[:, None], axis=1)[:, 0]
    samp = state["sampling"]

    # warped-proposal draft policy: only the mixed trace draws (the greedy
    # twin is selected precisely when no admitted row samples)
    policy = None
    if ecfg.draft_sampling and not greedy_only and K > 0:
        policy = (draft_keys(samp, c + 1, K), samp["temperature"],
                  samp["top_k"], samp["top_p"])

    if ecfg.drafter_mode == "parallel":
        drafts, dlogits, dcache = D.draft_parallel(
            dcfg, tcfg, dparams, state["dcache"], tok_next,
            state["taps_last"], c - 1, K, policy=policy)
    elif ecfg.drafter_mode == "ar":
        drafts, dlogits, dcache = D.draft_ar(
            dcfg, tcfg, dparams, state["dcache"], tok_next,
            state["taps_last"], c - 1, K, policy=policy)
    else:
        drafts = jnp.zeros((B, 0), jnp.int32)
        dlogits, dcache = None, None

    # target verify over [t_last, d_1..d_K] at positions c..c+K
    vt = jnp.concatenate([tok_next[:, None], drafts], axis=1)
    positions = c[:, None] + jnp.arange(K + 1, dtype=jnp.int32)[None]
    tout = model.forward(tparams, vt, mode="decode",
                              positions=positions, cache=state["tcache"],
                              collect_taps=ecfg.drafter_mode != "none")

    if K == 0:
        accept_len = jnp.zeros((B,), jnp.int32)
        if greedy_only:
            t_star = jnp.argmax(tout.logits, axis=-1).astype(jnp.int32)
        else:
            t_star = SD.sample_token(step_keys(samp, c + 1),
                                     tout.logits[:, 0], samp["temperature"],
                                     samp["top_k"], samp["top_p"])[:, None]
    elif greedy_only:
        accept_len, t_star = SD.greedy_verify(drafts, tout.logits)
        if k_row is not None:
            # clip the matched prefix at the row's draft budget — the
            # correction token t_star[accept_len] is the target argmax at
            # that position, so the stream content is unchanged
            accept_len = jnp.minimum(accept_len, k_row)
    else:
        if policy is not None:
            # sampled rows drew their drafts from the row-warped drafter
            # distribution — the proposal q MUST be that same distribution
            # for rejection sampling to stay lossless. Greedy rows keep
            # the one-hot of their argmax drafts (their sampled-lane
            # output is discarded by mixed_verify's where-select anyway).
            q = jnp.where((samp["temperature"] > 0)[:, None, None],
                          SD.warp_probs(dlogits, samp["temperature"],
                                        samp["top_k"], samp["top_p"]),
                          jax.nn.one_hot(drafts, tout.logits.shape[-1],
                                         dtype=tout.logits.dtype))
        else:
            # drafts are the drafter's argmax — a DETERMINISTIC proposal,
            # so the distribution they were drawn from is a one-hot, and
            # lossless rejection reduces to accept-with-p(d) / residual
            # p-masked-at-d (passing the drafter softmax here would
            # over-accept the drafter's argmax and bias the committed
            # distribution)
            q = jax.nn.one_hot(drafts, tout.logits.shape[-1],
                               dtype=tout.logits.dtype)
        accept_len, t_star = SD.mixed_verify(
            step_keys(samp, c + 1), drafts, q, tout.logits,
            samp["temperature"], samp["top_k"], samp["top_p"], k_row)

    budget = jnp.asarray(ecfg.max_new_tokens, jnp.int32) \
        if max_new is None else max_new
    active = state["new_count"] < budget
    if active_mask is not None:
        active &= active_mask
    accept_len = jnp.where(active, accept_len, 0)

    # commit target cache (invalidate stale attention slots / select
    # recurrent snapshots at the last accepted token)
    tcache = cache_ops.commit(tout.cache, tout.aux.get("snapshots"),
                              c + accept_len, accept_len)

    # append committed tokens t_star[0..accept_len]
    idx = c[:, None] + 1 + jnp.arange(K + 1, dtype=jnp.int32)[None]
    keep = jnp.arange(K + 1)[None] <= accept_len[:, None]
    keep &= active[:, None]
    safe_idx = jnp.where(keep, idx, state["tokens"].shape[1])
    tokens = jax.vmap(lambda t, i, v: t.at[i].set(v, mode="drop"))(
        state["tokens"], safe_idx, t_star)
    # committed-token logprobs ride the same scatter: tout.logits[:, j] is
    # the raw target distribution at position c+j, which determined the
    # token committed at c+1+j — exactly the pairing _token_logprob scores
    logprobs = jax.vmap(lambda t, i, v: t.at[i].set(v, mode="drop"))(
        state["logprobs"], safe_idx, _token_logprob(tout.logits, t_star))

    new_last = jnp.where(active, c + accept_len + 1, c)
    taps_last = state["taps_last"]
    if ecfg.drafter_mode != "none":
        taps_new = jnp.take_along_axis(
            tout.taps, accept_len[:, None, None], axis=1)[:, 0]
        taps_last = jnp.where(active[:, None], taps_new, taps_last)
        # extend drafter cache across the verified block (stale tail is
        # auto-invalidated by the next positional write)
        dcache = D.extend(dcfg, tcfg, dparams, dcache, t_star, tout.taps,
                          positions)

    ncommit = jnp.where(active, accept_len + 1, 0)
    new_state = dict(
        tokens=tokens,
        logprobs=logprobs,
        last=new_last,
        taps_last=taps_last,
        tcache=tcache,
        new_count=state["new_count"] + ncommit,
        slot_iters=state["slot_iters"] + active.astype(jnp.int32),
        iters=state["iters"] + jnp.any(active).astype(jnp.int32),
        row_iters=state["row_iters"] + jnp.sum(active.astype(jnp.int32)),
        committed=state["committed"] + jnp.sum(ncommit),
        sampling=samp,
    )
    if ecfg.drafter_mode != "none":
        new_state["dcache"] = dcache
    return new_state

