"""Wall-clock streaming front-end over the Scheduler's shared loop core.

``serving/scheduler.py`` owns the step/admit/preempt/harvest machinery and
drives it two ways: the deterministic virtual-clock ``Scheduler.serve``
(batch, replayable, what every losslessness/churn test pins) and THIS
module's :class:`AsyncEngine` — the same core methods, paced by real time
and asyncio, streaming each request's ``(token, logprob)`` pairs out as
speculative syncs commit::

                 ┌──────────────── shared loop core ────────────────┐
                 │  _admit_waiting → _grow → _dispatch → _harvest   │
                 └───────▲──────────────────────────────▲───────────┘
          virtual clock  │                              │  wall clock
      Scheduler.serve()  │                              │  AsyncEngine._run()
      (deterministic twin; batch report)     (asyncio; streams the emit
                                              buffer, accepts abort())

Because every request's token stream is a pure function of its own
``(prompt, SamplingParams)`` — row independence through attention/caches,
per-request ``fold_in(seed, position)`` keys — a streamed run yields
token-for-token exactly what the virtual-clock twin produces for the same
workload, regardless of arrival timing, batch composition, preemptions, or
aborts of OTHER requests (tests/test_streaming.py pins this).

Streaming semantics:

- ``generate()`` yields only FINAL tokens: the emit buffer is filled after
  the incremental stop/budget trim (``_clip_and_check_done``), so nothing
  past a stop token or budget is ever yielded, and a yielded token is
  never retracted.
- ``abort()`` (or closing a ``generate()`` iterator early) cancels a
  request immediately: a queued request leaves the wait queue; a running
  one's pages return to the pool via the ordinary ``free_slot`` path
  before the next sync, so the slot is reusable at once. Aborting a
  swapped-out request (``EngineConfig(swap="host")``) additionally frees
  its host bytes right away — the HostPagePool never holds state for a
  dead request.
- Backpressure: at most ``max_pending`` requests may be in flight
  (queued + running); ``submit()``/``generate()`` await a free admission
  ticket. ``health()`` reports queue depth, running slots, pool occupancy
  and wait percentiles for monitoring.

The process-separated NDJSON socket front-end lives in
``launch/serve_stream.py``; this class is the in-process API it wraps.
"""
from __future__ import annotations

import asyncio
import bisect
import time
from typing import Any, AsyncIterator, Dict, Optional, Tuple

import numpy as np

from repro.serving.engine import Engine
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import (ABORTED, FINISHED, Request, Scheduler)


class StreamHandle:
    """One in-flight streamed request: an async iterator of
    ``(token, logprob)`` pairs plus ``abort()``. Obtained from
    :meth:`AsyncEngine.submit`; :meth:`AsyncEngine.generate` wraps one."""

    def __init__(self, engine: "AsyncEngine", request: Request,
                 queue: "asyncio.Queue"):
        self._engine = engine
        self.request = request
        self._queue = queue
        self._exhausted = False

    @property
    def rid(self) -> int:
        return self.request.rid

    @property
    def done(self) -> bool:
        """Finished or aborted — no further tokens will arrive."""
        return self.request.status in (FINISHED, ABORTED)

    @property
    def aborted(self) -> bool:
        return self.request.status == ABORTED

    def abort(self) -> bool:
        """Cancel this request (idempotent); see AsyncEngine.abort."""
        return self._engine.abort(self)

    def __aiter__(self) -> "StreamHandle":
        return self

    async def __anext__(self) -> Tuple[int, float]:
        if self._exhausted:
            raise StopAsyncIteration
        item = await self._queue.get()
        if item is None:                      # finish/abort sentinel
            self._exhausted = True
            raise StopAsyncIteration
        if isinstance(item, BaseException):   # dispatch loop died
            self._exhausted = True
            raise item
        return item


class AsyncEngine:
    """Wall-clock asyncio serving engine over one :class:`Engine`.

    Owns a private :class:`Scheduler` session driven by a background
    dispatch task; everything — admissions, speculative steps, harvests,
    aborts — runs on the one event loop, so core state never needs locks
    (client-facing calls only touch it at the loop's await boundaries).

    Args:
      engine: the (typically paged) serving Engine. Exclusive: don't drive
        the same Engine from ``Scheduler.serve`` while a session is open.
      eos_id / sync_every / preempt / free_on_finish / adaptive_k:
        forwarded to the underlying Scheduler (same semantics as the
        batch driver; ``adaptive_k`` enables the per-request dynamic-K
        speculation controller, serving/speculation.py).
      max_pending: admission-ticket bound — submitted-but-unfinished
        requests beyond this block in ``submit()`` until something
        finishes or aborts (default ``4 * engine.batch``).

    Quickstart::

        aeng = AsyncEngine(engine, eos_id=2)
        async for tok, lp in aeng.generate(prompt,
                                           SamplingParams(temperature=0.8,
                                                          seed=7)):
            ...                        # arrives as each sync commits
        aeng.health()["queue_depth"]
        report = await aeng.close()    # Scheduler-style aggregate report
    """

    def __init__(self, engine: Engine, eos_id: Optional[int] = None,
                 sync_every: int = 1, preempt: Optional[bool] = None,
                 free_on_finish: bool = True,
                 max_pending: Optional[int] = None,
                 adaptive_k: Any = None):
        self.engine = engine
        self.scheduler = Scheduler(engine, eos_id=eos_id,
                                   free_on_finish=free_on_finish,
                                   sync_every=sync_every, preempt=preempt,
                                   adaptive_k=adaptive_k)
        self.max_pending = (int(max_pending) if max_pending
                            else 4 * engine.batch)
        if self.max_pending < 1:
            raise ValueError("max_pending must be >= 1")
        self._queues: Dict[int, asyncio.Queue] = {}
        self._done: set = set()          # rids whose sentinel was delivered
        self._inflight = 0
        self._n_fin = 0                  # _finished entries already delivered
        self._task: Optional[asyncio.Task] = None
        self._sem: Optional[asyncio.Semaphore] = None
        self._wake: Optional[asyncio.Event] = None
        self._closing = False
        self._error: Optional[BaseException] = None
        self._report: Optional[Dict[str, Any]] = None

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    async def start(self) -> None:
        """Open the serving session and start the dispatch loop (idempotent;
        ``submit`` calls it lazily)."""
        if self._task is not None:
            return
        sched = self.scheduler
        sched._begin_session()
        # wall-clock mode: _advance re-reads elapsed real time, so the
        # session's *_vt columns and event stamps are wall seconds
        sched._wall_t0 = sched._t_start
        self._sem = asyncio.Semaphore(self.max_pending)
        self._wake = asyncio.Event()
        self._task = asyncio.get_running_loop().create_task(self._run())

    async def close(self, drain: bool = True) -> Dict[str, Any]:
        """Shut the session down and return the Scheduler-style aggregate
        report. ``drain=True`` first waits for every in-flight request;
        ``drain=False`` aborts them."""
        if self._task is None:
            await self.start()           # trivial empty session
        if not drain:
            for req in list(self.scheduler._waiting):
                self.abort(req)
            for req in list(self.scheduler._slot_req):
                if req is not None:
                    self.abort(req)
        self._closing = True
        self._wake.set()
        await self._task
        if self._report is None:
            sched = self.scheduler
            self._report = sched._end_session(
                time.perf_counter() - sched._t_start)
        if self._error is not None:
            raise self._error
        return self._report

    # ------------------------------------------------------------------
    # client API
    # ------------------------------------------------------------------
    async def submit(self, prompt, sampling_params: Optional[SamplingParams]
                     = None, max_new_tokens: Optional[int] = None,
                     extras: Optional[dict] = None) -> StreamHandle:
        """Admission-gated submit: awaits a backpressure ticket, then
        enqueues the request and returns its :class:`StreamHandle`.
        Raises ValueError (before consuming a ticket slot) for requests
        that could never be served (budget exceeds max_len / pool)."""
        await self.start()
        if self._error is not None:
            raise self._error
        if self._closing:
            raise RuntimeError("AsyncEngine is closing")
        await self._sem.acquire()
        sched = self.scheduler
        try:
            if self._error is not None:
                raise self._error
            sched._advance(0.0)          # refresh the wall clock
            req = Request(prompt, max_new_tokens=max_new_tokens,
                          arrival_time=sched._clock, extras=extras,
                          sampling=sampling_params)
            sched._prepare(req)          # ValueError → ticket returned
        except BaseException:
            self._sem.release()
            raise
        self._inflight += 1
        q: asyncio.Queue = asyncio.Queue()
        self._queues[req.rid] = q
        bisect.insort(sched._waiting, req, key=sched._prio)
        sched._event("arrive", req.rid)
        self._wake.set()
        return StreamHandle(self, req, q)

    async def generate(self, prompt,
                       sampling_params: Optional[SamplingParams] = None,
                       max_new_tokens: Optional[int] = None,
                       extras: Optional[dict] = None
                       ) -> AsyncIterator[Tuple[int, float]]:
        """Stream one completion: yields ``(token, logprob)`` as each
        speculative sync commits (stop/budget-trimmed — never a token past
        the stop). Closing the iterator early aborts the request, freeing
        its slot immediately."""
        handle = await self.submit(prompt, sampling_params, max_new_tokens,
                                   extras)
        try:
            async for tok, lp in handle:
                yield tok, lp
        finally:
            if not handle.done:
                self.abort(handle)

    def abort(self, handle) -> bool:
        """Cancel a request (StreamHandle or Request) right now. Pages are
        freed through the ordinary free_slot path, so the slot is
        admissible again on the very next loop pass; tokens already
        streamed remain valid. Returns False when the request had already
        finished. Safe to call from any coroutine on the engine's loop —
        the dispatch loop only runs core mutations between awaits."""
        req = handle.request if isinstance(handle, StreamHandle) else handle
        sched = self.scheduler
        if self._task is None:
            return False
        sched._advance(0.0)
        if not sched._abort(req):
            return False
        self._deliver()                  # sentinel + ticket release
        self._wake.set()
        return True

    def health(self) -> Dict[str, Any]:
        """Monitoring snapshot of the live session (cheap, host-side)."""
        sched, eng = self.scheduler, self.engine
        if self._task is None:
            raise RuntimeError("AsyncEngine not started")
        completed = [r for r in sched._finished if r.status == FINISHED]
        # wait list and filter use the SAME clock: the list reads the wall
        # stamps (t_admit - t_submit), so never-admitted requests are
        # screened by the wall stamp too (t_admit == 0.0 means the request
        # finished/aborted without ever being admitted — mixing in the
        # virtual vt_admit here would conflate the two clocks PR 7 split)
        waits = sorted(r.t_admit - r.t_submit for r in completed
                       if r.t_admit > 0.0)

        def pct(p: float) -> float:
            # guarded on the DATA, not on the callable: zero completed
            # requests yield zeroed percentiles, never an IndexError
            if not waits:
                return 0.0
            return waits[min(int(p / 100 * len(waits)), len(waits) - 1)]

        pool_total = eng.pool_pages if eng.paged else 0
        pool_free = eng.allocator.n_free if eng.paged else 0
        hp = eng.host_pool               # None unless swap="host"
        return {
            "queue_depth": len(sched._waiting),
            "running": int(sched._active.sum()),
            "slots": eng.batch,
            "inflight": self._inflight,
            "max_pending": self.max_pending,
            "pool_pages": pool_total,
            "pool_free": pool_free,
            "pool_occupancy": (1.0 - pool_free / pool_total
                               if pool_total else 0.0),
            # host swap pool (all zeros unless EngineConfig(swap="host");
            # `is not None` because an empty HostPagePool is falsy)
            "swapped": len(hp) if hp is not None else 0,
            "host_pool_bytes": hp.capacity if hp is not None else 0,
            "host_pool_used_bytes": hp.used_bytes if hp is not None else 0,
            "host_pool_peak_bytes": hp.peak_used if hp is not None else 0,
            "host_pool_occupancy": (hp.used_bytes / hp.capacity
                                    if hp is not None and hp.capacity
                                    else 0.0),
            "finished": len(completed),
            "aborted": len(sched._finished) - len(completed),
            "preemptions": sched._n_preempt,
            "p50_wait_s": pct(50),
            "p99_wait_s": pct(99),
            "uptime_s": time.perf_counter() - sched._t_start,
        }

    # ------------------------------------------------------------------
    # dispatch loop
    # ------------------------------------------------------------------
    def _deliver(self) -> None:
        """Drain the core's emit buffer into per-request queues and send
        finish sentinels (+ release backpressure tickets) for newly
        finished/aborted requests."""
        sched = self.scheduler
        for req, toks, lps in sched._emit:
            q = self._queues.get(req.rid)
            if q is not None:
                for pair in zip(toks, lps):
                    q.put_nowait(pair)
        sched._emit.clear()
        while self._n_fin < len(sched._finished):
            req = sched._finished[self._n_fin]
            self._n_fin += 1
            if req.rid in self._done:
                continue
            self._done.add(req.rid)
            q = self._queues.pop(req.rid, None)
            if q is not None:
                q.put_nowait(None)
            self._inflight -= 1
            self._sem.release()

    def _fail(self, err: BaseException) -> None:
        """Dispatch loop died: surface the error on every open stream and
        on future submits, and unblock backpressure waiters."""
        self._error = err
        for rid, q in list(self._queues.items()):
            if rid not in self._done:
                self._done.add(rid)
                q.put_nowait(err)
                self._inflight -= 1
                self._sem.release()
        self._queues.clear()

    async def _run(self) -> None:
        """The wall-clock driver of the shared loop core: admit → grow →
        dispatch → harvest, yielding to clients between syncs, parking on
        the wake event when idle."""
        sched = self.scheduler
        try:
            while True:
                sched._advance(0.0)
                if not sched._waiting and not sched._active.any():
                    if self._closing:
                        break
                    self._wake.clear()
                    await self._wake.wait()
                    continue
                sched._admit_waiting()
                self._deliver()          # EOS-at-prefill finishes
                if not sched._active.any():
                    if sched._waiting:
                        raise RuntimeError(
                            "no active slot and the head request cannot "
                            "be admitted — page pool leak?")
                    continue
                run = sched._grow()
                sched._dispatch(run)     # blocking jax compute
                sched._harvest()
                self._deliver()
                # hand the loop to submitters/consumers between syncs —
                # this is the only point client coroutines mutate core
                # state (submit/abort), so the sync above sees a stable
                # view without locks
                await asyncio.sleep(0)
        except BaseException as e:       # noqa: BLE001 — surfaced to clients
            self._fail(e)
        finally:
            sched._advance(0.0)
            self._report = sched._end_session(
                time.perf_counter() - sched._t_start)


def virtual_twin_report(engine: Engine, workload, eos_id: Optional[int]
                        = None, **scheduler_kwargs) -> Dict[str, Any]:
    """Run ``workload`` — a list of (prompt, SamplingParams|None,
    max_new_tokens|None) tuples — through the deterministic virtual-clock
    driver, returning its report. The reference the streaming tests and
    benchmark compare token streams against."""
    reqs = [Request(np.asarray(p, np.int32), sampling=sp,
                    max_new_tokens=mnt) for p, sp, mnt in workload]
    sched = Scheduler(engine, eos_id=eos_id, **scheduler_kwargs)
    rep = sched.serve(reqs)
    order = {r.rid: i for i, r in enumerate(reqs)}
    rep["results"] = sorted(rep["results"], key=lambda r: order[r["rid"]])
    return rep
