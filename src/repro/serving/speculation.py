"""Adaptive speculation controller: per-request dynamic draft length.

The engine speculates ``EngineConfig.K`` tokens per iteration for every
row. That is the right depth for "easy" streams (high drafter/target
agreement) and pure waste for "hard" ones — each unaccepted draft slot
costs a target verify position, and under the paged layout it also costs
page headroom (``_grow`` reserves ``sync_every * (k + 1)`` positions per
growth quantum), which on a tight pool turns into preemptions.

:class:`SpeculationController` closes that loop host-side. It is keyed by
REQUEST id, not slot: the per-request acceptance state survives
preemption/resume exactly like the request's token stream does, and every
update is derived only from the request's own committed-token deltas — so
the ``k_row`` sequence a request sees is a pure function of its own
stream, never of batch composition, slot index, layout, or mesh. That is
what keeps the streamed ≡ virtual-twin and composition-invariance pins
intact with the controller enabled (tests/test_speculation.py).

The decision is applied as a max-K mask: ``k_row`` (B,) int32 is a traced
argument of the one jitted step (``Engine.step(..., k_row=...)``), so
varying depth per row per iteration never recompiles. Slots at or beyond
``k_row`` are force-rejected inside verification with the proposal mass
zeroed there — lossless by construction (core/spec_decode.py).

Controller state machine, per request:

1. admission  → ``k_row = k_for(rid)``; a fresh rid starts OPTIMISTIC
   (``ema = K + 1`` ⇒ full-depth speculation) so easy streams never pay a
   ramp-up and the first harvest already measures true acceptance.
2. harvest    → ``observe(rid, d_tok, d_it)`` folds the delta
   (``d_tok`` committed tokens over ``d_it`` iterations) into the running
   aggregate (:func:`repro.core.spec_decode.update_acceptance_stats`,
   with the active mask and iteration weights — the controller is a
   caller of the shared machinery, not a fork of it) and into an
   n-step-decayed EMA; the slot's ``k_row`` is refreshed from the EMA.
   Zero-iteration deltas (idle/frozen slots) are skipped entirely.
3. preemption → state is simply kept (rid-keyed); the resume admission
   re-reads ``k_for(rid)`` and continues where the stream left off.
4. finish     → ``finish(rid)`` freezes the final stats for telemetry
   and releases the live entry.

The policy itself is deliberately boring: speculate one slot past the
EMA's accepted-draft estimate, clipped to ``[k_min, K]``. Boring is a
feature — a monotone function of a deterministic statistic is what the
reproducibility pins require.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.core import spec_decode as SD


@dataclass(frozen=True)
class SpeculationConfig:
    """Knobs of the adaptive-K controller.

    Attributes:
      k_min: floor of the per-row draft length — never speculate less
        than this (1 keeps every row speculative; 0 would degrade a row
        to vanilla AR decoding inside a drafter-mode engine).
      ema_decay: per-ITERATION decay of the acceptance-length EMA; a
        harvest delta spanning ``n`` iterations is folded with weight
        ``1 - ema_decay**n``, so the EMA's horizon is measured in engine
        iterations, not in harvest boundaries (which depend on
        ``sync_every`` and would otherwise leak pacing into the policy).
      headroom: extra draft slots granted past the EMA's accepted-draft
        estimate — the explore margin that lets a stream climb back to
        deep speculation when its acceptance recovers.
    """
    k_min: int = 1
    ema_decay: float = 0.8
    headroom: int = 1

    def __post_init__(self):
        if self.k_min < 0:
            raise ValueError(f"k_min must be >= 0, got {self.k_min!r}")
        if not 0.0 < self.ema_decay < 1.0:
            raise ValueError(
                f"ema_decay must be in (0, 1), got {self.ema_decay!r}")
        if self.headroom < 0:
            raise ValueError(
                f"headroom must be >= 0, got {self.headroom!r}")


class SpeculationController:
    """Host-side per-request dynamic-K policy (see module docstring).

    Args:
      K: the engine's static speculation depth — the ceiling of every
        ``k_row`` decision.
      cfg: policy knobs; ``None`` uses the defaults.
    """

    def __init__(self, K: int, cfg: Optional[SpeculationConfig] = None):
        self.K = int(K)
        self.cfg = cfg if cfg is not None else SpeculationConfig()
        # rid -> {"stats": running aggregate, "ema": float, "k": int}
        self._live: Dict[int, dict] = {}
        self._done: Dict[int, dict] = {}

    # -- state machine -------------------------------------------------
    def _entry(self, rid: int) -> dict:
        e = self._live.get(rid)
        if e is None:
            # optimistic init: full-depth speculation until measured
            e = {"stats": {}, "ema": float(self.K + 1), "k": self.K}
            self._live[rid] = e
        return e

    def observe(self, rid: int, d_tok: int, d_it: int) -> None:
        """Fold a harvest delta — ``d_tok`` committed tokens over ``d_it``
        engine iterations — into the request's acceptance state.

        ``d_it == 0`` deltas are skipped: an idle/frozen slot carries no
        acceptance information, and crediting it iterations is exactly the
        deflation bug ``update_acceptance_stats(active=...)`` guards
        against."""
        if d_it <= 0:
            return
        e = self._entry(rid)
        # shared aggregate machinery: accept_len = accepted DRAFTS over
        # the window, weighted as d_it iterations, explicitly active
        e["stats"] = SD.update_acceptance_stats(
            e["stats"], np.asarray([d_tok - d_it], np.int64),
            active=np.asarray([True]), iters=np.asarray([d_it], np.int64))
        al = d_tok / d_it                       # window acceptance length
        w = self.cfg.ema_decay ** d_it          # n-step decay
        e["ema"] = w * e["ema"] + (1.0 - w) * al
        e["k"] = self._decide(e["ema"])

    def _decide(self, ema: float) -> int:
        # accepted drafts per iteration = AL - 1; speculate `headroom`
        # past the (rounded) estimate, clipped into [k_min, K]
        est = int(round(ema - 1.0))
        return int(np.clip(est + self.cfg.headroom,
                           min(self.cfg.k_min, self.K), self.K))

    def k_for(self, rid: int) -> int:
        """The draft length to run ``rid`` at — admission and every
        harvest read this; a never-observed rid gets the optimistic K."""
        return self._entry(rid)["k"]

    def finish(self, rid: int) -> None:
        """Freeze ``rid``'s final state for telemetry and drop the live
        entry (abort/finish both land here; a forgotten rid is a no-op)."""
        e = self._live.pop(rid, None)
        if e is not None:
            self._done[rid] = e

    # -- telemetry -----------------------------------------------------
    def request_report(self, rid: int) -> dict:
        """Per-request telemetry: final k, EMA, and the running-aggregate
        acceptance length over every observed iteration."""
        e = self._done.get(rid) or self._live.get(rid)
        if e is None:
            return {"k_final": self.K, "ema": float(self.K + 1),
                    "observed_iters": 0, "acceptance_length": 0.0}
        stats = e["stats"]
        return {
            "k_final": e["k"],
            "ema": e["ema"],
            "observed_iters": int(stats.get("iters", 0)),
            "acceptance_length": (SD.acceptance_length(stats)
                                  if stats else 0.0),
        }

    def report(self) -> dict:
        """Controller-level telemetry for scheduler reports."""
        entries = list(self._done.values()) + list(self._live.values())
        ks = [e["k"] for e in entries]
        return {
            "requests": len(entries),
            "mean_k": float(np.mean(ks)) if ks else float(self.K),
            "min_k": int(min(ks)) if ks else self.K,
            "max_k": int(max(ks)) if ks else self.K,
        }
