"""Cache commit after speculative verification.

Attention caches roll back by *position invalidation*: any slot holding a
position beyond the last accepted token is marked empty (-1) — the next
write reuses it. Recurrent caches (SSM state, RG-LRU h, conv windows) cannot
be invalidated in place, so decode forwards emit per-token snapshots
(models/ssm.py, models/hybrid.py) and commit selects the snapshot of the
last accepted token.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array
_SNAP_LEAVES = ("state", "conv", "h")


def _path_str(path) -> str:
    parts = []
    for pe in path:
        parts.append(str(getattr(pe, "key", getattr(pe, "idx", pe))))
    return "/".join(parts)


def commit(cache, snapshots, commit_pos: Array, accept_idx: Array):
    """cache: model cache pytree; snapshots: matching pytree from
    ModelOutput.aux["snapshots"] (or None for attention-only models);
    commit_pos (B,): last valid absolute position; accept_idx (B,): index of
    the last committed token within the just-verified block."""
    snap_map = {}
    if snapshots is not None:
        flat, _ = jax.tree_util.tree_flatten_with_path(snapshots)
        snap_map = {_path_str(p): l for p, l in flat}

    def fix(path, leaf):
        ps = _path_str(path)
        name = ps.rsplit("/", 1)[-1]
        if name == "positions":
            # leaf (..., B, W); B is dim -2
            cp = commit_pos.reshape((1,) * (leaf.ndim - 2) + (-1, 1))
            return jnp.where(leaf > cp, -1, leaf)
        if name in _SNAP_LEAVES and ps in snap_map:
            snap = snap_map[ps]                    # cache leaf + extra T axis
            stacked = snap.ndim == leaf.ndim + 1
            t_axis = 2 if ps.startswith("blocks") else 1
            b_axis = t_axis - 1
            idx = accept_idx.reshape(
                (1,) * b_axis + (-1,) + (1,) * (snap.ndim - b_axis - 1))
            sel = jnp.take_along_axis(snap, idx, axis=t_axis)
            return jnp.squeeze(sel, axis=t_axis).astype(leaf.dtype)
        return leaf

    return jax.tree_util.tree_map_with_path(fix, cache)
