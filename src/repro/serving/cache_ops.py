"""Cache commit after speculative verification, and per-slot batch surgery
for continuous batching.

Attention caches roll back by *position invalidation*: any slot holding a
position beyond the last accepted token is marked empty (-1) — the next
write reuses it. Recurrent caches (SSM state, RG-LRU h, conv windows) cannot
be invalidated in place, so decode forwards emit per-token snapshots
(models/ssm.py, models/hybrid.py) and commit selects the snapshot of the
last accepted token.

Per-slot surgery (``batch_axes`` / ``write_slot`` / ``reset_slot``) is what
lets the scheduler admit a request *into a live batch*: a prompt is prefilled
as a batch-1 state, then every batched leaf's row 0 is scattered into the
victim slot of the running state. The batch axis of each leaf is inferred
structurally — by diffing abstract evaluations of the same state at two batch
sizes — so the machinery is agnostic to cache layout (stacked super-block
KV, ring buffers, recurrent snapshots, drafter caches alike).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array
_SNAP_LEAVES = ("state", "conv", "h")
NO_BATCH = -1          # batch_axes sentinel: leaf has no batch dimension


def _path_str(path) -> str:
    parts = []
    for pe in path:
        parts.append(str(getattr(pe, "key", getattr(pe, "idx", pe))))
    return "/".join(parts)


def commit(cache, snapshots, commit_pos: Array, accept_idx: Array):
    """cache: model cache pytree; snapshots: matching pytree from
    ModelOutput.aux["snapshots"] (or None for attention-only models);
    commit_pos (B,): last valid absolute position; accept_idx (B,): index of
    the last committed token within the just-verified block."""
    snap_map = {}
    if snapshots is not None:
        flat, _ = jax.tree_util.tree_flatten_with_path(snapshots)
        snap_map = {_path_str(p): l for p, l in flat}

    def fix(path, leaf):
        ps = _path_str(path)
        name = ps.rsplit("/", 1)[-1]
        if name == "positions":
            # leaf (..., B, W); B is dim -2
            cp = commit_pos.reshape((1,) * (leaf.ndim - 2) + (-1, 1))
            return jnp.where(leaf > cp, -1, leaf)
        if name in _SNAP_LEAVES and ps in snap_map:
            snap = snap_map[ps]                    # cache leaf + extra T axis
            stacked = snap.ndim == leaf.ndim + 1
            t_axis = 2 if ps.startswith("blocks") else 1
            b_axis = t_axis - 1
            idx = accept_idx.reshape(
                (1,) * b_axis + (-1,) + (1,) * (snap.ndim - b_axis - 1))
            sel = jnp.take_along_axis(snap, idx, axis=t_axis)
            return jnp.squeeze(sel, axis=t_axis).astype(leaf.dtype)
        return leaf

    return jax.tree_util.tree_map_with_path(fix, cache)


# ---------------------------------------------------------------------------
# per-slot batch surgery (continuous batching)
# ---------------------------------------------------------------------------

def batch_axes(tree_b1, tree_b2):
    """Infer each leaf's batch axis by diffing two abstract evaluations of the
    same pytree built at two different batch sizes (jax.eval_shape — no device
    work). Returns a matching pytree of ints: the first axis whose extent
    differs, or ``NO_BATCH`` for leaves without a batch dimension (scalar
    counters, rng keys, ring flags)."""
    def ax(a, b):
        for i, (x, y) in enumerate(zip(a.shape, b.shape)):
            if x != y:
                return i
        return NO_BATCH
    return jax.tree.map(ax, tree_b1, tree_b2)


def write_slot(dst, src, slot: Array, axes):
    """Scatter batch row 0 of ``src`` (a batch-1 state/cache pytree) into
    batch row ``slot`` of ``dst``. Leaves without a batch axis (``axes`` leaf
    == NO_BATCH: scalar counters, rng, ring flags) keep their dst value.
    jit-friendly: ``slot`` may be traced; ``axes`` must be static."""
    def w(d, s, ax):
        if ax < 0:
            return d
        row = jax.lax.index_in_dim(s, 0, axis=ax, keepdims=True)
        return jax.lax.dynamic_update_slice_in_dim(
            d, row.astype(d.dtype), slot, axis=ax)
    return jax.tree.map(w, dst, src, axes)


def reset_slot(tree, slot: Array, axes, fills: Optional[dict] = None):
    """Blank batch row ``slot``: cache ``positions`` leaves become -1 (empty —
    nothing to attend), every other batched leaf becomes 0. ``fills`` overrides
    the fill value by leaf name (e.g. {"new_count": max_new} to keep a freed
    slot frozen under the Engine's budget check). Leaves without a batch axis
    are untouched."""
    fills = fills or {}

    def r(path, d, ax):
        if ax < 0:
            return d
        name = _path_str(path).rsplit("/", 1)[-1]
        fill = fills.get(name, -1 if name == "positions" else 0)
        shape = list(d.shape)
        shape[ax] = 1
        row = jnp.full(shape, fill, d.dtype)
        return jax.lax.dynamic_update_slice_in_dim(d, row, slot, axis=ax)

    return jax.tree_util.tree_map_with_path(r, tree, axes)
