"""Cache commit after speculative verification, and per-slot batch surgery
for continuous batching.

Attention caches roll back by *position invalidation*: any slot holding a
position beyond the last accepted token is marked empty (-1) — the next
write reuses it. Recurrent caches (SSM state, RG-LRU h, conv windows) cannot
be invalidated in place, so decode forwards emit per-token snapshots
(models/ssm.py, models/hybrid.py) and commit selects the snapshot of the
last accepted token.

Per-slot surgery (``batch_axes`` / ``write_slot`` / ``reset_slot``) is what
lets the scheduler admit a request *into a live batch*: a prompt is prefilled
as a batch-1 state, then every batched leaf's row 0 is scattered into the
victim slot of the running state. The batch axis of each leaf is inferred
structurally — by diffing abstract evaluations of the same state at two batch
sizes — so the machinery is agnostic to cache layout (stacked super-block
KV, ring buffers, recurrent snapshots, drafter caches alike).

Paged (block) KV layout
-----------------------
``paged_state`` / ``gather_state`` / ``scatter_state`` / ``admit_pages``
re-express every *full-length* attention KV cache (a sub-dict with
``k/v/positions/ring`` whose window equals ``max_len``) as a **shared pool of
fixed-size position pages** plus a per-slot block table:

    contiguous   k (..., B, max_len, KV, hd)
    paged        k (..., n_pool_pages, page, KV, hd)   + table (B, max_len/page)

Pages are the allocation unit (``BlockAllocator``): admission claims
``ceil(need/page)`` pages instead of a full max-length row, EOS returns them,
and a pool of fixed byte size holds as many *requests* as their actual
lengths — not their worst case — allow. Ring (sliding-window) caches and
recurrent leaves (SSM state, conv windows, RG-LRU h) are already
memory-bounded per slot and stay in per-slot rows.

The decode step runs unchanged on a *gathered view*: ``gather_state``
reassembles each slot's pages into the contiguous per-slot layout the model
forward expects (the CPU twin of the paged Pallas gather in
kernels/decode_attention.py, which reads pages through the block table
without materializing the view), and ``scatter_state`` writes the updated
view back through the table — so speculative rollback-invalidation and
recurrent snapshot commit work bit-identically across layouts.

Swap-to-host (the SWAPPED lifecycle state)
------------------------------------------
Preemption's third page state beyond allocated/free: instead of discarding
a victim's pages and re-paying the prefix as a recompute-prefill, the
engine snapshots the slot with ``extract_slot`` (per-slot rows + gathered
page payloads in one jit), trims the copy host-side to the refcount==1
pages, and parks the bytes in a ``HostPagePool``. Pages shared with the
prefix cache (or another slot) stay *resident* — the swap handle keeps the
slot's reference, pinning them against LRU eviction — so only the
exclusive remainder moves. Swap-in re-admits the host bytes through
``admit_pages`` with a ``scatter_row`` that masks the still-resident
pages, which makes resume a pure device scatter: bitwise the state the
victim had at its eviction step boundary, for attention KV, recurrent
stream state, and sampling/logprob rows alike.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

Array = jax.Array
_SNAP_LEAVES = ("state", "conv", "h")
NO_BATCH = -1          # batch_axes sentinel: leaf has no batch dimension

# paged-spec leaf tags (structure-matched int pytree over a decode state)
NOT_PAGED = 0          # per-slot leaf: handled by write_slot/reset_slot
PAGED_KV = 1           # k/v pool leaf: pages on axis -4
PAGED_POS = 2          # positions pool leaf: pages on axis -2


def _path_str(path) -> str:
    parts = []
    for pe in path:
        parts.append(str(getattr(pe, "key", getattr(pe, "idx", pe))))
    return "/".join(parts)


def commit(cache, snapshots, commit_pos: Array, accept_idx: Array):
    """cache: model cache pytree; snapshots: matching pytree from
    ModelOutput.aux["snapshots"] (or None for attention-only models);
    commit_pos (B,): last valid absolute position; accept_idx (B,): index of
    the last committed token within the just-verified block."""
    snap_map = {}
    if snapshots is not None:
        flat, _ = jax.tree_util.tree_flatten_with_path(snapshots)
        snap_map = {_path_str(p): l for p, l in flat}

    def fix(path, leaf):
        ps = _path_str(path)
        name = ps.rsplit("/", 1)[-1]
        if name == "positions":
            # leaf (..., B, W); B is dim -2
            cp = commit_pos.reshape((1,) * (leaf.ndim - 2) + (-1, 1))
            return jnp.where(leaf > cp, -1, leaf)
        if name in _SNAP_LEAVES and ps in snap_map:
            snap = snap_map[ps]                    # cache leaf + extra T axis
            stacked = snap.ndim == leaf.ndim + 1
            t_axis = 2 if ps.startswith("blocks") else 1
            b_axis = t_axis - 1
            idx = accept_idx.reshape(
                (1,) * b_axis + (-1,) + (1,) * (snap.ndim - b_axis - 1))
            sel = jnp.take_along_axis(snap, idx, axis=t_axis)
            return jnp.squeeze(sel, axis=t_axis).astype(leaf.dtype)
        return leaf

    return jax.tree_util.tree_map_with_path(fix, cache)


# ---------------------------------------------------------------------------
# per-slot batch surgery (continuous batching)
# ---------------------------------------------------------------------------

def batch_axes(tree_b1, tree_b2):
    """Infer each leaf's batch axis by diffing two abstract evaluations of the
    same pytree built at two different batch sizes (jax.eval_shape — no device
    work). Returns a matching pytree of ints: the first axis whose extent
    differs, or ``NO_BATCH`` for leaves without a batch dimension (scalar
    counters, ring flags)."""
    def ax(a, b):
        for i, (x, y) in enumerate(zip(a.shape, b.shape)):
            if x != y:
                return i
        return NO_BATCH
    return jax.tree.map(ax, tree_b1, tree_b2)


def write_slot(dst, src, slot: Array, axes):
    """Scatter batch row 0 of ``src`` (a batch-1 state/cache pytree) into
    batch row ``slot`` of ``dst``. Leaves without a batch axis (``axes`` leaf
    == NO_BATCH: scalar counters, ring flags) keep their dst value.
    jit-friendly: ``slot`` may be traced; ``axes`` must be static."""
    def w(d, s, ax):
        if ax < 0:
            return d
        row = jax.lax.index_in_dim(s, 0, axis=ax, keepdims=True)
        return jax.lax.dynamic_update_slice_in_dim(
            d, row.astype(d.dtype), slot, axis=ax)
    return jax.tree.map(w, dst, src, axes)


def reset_slot(tree, slot: Array, axes, fills: Optional[dict] = None):
    """Blank batch row ``slot``: cache ``positions`` leaves become -1 (empty —
    nothing to attend), every other batched leaf becomes 0. ``fills`` overrides
    the fill value by leaf name (e.g. {"new_count": max_new} to keep a freed
    slot frozen under the Engine's budget check). Leaves without a batch axis
    are untouched."""
    fills = fills or {}

    def r(path, d, ax):
        if ax < 0:
            return d
        name = _path_str(path).rsplit("/", 1)[-1]
        fill = fills.get(name, -1 if name == "positions" else 0)
        shape = list(d.shape)
        shape[ax] = 1
        row = jnp.full(shape, fill, d.dtype)
        return jax.lax.dynamic_update_slice_in_dim(d, row, slot, axis=ax)

    return jax.tree_util.tree_map_with_path(r, tree, axes)


# ---------------------------------------------------------------------------
# paged (block) KV layout
# ---------------------------------------------------------------------------

class BlockAllocator:
    """Host-side refcounted free-list allocator over a fixed pool of KV
    pages.

    ``alloc(n)`` pops n page ids at refcount 1 (returns None — allocating
    nothing — when the pool can't satisfy the request, so admission can
    simply wait); ``free(pages)`` drops one reference per page and returns
    a page to the free list only when its count reaches zero. ``incref``
    adds owners — the prefix cache shares one physical page between its
    index and every slot whose block table maps it, so a page may outlive
    the request that prefilled it. Double-free (decref past zero) and
    foreign ids raise: leaked or aliased pages corrupt neighbouring
    requests silently, so the allocator is the loud line of defense."""

    def __init__(self, n_pages: int):
        if n_pages <= 0:
            raise ValueError(f"need a positive pool, got {n_pages}")
        self.n_pages = n_pages
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        self._ref: Dict[int, int] = {}   # page id -> reference count (>= 1)
        self.peak_used = 0     # high-water mark (honest residency metrics)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return len(self._ref)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` page ids off the free list at refcount 1 (LIFO —
        freshly freed pages are reused first, which keeps the working set
        compact).

        Returns the page ids, or None — allocating *nothing* — when fewer
        than ``n`` pages are free, so a caller can atomically wait/preempt
        instead of holding a partial claim. Raises on negative ``n``.

        A recycled page may carry the previous owner's stale bytes: every
        acquisition path must blank or fully overwrite it (admission
        scatters cover admission; ``Engine.ensure_capacity`` blanks growth
        pages explicitly — blanking at free time is impossible now that
        cached pages survive their request)."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        self.peak_used = max(self.peak_used, len(self._ref))
        return pages

    def incref(self, pages: List[int]) -> None:
        """Add one owner to each page (block-table sharing / CoW-source
        pinning / prefix-cache insertion). Raises on a page that is not
        currently allocated — sharing a free page would alias whatever the
        free list hands out next."""
        for p in pages:
            if p not in self._ref:
                raise ValueError(f"incref of page {p} not currently allocated")
        for p in pages:
            self._ref[p] += 1

    def refcount(self, page: int) -> int:
        """Current owner count of ``page`` (0 when free)."""
        return self._ref.get(page, 0)

    def free(self, pages: List[int]) -> None:
        """Drop one reference per page; a page returns to the pool only at
        refcount zero (shared pages survive until their last owner lets
        go). Raises on a page that is not currently allocated (double-free
        past zero, or a foreign id) — silent aliasing would corrupt a
        neighbouring request's KV."""
        for p in pages:
            if p not in self._ref:
                raise ValueError(f"free of page {p} not currently allocated")
        for p in pages:
            self._ref[p] -= 1
            if self._ref[p] == 0:
                del self._ref[p]
                self._free.append(p)

    def reset_stats(self) -> None:
        """Restart the ``peak_used`` high-water mark at the CURRENT
        residency. Multi-phase benchmark runs (table12/13/16/19 compare
        disciplines or warm-up vs measured passes in one process) call this
        between phases so each phase reports its own honest peak instead of
        the max across every phase so far."""
        self.peak_used = self.n_used


class HostPagePool:
    """Byte-budgeted host-side store for swapped-out requests (the SWAPPED
    page-lifecycle state). Entries are opaque handles keyed by request id;
    the pool only does byte accounting — ``put`` refuses (returns False)
    when the budget would overflow, which is the scheduler's signal to fall
    back to recompute-prefill preemption instead of crashing or stalling.
    ``peak_used``/``reset_stats`` mirror the BlockAllocator's high-water
    discipline so multi-phase benchmarks report honest per-phase peaks."""

    def __init__(self, capacity_bytes: int = 0):
        if capacity_bytes < 0:
            raise ValueError(f"host_pool_bytes={capacity_bytes}")
        self.capacity = int(capacity_bytes)   # 0 = unbounded
        self._entries: Dict[object, tuple] = {}   # key -> (handle, nbytes)
        self.used_bytes = 0
        self.peak_used = 0

    def __contains__(self, key) -> bool:
        return key in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def can_store(self, nbytes: int) -> bool:
        """Whether ``nbytes`` more would still fit the budget."""
        return self.capacity <= 0 or self.used_bytes + nbytes <= self.capacity

    def put(self, key, handle, nbytes: int) -> bool:
        """Store ``handle`` under ``key``; False (storing nothing) when the
        budget can't hold it. Duplicate keys raise — two live snapshots of
        one request would mean a lost or double resume."""
        if key in self._entries:
            raise ValueError(f"swap handle for {key!r} already stored")
        nbytes = int(nbytes)
        if not self.can_store(nbytes):
            return False
        self._entries[key] = (handle, nbytes)
        self.used_bytes += nbytes
        self.peak_used = max(self.peak_used, self.used_bytes)
        return True

    def get(self, key):
        """The stored handle, or None."""
        ent = self._entries.get(key)
        return None if ent is None else ent[0]

    def pop(self, key):
        """Remove and return the handle, releasing its bytes (swap-in
        consumed it, or an abort/fallback dropped it). Missing keys raise —
        like the allocator, double-free means corrupted bookkeeping."""
        if key not in self._entries:
            raise KeyError(f"no swap handle for {key!r}")
        handle, nbytes = self._entries.pop(key)
        self.used_bytes -= nbytes
        return handle

    def reset_stats(self) -> None:
        """Restart the ``peak_used`` high-water mark at current usage (same
        contract as BlockAllocator.reset_stats)."""
        self.peak_used = self.used_bytes


def _is_paged_dict(d: dict, max_len: int) -> bool:
    """A pageable KV cache: the make_kv_cache contract (k/v/positions/ring)
    at full length. Ring caches (positions window < max_len) are already
    memory-bounded and stay per-slot; so do recurrent leaves and the encdec
    cross K/V (no positions leaf)."""
    if not (isinstance(d, dict)
            and {"k", "v", "positions", "ring"} <= set(d.keys())):
        return False
    return d["positions"].shape[-1] == max_len


def has_ring_cache(cache_tree, max_len: int) -> bool:
    """Whether any attention KV cache in the tree is a ring (sliding-window)
    buffer — positions window shorter than max_len. Ring caches wrap on
    write (slot = pos % W), so right-padding a prefill past the window
    would evict live prompt entries; callers must chunk instead of pad."""
    found = False

    def walk(node):
        nonlocal found
        if isinstance(node, dict):
            if {"k", "v", "positions", "ring"} <= set(node.keys()):
                found |= node["positions"].shape[-1] != max_len
                return
            for v in node.values():
                walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(cache_tree)
    return found


def paged_spec(cache_tree, max_len: int):
    """Structure-matched int pytree tagging each leaf of a decode-state (or
    cache) subtree: PAGED_KV / PAGED_POS for pool leaves, NOT_PAGED
    otherwise. Computed from the *contiguous* template; the same spec
    addresses both layouts since paging preserves tree structure."""
    def walk(node):
        if isinstance(node, dict):
            if _is_paged_dict(node, max_len):
                return {k: (PAGED_KV if k in ("k", "v")
                            else PAGED_POS if k == "positions"
                            else NOT_PAGED) for k in node}
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return NOT_PAGED
    return walk(cache_tree)


def _page_axis(tag: int) -> int:
    # pool page axis: k/v (..., NP, page, KV, hd) → -4; positions (..., NP,
    # page) → -2. Same offsets index the (B, W) axes of the contiguous view.
    return -4 if tag == PAGED_KV else -2


def paged_pool(leaf, tag: int, page: int, n_pool_pages: int):
    """Pool counterpart of one contiguous cache leaf: the (B, W) axes become
    (n_pool_pages, page), leading stack axes are preserved. positions init
    to -1 (empty), K/V to zero."""
    ax = _page_axis(tag)
    stack = leaf.shape[:leaf.ndim + ax]             # dims before (B, W)
    tail = leaf.shape[leaf.ndim + ax + 2:]
    shape = stack + (n_pool_pages, page) + tail
    fill = -1 if tag == PAGED_POS else 0
    return jnp.full(shape, fill, leaf.dtype)


def paged_state(state_tree, spec, page: int, n_pool_pages: int):
    """Rebuild a contiguous decode state with every paged leaf replaced by
    its pool. Non-paged leaves are kept as-is (same objects)."""
    return jax.tree.map(
        lambda leaf, tag: leaf if tag == NOT_PAGED
        else paged_pool(leaf, tag, page, n_pool_pages), state_tree, spec)


def gather_pages(pool, table: Array, tag: int):
    """pool (..., NP, page, ...) + table (B, nb) → contiguous view
    (..., B, nb*page, ...). Unallocated table entries (-1) read page 0 but
    their positions are forced to -1, so the view region is *empty* — K/V
    garbage under an empty position is masked by every attention path."""
    ax = _page_axis(tag)
    nd = pool.ndim
    B, nb = table.shape
    view = jnp.take(pool, jnp.clip(table, 0, None), axis=nd + ax)
    # (..., B, nb, page, ...) → merge (nb, page)
    shape = (view.shape[:nd + ax] + (B, nb * pool.shape[nd + ax + 1])
             + view.shape[nd + ax + 3:])
    view = view.reshape(shape)
    if tag == PAGED_POS:
        invalid = jnp.repeat(table < 0, pool.shape[-1], axis=1)   # (B, W)
        view = jnp.where(invalid, -1, view)
    return view


def scatter_pages(pool, view, table: Array, tag: int):
    """Inverse of gather_pages: write the per-slot view back through the
    block table. Rows of unallocated pages (table -1) are dropped (their
    index is forced out of range). Indexing stays on the native page axis —
    no transposes, so XLA lowers a single scatter."""
    ax = pool.ndim + _page_axis(tag)             # absolute page axis
    B, nb = table.shape
    page = pool.shape[ax + 1]
    blocks = view.reshape(view.shape[:ax] + (B * nb, page)
                          + view.shape[ax + 2:])
    idx = jnp.where(table < 0, pool.shape[ax], table).reshape(-1)
    sl = (slice(None),) * ax + (idx,)
    return pool.at[sl].set(blocks.astype(pool.dtype), mode="drop")


def gather_state(pstate, table: Array, spec):
    """Paged decode state → contiguous per-slot view (non-paged leaves pass
    through untouched)."""
    return jax.tree.map(
        lambda leaf, tag: leaf if tag == NOT_PAGED
        else gather_pages(leaf, table, tag), pstate, spec)


def scatter_state(pstate, view_state, table: Array, spec):
    """Contiguous view (post-step) → paged state: paged leaves scatter into
    their pools, everything else takes the stepped view value."""
    return jax.tree.map(
        lambda pool, view, tag: view if tag == NOT_PAGED
        else scatter_pages(pool, view, table, tag), pstate, view_state, spec)


def blank_pages(pstate, table_row: Array, spec):
    """Mark every position slot of the pages in ``table_row`` (nb,) empty
    (-1). A recycled page MUST read as empty at ACQUISITION time:
    incremental growth (``Engine.ensure_capacity``) splices a pool page
    into another slot's table without the full-row overwrite an admission
    does, so a stale positions entry would resurrect the previous owner's
    KV as attendable history. Blanking runs on alloc, not free — a freed
    page may still be mapped by the prefix cache or a sharing slot, and
    blanking it at free time would corrupt the surviving owners' history.
    K/V bytes are left in place — empty positions mask them on every
    attention path. Unallocated entries (-1) are dropped."""
    def blank(pool, tag):
        if tag != PAGED_POS:
            return pool
        ax = pool.ndim + _page_axis(tag)
        nb, page = table_row.shape[0], pool.shape[ax + 1]
        view = jnp.full(pool.shape[:ax] + (1, nb * page), -1, pool.dtype)
        return scatter_pages(pool, view, table_row[None], tag)
    return jax.tree.map(blank, pstate, spec)


def copy_page(pstate, src: Array, dst: Array, spec):
    """Copy one pool page — K/V bytes and positions alike — from page id
    ``src`` to page id ``dst`` across every paged leaf. This is the
    copy-on-write step of prefix caching: a cached page whose token chain
    matches but whose content a new request must amend (the divergent last
    drafter entry) is duplicated into a freshly allocated page the slot
    owns, leaving the shared original byte-stable for its other owners.
    ``src``/``dst`` may be traced scalars, so one trace serves every page
    pair."""
    def cp(pool, tag):
        if tag == NOT_PAGED:
            return pool
        ax = pool.ndim + _page_axis(tag)
        page = jax.lax.dynamic_index_in_dim(pool, src, axis=ax, keepdims=True)
        return jax.lax.dynamic_update_slice_in_dim(pool, page, dst, axis=ax)
    return jax.tree.map(cp, pstate, spec)


def admit_pages(pstate, src, slot: Array, table_row: Array, axes, spec,
                scatter_row: Optional[Array] = None):
    """Admit a batch-1 contiguous state ``src`` into a paged state: per-slot
    leaves go through ``write_slot`` (pool leaves have no batch axis in the
    paged layout, so the inferred ``axes`` skip them automatically), paged
    leaves scatter src row 0 into the pages of ``table_row`` (nb,).

    ``scatter_row`` (default: ``table_row``) selects which of the row's
    pages actually receive the src view — a prefix-cache hit masks the
    shared prefix pages to -1 (dropped by ``scatter_pages``) so admission
    writes only the freshly prefilled suffix pages and never touches pages
    other slots (or the cache index) still map."""
    out = write_slot(pstate, src, slot, axes)
    sr = table_row if scatter_row is None else scatter_row

    def admit(pool, s, tag):
        if tag == NOT_PAGED:
            return pool
        return scatter_pages(pool, jax.lax.index_in_dim(
            s, 0, axis=s.ndim + _page_axis(tag), keepdims=True),
            sr[None], tag)

    return jax.tree.map(admit, out, src, spec)


def view_width_axis(ndim: int, tag: int) -> int:
    """Absolute index of the W (position-within-slot) axis of a contiguous
    view leaf with ``ndim`` dims — one right of where the pool's page axis
    sits. Host-side swap code uses this to slice page spans (page ``i``
    occupies ``[i*page, (i+1)*page)`` along this axis) out of / back into
    the gathered view with plain numpy indexing."""
    return ndim + _page_axis(tag) + 1


def extract_slot(pstate, slot: Array, table_row: Array, axes, spec):
    """Inverse of ``admit_pages``: re-express batch row ``slot`` of a paged
    state as a batch-1 *contiguous* state — per-slot leaves slice their
    ``slot`` row, paged leaves gather the row's pages (``table_row`` (nb,))
    into the per-slot view. Leaves without a batch axis (global counters)
    pass through unchanged; restore paths must ignore them (``write_slot``
    already does). This is the device half of swap-out: one jit-friendly
    gather whose output, round-tripped through host memory, re-admits
    bitwise via ``admit_pages`` — unallocated table entries (-1) read as
    empty positions exactly as ``gather_pages`` guarantees, and the matching
    swap-in drops those spans via its ``scatter_row`` mask."""
    def ex(leaf, ax, tag):
        if tag != NOT_PAGED:
            return gather_pages(leaf, table_row[None], tag)
        if ax < 0:
            return leaf
        return jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis=ax)
    return jax.tree.map(ex, pstate, axes, spec)
