"""Cross-request prefix caching over the paged KV pool (hash-consed pages).

At serving scale most traffic shares a system prompt or few-shot preamble.
The paged layout (cache_ops) makes the KV of every ``page_size``-position
span a first-class pool page, and pages are **positions-exact**: the page at
block-table index ``m`` holds absolute positions ``[m*ps, (m+1)*ps)``, and
its content — target K/V plus the drafter's fused (tap, embedding) entries —
is a pure function of the token stream. That makes full pages hash-consable:
:class:`PrefixCache` keys each page by its *token-prefix chain*, and
admission of a request whose prompt walks the same chain maps the cached
pages into its block-table row instead of re-prefilling them
(``Engine.prefill_into_slot``), prefilling only the uncached suffix.

Key scheme (why the lookahead token is part of the key)
-------------------------------------------------------
Target KV at position ``p`` depends on tokens ``0..p``. But the drafter
cache entry at ``p`` fuses ``(tap[p], embedding(token[p+1]))`` — EAGLE-style
drafters condition on the *next* token — so the page covering positions
``[m*ps, (m+1)*ps)`` depends on tokens ``0..(m+1)*ps`` inclusive: the page's
own tokens plus one **lookahead** token. Hence two keys per page:

  partial key   h_{m+1}            = H(h_m || page_tokens)   (chain)
  full key      H(h_{m+1} || lookahead_token)

A page is shareable as-is only through its full key. A *partial* match —
same chain, different (or absent) lookahead — still holds valid target KV
for all ``ps`` positions and valid drafter entries for all but the last, so
it serves as a **copy-on-write source**: the engine copies it into a fresh
page the new request owns (``cache_ops.copy_page``) and recomputes just the
final position, leaving the shared original byte-stable for its owners.

Sharing, refcounts, eviction
----------------------------
The cache holds its own reference on every indexed page
(``BlockAllocator.incref``), so cached pages survive ``free_slot`` — a
request's prefix stays warm after it finishes, and a preempted request's
own resume can hit the pages its eviction left behind. Pages are inserted
after admission (the verifiable prompt prefix) and at ``free_slot`` (the
committed prompt+generation stream), always deduplicated by full key.
Under pool pressure the engine evicts **least-recently-used cache-only
pages** (allocator refcount 1); pages any live slot still maps (refcount
> 1) are pinned and skipped. Everything here is host-side bookkeeping —
page ids and hashes — device pools are never touched by this module.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

_ROOT = b"prefix-cache-root"


def _h(*parts: bytes) -> bytes:
    d = hashlib.blake2b(digest_size=16)
    for p in parts:
        d.update(p)
    return d.digest()


@dataclass
class _Entry:
    full_key: bytes      # H(chain || lookahead) — shareable identity
    partial_key: bytes   # chain hash — CoW-source identity
    page: int            # pool page id (cache holds one allocator ref)


class PrefixCache:
    """Host-side index: token-prefix chain -> pool page id.

    One instance per :class:`~repro.serving.engine.Engine` (the engine IS
    the model axis of the (token-prefix, model) key — pages from different
    models never share a pool). All methods take token streams as 1-D
    int32 arrays and return plain page ids; the engine owns every device
    interaction and all refcount transitions except the cache's own
    insert-ref/evict-deref pair."""

    def __init__(self, page_size: int):
        if page_size <= 0:
            raise ValueError(f"need a positive page_size, got {page_size}")
        self.page_size = page_size
        self._entries: "OrderedDict[bytes, _Entry]" = OrderedDict()  # LRU
        self._partial: Dict[bytes, "OrderedDict[bytes, None]"] = {}
        self.stats = {"admissions": 0, "hits": 0, "misses": 0,
                      "hit_tokens": 0, "cow_hits": 0, "inserts": 0,
                      "evictions": 0}

    def __len__(self) -> int:
        return len(self._entries)

    def pages(self) -> List[int]:
        """Page ids currently indexed (one allocator ref each)."""
        return [e.page for e in self._entries.values()]

    # ------------------------------------------------------------------
    def _page_bytes(self, toks: np.ndarray, m: int) -> bytes:
        ps = self.page_size
        return toks[m * ps:(m + 1) * ps].tobytes()

    def _walk(self, toks: np.ndarray, touch: bool):
        """Longest full-key chain walk. Returns ``(shared_page_ids, h_m)``
        where ``h_m`` is the chain hash *before* the first unmatched page
        (ready for the CoW probe). ``touch`` refreshes LRU order."""
        ps = self.page_size
        P = toks.size
        m_max = (P - 1) // ps     # pages whose lookahead the stream contains
        shared: List[int] = []
        h = _ROOT
        for m in range(m_max):
            h2 = _h(h, self._page_bytes(toks, m))
            fk = _h(h2, toks[(m + 1) * ps].tobytes())
            e = self._entries.get(fk)
            if e is None:
                break
            if touch:
                self._entries.move_to_end(fk)
            shared.append(e.page)
            h = h2
        return shared, h

    def _match(self, tokens, touch: bool):
        toks = np.asarray(tokens, np.int32).reshape(-1)
        shared, h = self._walk(toks, touch)
        cow = None
        m = len(shared)
        if (m + 1) * self.page_size <= toks.size:
            bucket = self._partial.get(_h(h, self._page_bytes(toks, m)))
            if bucket:
                cow = self._entries[next(reversed(bucket))].page
        return shared, cow

    def match(self, tokens) -> Tuple[List[int], Optional[int]]:
        """Longest cached prefix of ``tokens``: ``(shared_pages, cow_src)``.

        ``shared_pages`` are full-key hits, mappable as-is (the caller must
        ``incref`` them before any allocation that could evict). ``cow_src``
        — when the page after the shared run has a partial-chain match whose
        ``page_size`` tokens the stream fully contains — is a page to
        copy-on-write: valid except its last drafter entry. Matched entries
        are LRU-refreshed; the CoW source is not (a copy is not reuse)."""
        return self._match(tokens, touch=True)

    def probe(self, tokens) -> Tuple[List[int], Optional[int]]:
        """Read-only :meth:`match` — same result, but never touches LRU
        order. For admission gating (``Engine.can_admit``): probing
        admissibility is not reuse, and the gate needs the page ids to know
        which evictable pages a real admission would pin."""
        return self._match(tokens, touch=False)

    def match_len(self, tokens) -> int:
        """Read-only full-key hit count in pages (the post-hit page need is
        ``initial_pages - match_len``)."""
        toks = np.asarray(tokens, np.int32).reshape(-1)
        return len(self._walk(toks, touch=False)[0])

    # ------------------------------------------------------------------
    def insert_stream(self, tokens, pages: List[int], allocator) -> int:
        """Index every *verifiable* full page of ``tokens``: page ``m`` is
        insertable iff the stream contains its lookahead token —
        ``(m+1)*page_size + 1 <= len(tokens)`` — which also guarantees the
        owning slot never writes it again (decode writes start past the
        prompt; committed entries are append-only). ``pages`` is the
        owning slot's page list; each newly indexed page gains one
        allocator ref. Full-key duplicates are LRU-refreshed, not
        re-inserted (first physical page wins). Returns pages inserted."""
        toks = np.asarray(tokens, np.int32).reshape(-1)
        ps = self.page_size
        n = min((toks.size - 1) // ps, len(pages))
        h = _ROOT
        inserted = 0
        for m in range(n):
            h = _h(h, self._page_bytes(toks, m))
            fk = _h(h, toks[(m + 1) * ps].tobytes())
            if fk in self._entries:
                self._entries.move_to_end(fk)
                continue
            allocator.incref([pages[m]])
            self._entries[fk] = _Entry(fk, h, pages[m])
            self._partial.setdefault(h, OrderedDict())[fk] = None
            inserted += 1
        self.stats["inserts"] += inserted
        return inserted

    # ------------------------------------------------------------------
    def _drop(self, fk: bytes) -> _Entry:
        e = self._entries.pop(fk)
        bucket = self._partial.get(e.partial_key)
        if bucket is not None:
            bucket.pop(fk, None)
            if not bucket:
                del self._partial[e.partial_key]
        return e

    def evictable(self, allocator, exclude=()) -> int:
        """Pages reclaimable right now: cache-only (allocator refcount 1).
        Pages a live slot still maps are pinned. ``exclude`` — page ids to
        leave out of the count (an admission gate passes the pages its own
        hit would pin, which therefore can't be evicted to fund it)."""
        skip = set(exclude)
        return sum(1 for e in self._entries.values()
                   if allocator.refcount(e.page) == 1
                   and e.page not in skip)

    def evict(self, need: int, allocator) -> int:
        """Free up to ``need`` cache-only pages, least-recently-used first;
        pinned pages (refcount > 1) are skipped, not stalled on. Returns
        pages actually freed to the pool."""
        freed = 0
        if need <= 0:
            return 0
        for fk in list(self._entries):         # oldest -> newest
            e = self._entries[fk]
            if allocator.refcount(e.page) != 1:
                continue
            self._drop(fk)
            allocator.free([e.page])
            freed += 1
            self.stats["evictions"] += 1
            if freed >= need:
                break
        return freed

    def flush(self, allocator) -> int:
        """Drop every entry (cache refs released; pages shared with live
        slots survive at their remaining count). Test/drain hook."""
        n = 0
        for fk in list(self._entries):
            e = self._drop(fk)
            allocator.free([e.page])
            n += 1
        return n

    # ------------------------------------------------------------------
    def note_admission(self, hit_tokens: int, cow: bool) -> None:
        """Record one admission's outcome (engine calls this whether or not
        the prompt hit)."""
        self.stats["admissions"] += 1
        if hit_tokens > 0:
            self.stats["hits"] += 1
            self.stats["hit_tokens"] += hit_tokens
        else:
            self.stats["misses"] += 1
        if cow:
            self.stats["cow_hits"] += 1
