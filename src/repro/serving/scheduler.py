"""Event-driven continuous-batching scheduler — the framework's
request-lifecycle layer over serving/engine.py (what vLLM's scheduler is to
its model runner, and what the paper's deployed-serving numbers §5.4
implicitly rely on).

Request lifecycle::

    QUEUED ──arrive──► (eligible) ──admit──► PREFILLING ──► DECODING ──┐
      ▲                                          ▲                    │
      │                                          │            EOS / budget
      └────────── preempted (pages freed, ───────┘                    │
                  tokens kept host-side)                          FINISHED

The engine's decode state is a fixed-shape batch of B *slots*; every
speculative iteration steps all B rows under a per-slot active mask. When a
request finishes (per-request ``max_new_tokens`` budget or EOS), its slot is
freed *immediately* — mid-stream — and the next eligible request is prefilled
straight into the live batch (``Engine.prefill_into_slot``), not held until
the whole batch drains.

Arrival times and the virtual clock
-----------------------------------
Requests carry an ``arrival_time`` (virtual time units). The scheduler runs a
deterministic, step-cost-driven **virtual clock**: every dispatched
speculative iteration advances it by ``iter_cost``, every admission prefill
by ``prefill_cost``, and when nothing is live the clock jumps to the next
arrival. No request is admitted before its arrival; among arrived requests
admission is FIFO by ``(arrival_time, submission order)`` with head-of-line
blocking (when the head doesn't fit the page pool the scheduler waits for
frees — or preempts — rather than admitting around it). Because the clock is
derived from step counts, not wall time, async traces replay bit-identically
on CPU test runs; wall-clock metrics are kept alongside for throughput.

Preemption (paged layout)
-------------------------
Under incremental page growth (``EngineConfig(kv_growth="incremental")``) a
slot claims pages only as its length crosses page boundaries, so the pool can
genuinely run out mid-decode. When growth fails — or when the queue head
would starve behind lower-priority runners — the lowest-priority running slot
(latest ``(arrival_time, submission)``) is evicted: its pages return to the
pool and its prompt + generated tokens are retained host-side. It is later
re-admitted by **recompute-prefill** (prompt + generated-so-far becomes the
new prefill), token-for-token losslessly for EVERY decoding policy: greedy
speculative output is a pure function of the prefix, and a seeded sampled
request's continuation is a pure function of ``(seed, prefix)`` — its
per-step keys are ``fold_in(seed, position)`` counters, re-derived over the
recomputed prefix (the resume prefill rebuilds the eviction's exact
step-boundary state and commits nothing new; serving/sampling.py).
tests/test_async_serving.py pins both, per family. Re-admission of a
preempted request gates on its *full* remaining need so the same pressure
cannot immediately re-evict it.

Row independence is the correctness backbone: attention, cache updates, and
verification are all per-row, so admitting into slot *i* cannot change what
slot *j* emits (tests/test_scheduler.py asserts this token-for-token; note
MoE targets with capacity-based routing couple rows and are excluded from
that guarantee).

Termination is host-driven: after each iteration the scheduler reads back
the small per-slot counters plus newly committed tokens, detects per-request
EOS (output trimmed at the first EOS, vLLM semantics) and budget exhaustion,
and retires slots. Speculative commits can overshoot a budget by up to K;
overshoot tokens are trimmed from the emitted output.

The scheduler is device-layout agnostic: it only ever calls the Engine's
jitted entry points and reads back small replicated counters, so a
model-sharded engine (``EngineConfig(shard_model=True)`` — weights and KV
page pools storage-sharded over a device mesh, docs/sharding.md) slots in
with zero changes here and identical token streams (pinned by the sharded
cases in tests/test_serving.py and tests/test_async_serving.py).

Quickstart::

    eng = Engine(tcfg, dcfg, tparams, dparams, EngineConfig(...), batch=4)
    sched = Scheduler(eng, eos_id=None)
    report = sched.serve([Request(p, arrival_time=t) for p, t in work])
    report["otps"], report["p99_latency_vt"], report["results"][0]["tokens"]
"""
from __future__ import annotations

import bisect
import itertools
import time
import zlib
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import make_extras
from repro.serving.engine import Engine
from repro.serving.sampling import SamplingParams

QUEUED = "queued"
PREFILLING = "prefilling"
DECODING = "decoding"
FINISHED = "finished"

_rid_counter = itertools.count()


@dataclass
class Request:
    """One generation request. ``prompt`` is a 1-D int32 token array; the
    prefill commits the first generated token, which counts toward
    ``max_new_tokens`` (None = the engine's default budget).

    ``sampling`` is the request's decoding policy (temperature / top-k /
    top-p / seed / stop tokens — serving/sampling.SamplingParams); None
    falls back to the engine default (``EngineConfig.sampling``, greedy
    unless configured otherwise). A batch may freely mix greedy and sampled
    requests: policy is a per-slot row of the device state, not an engine
    mode. Budget precedence: ``max_new_tokens`` here, else
    ``sampling.max_new_tokens``, else the engine default.

    ``arrival_time`` is in virtual time units — the scheduler will not admit
    the request before its arrival. ``extras`` carries per-request modality
    inputs (vision embeds / encoder embeds, leading batch axis 1, as built
    by ``models.make_extras(cfg, 1, "prefill", key)``); for vlm/encdec
    targets without explicit extras a deterministic stub (keyed by the
    prompt bytes) is synthesized at admission."""
    prompt: Any
    max_new_tokens: Optional[int] = None
    arrival_time: float = 0.0
    extras: Optional[dict] = None
    sampling: Optional[SamplingParams] = None
    rid: int = field(default_factory=lambda: next(_rid_counter))
    # lifecycle (managed by the scheduler)
    status: str = QUEUED
    slot: Optional[int] = None
    out_tokens: List[int] = field(default_factory=list)
    # metrics
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_finish: float = 0.0
    vt_admit: Optional[float] = None   # virtual clock at first admission
    vt_finish: float = 0.0
    n_preempt: int = 0
    iters: int = 0                 # decode iterations this request was live
    cached_tokens: int = 0         # prompt positions served from the prefix
    #                                cache across all admissions (0 = cold)
    # internal bookkeeping
    _prev_new: int = 0             # device-side new_count at last sync
    _prev_last: int = 0            # device-side last position at last sync
    _iters_base: int = 0           # iters accumulated before the last resume
    _committed: int = 0            # tokens committed across all admissions
    _prefills: int = 0             # prefill-committed tokens (1 + resumes)
    _seq: int = 0                  # submission index (FIFO tie-break)

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError("empty prompt")
        if not (self.arrival_time >= 0.0 and np.isfinite(self.arrival_time)):
            raise ValueError(f"bad arrival_time {self.arrival_time!r}")

    @property
    def acceptance_length(self) -> float:
        """Mean tokens committed per decode iteration (prefill-committed
        tokens excluded, one per admission) — the paper's AL, per request."""
        return (self._committed - self._prefills) / max(self.iters, 1)


class Scheduler:
    """Event-driven continuous-batching loop over an Engine's B slots.

    ``eos_id`` — token id that terminates a request (output trimmed at the
    first occurrence, which the losslessness tests rely on being identical
    across drafter modes). ``free_on_finish`` — blank freed slots' cache rows
    (optional; admission fully overwrites a slot either way).

    ``sync_every`` — speculative iterations dispatched between host syncs.
    1 gives the most responsive admission/EOS handling; higher values let jax
    pipeline dispatch (the whole-batch Engine.run polls every 8) at the cost
    of slots idling up to sync_every-1 iterations after finishing, and of
    page growth reserving capacity for the whole block up front. Outputs
    are identical either way: per-slot budgets freeze rows ON DEVICE, and
    EOS/budget trimming is positional, not timing-dependent.

    ``iter_cost`` / ``prefill_cost`` — virtual-clock cost of one speculative
    iteration / one admission prefill. The defaults (1.0 each) make the clock
    an iteration counter; scale them to calibrated step times to model a
    specific accelerator without losing determinism.

    ``preempt`` — evict the lowest-priority running slot when the page pool
    is exhausted (growth failure or queue-head starvation), resuming later by
    recompute-prefill (default: enabled). The resume is token-for-token
    lossless for every decoding policy: greedy continuation is a pure
    function of the prefix, and seeded sampling re-derives its per-step keys
    from ``fold_in(seed, position)`` over the recomputed prefix
    (``Engine.prefill_into_slot(resume=True)`` restarts verification at the
    exact step boundary the eviction stopped at). ``preempt=False`` stalls
    slots on pool exhaustion instead.
    """

    def __init__(self, engine: Engine, eos_id: Optional[int] = None,
                 free_on_finish: bool = True, sync_every: int = 1,
                 iter_cost: float = 1.0, prefill_cost: float = 1.0,
                 preempt: Optional[bool] = None):
        self.engine = engine
        self.eos_id = eos_id
        self.free_on_finish = free_on_finish
        self.sync_every = max(int(sync_every), 1)
        self.iter_cost = float(iter_cost)
        self.prefill_cost = float(prefill_cost)
        self.preempt = True if preempt is None else bool(preempt)

    # ------------------------------------------------------------------
    def serve(self, requests: Sequence,
              max_iters: int = 100_000) -> Dict[str, Any]:
        """Run every request to completion; returns aggregate + per-request
        metrics (wall-clock and virtual-time). ``requests`` entries may be
        Request objects or raw prompt arrays (coerced with the engine's
        default budget and sampling policy, arrival 0)."""
        eng = self.engine
        B = eng.batch
        default_budget = eng.ecfg.max_new_tokens

        reqs = [r if isinstance(r, Request) else Request(r) for r in requests]
        t_start = time.perf_counter()
        for i, r in enumerate(reqs):
            if r.status != QUEUED or r.out_tokens:
                raise ValueError(
                    f"request {r.rid} is {r.status}; Request objects are "
                    "single-use — submit a fresh one")
            r.t_submit = t_start
            r._seq = i
            if r.sampling is None:
                r.sampling = eng.ecfg.sampling
            if r.max_new_tokens is None:
                r.max_new_tokens = (r.sampling.max_new_tokens
                                    if r.sampling.max_new_tokens is not None
                                    else default_budget)
            # prompt + budget + worst-case speculative overshoot must fit the
            # cache, else the slot could never reach its budget
            need = (r.prompt.size + eng.pos_offset + r.max_new_tokens
                    + eng.ecfg.K + 1)
            if need > eng.ecfg.max_len:
                raise ValueError(
                    f"request {r.rid}: prompt {r.prompt.size} + "
                    f"max_new_tokens {r.max_new_tokens} (+K overshoot) "
                    f"exceeds max_len {eng.ecfg.max_len}")
            if eng.paged:
                n = eng.pages_needed(r.prompt.size, r.max_new_tokens)
                if n > eng.pool_pages:
                    raise ValueError(
                        f"request {r.rid}: needs {n} KV pages but the pool "
                        f"only has {eng.pool_pages}; it could never be "
                        "admitted")

        def prio(r: Request) -> Tuple[float, int]:
            return (r.arrival_time, r._seq)

        pending = deque(sorted(reqs, key=prio))   # not yet arrived
        waiting: List[Request] = []               # arrived, sorted by prio
        clock = 0.0
        events: List[Tuple[float, str, int]] = []

        # a prefix-cache engine resumes from the previous session's pool
        # (cached page content lives in the state arrays); otherwise blank
        state = eng.serve_state()
        active = np.zeros((B,), bool)
        max_new = np.zeros((B,), np.int32)
        slot_req: List[Optional[Request]] = [None] * B
        finished: List[Request] = []
        n_iters = 0
        n_preempt_total = 0

        def committed_stream(req: Request) -> np.ndarray:
            """prompt + emitted tokens — what a freed slot's pages verifiably
            hold; the engine's prefix cache indexes its full pages so later
            requests (or this one's resume) admit against them."""
            return np.concatenate(
                [req.prompt, np.asarray(req.out_tokens, np.int32)])

        def finish(s: int):
            nonlocal state
            req = slot_req[s]
            req.status = FINISHED
            req.t_finish = time.perf_counter()
            req.vt_finish = clock
            active[s] = False
            slot_req[s] = None
            finished.append(req)
            events.append((clock, "finish", req.rid))
            # paged engines MUST free (pages return to the pool); contiguous
            # freeing is cosmetic and stays opt-out
            if self.free_on_finish or eng.paged:
                state = eng.free_slot(state, s,
                                      final_tokens=committed_stream(req))

        def preempt_slot(s: int):
            """Evict slot s: pages freed, prompt + generated tokens retained
            host-side; the request re-enters the queue at its original
            priority for a recompute-prefill resume."""
            nonlocal state, n_preempt_total
            req = slot_req[s]
            req.status = QUEUED
            req.slot = None
            req.n_preempt += 1
            req._iters_base = req.iters
            n_preempt_total += 1
            active[s] = False
            slot_req[s] = None
            state = eng.free_slot(state, s,
                                  final_tokens=committed_stream(req))
            bisect.insort(waiting, req, key=prio)
            events.append((clock, "preempt", req.rid))

        def lowest_prio_active() -> Optional[int]:
            live = [s for s in range(B) if active[s]]
            if not live:
                return None
            return max(live, key=lambda s: prio(slot_req[s]))

        def head_admissible(req: Request) -> bool:
            # resumed requests gate on their full remaining need (anti-
            # thrash: a victim must not be re-evicted by the pressure that
            # evicted it); fresh ones on the initial claim only. The
            # admission prompt is passed along so a prefix-cache engine
            # gates on the EFFECTIVE need — pages the prompt will map from
            # the cache never touch the free list
            plen = req.prompt.size + len(req.out_tokens)
            rem = req.max_new_tokens - len(req.out_tokens)
            stream = req.prompt
            if req.out_tokens:
                stream = committed_stream(req)
                if not req.sampling.is_greedy:
                    stream = stream[:-1]   # sampled resume prefills [:-1]
            return eng.can_admit(plen, rem, full=req.n_preempt > 0,
                                 tokens=stream)

        def clip_and_check_done(req: Request) -> bool:
            """Trim at the first stop token (scheduler ``eos_id`` or the
            request's ``SamplingParams.stop_token_ids``) / budget; True when
            the request is complete."""
            out = req.out_tokens
            done = False
            stops = set(req.sampling.stop_token_ids)
            if self.eos_id is not None:
                stops.add(self.eos_id)
            idx = min((out.index(t) for t in stops if t in out), default=None)
            if idx is not None:
                del out[idx + 1:]
                done = True
            if len(out) >= req.max_new_tokens:
                del out[req.max_new_tokens:]     # speculative overshoot
                done = True
            return done

        def admit(req: Request, s: int):
            nonlocal state, clock
            # recompute-prefill resume: the prefix is prompt + everything
            # generated before eviction. Greedy continuation from that
            # prefix is exactly the uninterrupted stream (the prefill's
            # argmax commit equals the verify path's token); a sampled
            # request instead resumes via resume=True — the prefill rebuilds
            # the eviction's step-boundary state and commits nothing new, so
            # the next step restarts seeded verification at the same
            # committed prefix — and fold_in key — the uninterrupted run's
            # step boundary had
            prompt = (np.concatenate([req.prompt,
                                      np.asarray(req.out_tokens, np.int32)])
                      if req.out_tokens else req.prompt)
            resume = bool(req.out_tokens) and not req.sampling.is_greedy
            remaining = req.max_new_tokens - len(req.out_tokens)
            req.status = PREFILLING
            req.slot = s
            if req.vt_admit is None:
                req.vt_admit = clock
                req.t_admit = time.perf_counter()
            extras = req.extras
            if extras is None and eng.tcfg.family in ("vlm", "encdec"):
                # deterministic stub frontend inputs keyed by the PROMPT
                # (not the process-global rid), so re-serving the same
                # workload with fresh Request objects replays identical
                # extras; cached on the request so a preemption resume
                # (longer recompute prompt) also replays them
                seed = zlib.crc32(req.prompt.tobytes()) & 0x7FFFFFFF
                extras = make_extras(eng.tcfg, 1, "prefill",
                                     jax.random.fold_in(jax.random.PRNGKey(0),
                                                        seed))
                req.extras = extras
            events.append((clock, "admit", req.rid))
            state, first, last = eng.prefill_into_slot(
                state, prompt, s, extras=extras, sampling=req.sampling,
                max_new=remaining, resume=resume)
            req.cached_tokens += eng.last_hit_tokens
            clock += self.prefill_cost
            if first is None:               # no-commit resume (sampled)
                req._prev_new, req._prev_last = 0, last
            else:
                req.out_tokens.append(first)
                req._committed += 1
                req._prefills += 1
                req._prev_new, req._prev_last = 1, last
            req.status = DECODING
            slot_req[s] = req
            active[s] = True
            max_new[s] = remaining
            if clip_and_check_done(req):     # EOS at the very first token
                finish(s)

        while pending or waiting or active.any():
            # ---- arrivals: move everything whose time has come -----------
            while pending and pending[0].arrival_time <= clock + 1e-9:
                r = pending.popleft()
                bisect.insort(waiting, r, key=prio)
                events.append((r.arrival_time, "arrive", r.rid))
            # ---- idle: nothing eligible, nothing running → jump the clock
            if not waiting and not active.any():
                clock = max(clock, pending[0].arrival_time)
                continue

            # ---- admission: eligible requests into free slots, FIFO by
            # (arrival, submission) with head-of-line blocking; preemption
            # resolves starvation when the head outranks a runner. Free
            # slots are recomputed per admission — a slot freed by a
            # preemption (or an EOS-at-prefill) is reusable immediately,
            # not after the next sync block ------------------------------
            while waiting:
                free = [s for s in range(B) if not active[s]
                        and slot_req[s] is None]
                if not free:
                    break
                head = waiting[0]
                if not head_admissible(head):
                    if self.preempt:
                        while not head_admissible(head):
                            v = lowest_prio_active()
                            if v is None or prio(slot_req[v]) <= prio(head):
                                break
                            preempt_slot(v)
                    if not head_admissible(head):
                        break                # head waits for frees (FIFO)
                admit(waiting.pop(0), free[0])

            if not active.any():
                if waiting:
                    raise RuntimeError(
                        "no active slot and the head request cannot be "
                        "admitted — page pool leak?")
                continue                     # everything died at prefill

            # ---- capacity: grow each live slot to cover the coming sync
            # block (incremental paged growth); on pool exhaustion preempt
            # the lowest-priority slot, or stall when preemption is off ----
            stalled = np.zeros((B,), bool)
            if eng.incremental:
                by_prio = sorted(np.flatnonzero(active),
                                 key=lambda s: prio(slot_req[s]))
                for s in by_prio:
                    if not active[s]:        # already evicted this pass
                        continue
                    req = slot_req[s]
                    cap = (req.prompt.size + eng.pos_offset
                           + req.max_new_tokens + eng.ecfg.K + 1)
                    # a step at position c writes KV c..c+stride-1 and moves
                    # c by at most stride, so sync_every steps need length
                    # last + sync_every*stride, exactly
                    target = min(req._prev_last
                                 + self.sync_every * eng.commit_stride, cap)
                    state, ok = eng.ensure_capacity(state, int(s), target)
                    while not ok and self.preempt:
                        v = lowest_prio_active()
                        preempt_slot(v)
                        if v == s:
                            break
                        state, ok = eng.ensure_capacity(state, int(s), target)
                    if not ok and active[s]:
                        stalled[s] = True    # retry once pages free up
            run = active & ~stalled
            if not run.any():
                raise RuntimeError(
                    "page pool exhausted and every live slot is stalled; "
                    "enable preemption (Scheduler(preempt=True)) or grow "
                    "pool_pages")

            # ---- speculative iterations over all live slots ---------------
            # (several per sync when sync_every > 1 — jax pipelines the
            # dispatches; budget freezes happen on device regardless)
            act_dev, mn_dev = jnp.asarray(run), jnp.asarray(max_new)
            for _ in range(self.sync_every):
                state = eng.step(state, act_dev, mn_dev)
                n_iters += 1
                clock += self.iter_cost
            if n_iters > max_iters:
                raise RuntimeError("scheduler exceeded max_iters")

            # ---- sync: harvest newly committed tokens, retire slots -------
            new_count = np.asarray(state["new_count"])
            slot_iters = np.asarray(state["slot_iters"])
            last = np.asarray(state["last"])
            tokens = np.asarray(state["tokens"])
            for s in range(B):
                req = slot_req[s]
                if req is None or not active[s]:
                    continue
                req.iters = req._iters_base + int(slot_iters[s])
                if new_count[s] > req._prev_new:
                    req.out_tokens.extend(
                        tokens[s, req._prev_last + 1:last[s] + 1].tolist())
                    req._committed += int(new_count[s]) - req._prev_new
                    req._prev_new = int(new_count[s])
                    req._prev_last = int(last[s])
                if clip_and_check_done(req):
                    finish(s)

        wall = time.perf_counter() - t_start
        eng.retain_state(state)       # keep cached pages warm across serves
        return self._report(finished, wall, n_iters, clock, events,
                            n_preempt_total)

    # ------------------------------------------------------------------
    def _report(self, finished: List[Request], wall: float, n_iters: int,
                makespan_vt: float, events: List[Tuple[float, str, int]],
                n_preempt: int) -> Dict[str, Any]:
        results = [{
            "rid": r.rid,
            "tokens": np.asarray(r.out_tokens, np.int32),
            "n_new": len(r.out_tokens),
            "iters": r.iters,
            "acceptance_length": r.acceptance_length,
            "arrival_time": r.arrival_time,
            "n_preempt": r.n_preempt,
            "cached_tokens": r.cached_tokens,
            "wait_s": r.t_admit - r.t_submit,
            "latency_s": r.t_finish - r.t_submit,
            "wait_vt": r.vt_admit - r.arrival_time,
            "latency_vt": r.vt_finish - r.arrival_time,
        } for r in sorted(finished, key=lambda r: r.rid)]
        total = sum(r["n_new"] for r in results)
        lat_vt = [r["latency_vt"] for r in results] or [0.0]
        wait_vt = [r["wait_vt"] for r in results] or [0.0]
        return {
            "results": results,
            "n_requests": len(results),
            "iterations": n_iters,
            "total_new_tokens": total,
            "wall_s": wall,
            "otps": total / max(wall, 1e-9),
            "mean_acceptance_length": float(np.mean(
                [r["acceptance_length"] for r in results])) if results else 0.0,
            "mean_latency_s": float(np.mean(
                [r["latency_s"] for r in results])) if results else 0.0,
            # virtual-time (deterministic) latency profile + churn trace
            "makespan_vt": makespan_vt,
            "otps_vt": total / max(makespan_vt, 1e-9),
            "preemptions": n_preempt,
            # prefix-cache effectiveness (0s on cache-off engines)
            "cache_hit_tokens": sum(r["cached_tokens"] for r in results),
            "cache_hit_requests": sum(
                1 for r in results if r["cached_tokens"] > 0),
            "p50_latency_vt": float(np.percentile(lat_vt, 50)),
            "p99_latency_vt": float(np.percentile(lat_vt, 99)),
            "p50_wait_vt": float(np.percentile(wait_vt, 50)),
            "p99_wait_vt": float(np.percentile(wait_vt, 99)),
            "events": events,
        }


class LLMEngine:
    """vLLM-style front-end over Engine + Scheduler: offline batch
    generation with per-prompt :class:`SamplingParams`.

    Quickstart::

        llm = LLMEngine(engine, eos_id=2)
        outs = llm.generate(prompts, SamplingParams(temperature=0.8, seed=7))
        outs[0]["tokens"]            # np.int32 generated ids, stop-trimmed

    ``generate`` accepts one ``SamplingParams`` for every prompt or a list
    with one entry per prompt (None entries fall back to the engine
    default), so a single call — and a single batch — may mix greedy and
    sampled requests. Outputs are returned in prompt order; the full
    scheduler report of the last call (aggregate OTPS, latency percentiles,
    event trace) is kept on ``last_report``.
    """

    def __init__(self, engine: Engine, eos_id: Optional[int] = None,
                 **scheduler_kwargs):
        self.engine = engine
        self.scheduler = Scheduler(engine, eos_id=eos_id, **scheduler_kwargs)
        self.last_report: Optional[Dict[str, Any]] = None

    def generate(self, prompts: Sequence,
                 sampling_params=None) -> List[Dict[str, Any]]:
        """Generate a completion for every prompt; returns one result dict
        per prompt (``tokens``, ``n_new``, ``acceptance_length``, ...) in
        prompt order."""
        n = len(prompts)
        if sampling_params is None or isinstance(sampling_params,
                                                 SamplingParams):
            sampling_params = [sampling_params] * n
        if len(sampling_params) != n:
            raise ValueError(
                f"{len(sampling_params)} sampling_params for {n} prompts")
        reqs = [Request(p, sampling=sp)
                for p, sp in zip(prompts, sampling_params)]
        order = {r.rid: i for i, r in enumerate(reqs)}
        self.last_report = self.scheduler.serve(reqs)
        return sorted(self.last_report["results"],
                      key=lambda res: order[res["rid"]])


def serve_round_based(engine: Engine, prompts: Sequence,
                      budgets: Optional[Sequence[int]] = None,
                      batch: Optional[int] = None) -> Dict[str, Any]:
    """The pre-scheduler baseline (previously examples/serve_batched.py's
    ``serve_queue``): fixed batch slots, queue refilled only *between* full
    generation rounds — a finished row idles until the round's slowest member
    drains. Honors per-request ``budgets`` (rows freeze on device at their
    own max_new, like HF-generate-style static batching with early stop) so
    benchmarks/table11_continuous.py compares the two disciplines on the
    same workload."""
    batch = batch or engine.batch
    default = engine.ecfg.max_new_tokens
    queue = [np.asarray(p, np.int32) for p in prompts]
    buds = list(budgets) if budgets is not None else [default] * len(queue)
    toks, rounds, al_num, al_den = 0, 0, 0, 0
    t0 = time.perf_counter()
    while queue:
        cur, queue = queue[:batch], queue[batch:]
        bud, buds = buds[:len(cur)], buds[len(cur):]
        n_real = len(cur)
        while len(cur) < batch:                  # pad final round
            cur.append(cur[-1])
            bud.append(0)                        # padded rows stay frozen
        state = engine.prefill(jnp.stack(cur))
        max_new = jnp.asarray(np.maximum(bud, 1), jnp.int32)
        it = 0
        while True:
            state = engine.step(state, max_new=max_new)
            it += 1
            if it % 4 == 0 or it < 2:
                nc = np.asarray(state["new_count"])
                if (nc >= np.asarray(bud))[:n_real].all():
                    break
        nc = np.asarray(state["new_count"])[:n_real]
        toks += int(np.minimum(nc, bud[:n_real]).sum())  # trim overshoot
        al_num += int(np.asarray(state["committed"]))
        al_den += max(int(np.asarray(state["row_iters"])), 1)
        rounds += 1
    wall = time.perf_counter() - t0
    return {
        "otps": toks / max(wall, 1e-9),
        "total_new_tokens": toks,
        "wall_s": wall,
        "mean_acceptance_length": al_num / max(al_den, 1),
        "rounds": rounds,
    }
