"""Per-slot continuous batching scheduler — the framework's request-lifecycle
layer over serving/engine.py (what vLLM's scheduler is to its model runner,
and what the paper's deployed-serving numbers §5.4 implicitly rely on).

Request lifecycle::

    QUEUED ──admit──► PREFILLING ──► DECODING ──EOS / budget──► FINISHED
              ▲                                      │
              └────────── slot freed, next request ──┘

The engine's decode state is a fixed-shape batch of B *slots*; every
speculative iteration steps all B rows under a per-slot active mask. When a
request finishes (per-request ``max_new_tokens`` budget or EOS), its slot is
freed *immediately* — mid-stream — and the next queued request is prefilled
straight into the live batch (``Engine.prefill_into_slot``), not held until
the whole batch drains. This is what separates continuous batching from the
old round-based ``serve_round_based`` baseline, which refills only between
full generation rounds and so pays the max-straggler latency every round.

Row independence is the correctness backbone: attention, cache updates, and
verification are all per-row, so admitting into slot *i* cannot change what
slot *j* emits (tests/test_scheduler.py asserts this token-for-token; note
MoE targets with capacity-based routing couple rows and are excluded from
that guarantee).

Termination is host-driven: after each iteration the scheduler reads back
the small per-slot counters plus newly committed tokens, detects per-request
EOS (output trimmed at the first EOS, vLLM semantics) and budget exhaustion,
and retires slots. Speculative commits can overshoot a budget by up to K;
overshoot tokens are trimmed from the emitted output.

Quickstart::

    eng = Engine(tcfg, dcfg, tparams, dparams, EngineConfig(...), batch=4)
    sched = Scheduler(eng, eos_id=None)
    report = sched.serve([Request(prompt) for prompt in prompts])
    report["otps"], report["results"][0]["tokens"], ...
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.engine import Engine

QUEUED = "queued"
PREFILLING = "prefilling"
DECODING = "decoding"
FINISHED = "finished"

_rid_counter = itertools.count()


@dataclass
class Request:
    """One generation request. ``prompt`` is a 1-D int32 token array; the
    prefill commits the first generated token, which counts toward
    ``max_new_tokens`` (None = the engine's default budget)."""
    prompt: Any
    max_new_tokens: Optional[int] = None
    rid: int = field(default_factory=lambda: next(_rid_counter))
    # lifecycle (managed by the scheduler)
    status: str = QUEUED
    slot: Optional[int] = None
    out_tokens: List[int] = field(default_factory=list)
    # metrics
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_finish: float = 0.0
    iters: int = 0                 # decode iterations this request was live
    # internal bookkeeping
    _prev_new: int = 0             # device-side new_count at last sync
    _prev_last: int = 0            # device-side last position at last sync

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError("empty prompt")

    @property
    def acceptance_length(self) -> float:
        """Mean tokens committed per decode iteration (prefill token
        excluded) — the paper's AL, per request."""
        return (self._prev_new - 1) / max(self.iters, 1)


class Scheduler:
    """Continuous-batching loop over an Engine's B slots.

    ``eos_id`` — token id that terminates a request (output trimmed at the
    first occurrence, which the losslessness tests rely on being identical
    across drafter modes). ``free_on_finish`` — blank freed slots' cache rows
    (optional; admission fully overwrites a slot either way).

    ``sync_every`` — speculative iterations dispatched between host syncs.
    1 gives the most responsive admission/EOS handling; higher values let jax
    pipeline dispatch (the whole-batch Engine.run polls every 8) at the cost
    of slots idling up to sync_every-1 iterations after finishing. Outputs
    are identical either way: per-slot budgets freeze rows ON DEVICE, and
    EOS/budget trimming is positional, not timing-dependent.
    """

    def __init__(self, engine: Engine, eos_id: Optional[int] = None,
                 free_on_finish: bool = True, sync_every: int = 1):
        self.engine = engine
        self.eos_id = eos_id
        self.free_on_finish = free_on_finish
        self.sync_every = max(int(sync_every), 1)
        if engine.tcfg.family in ("vlm", "encdec"):
            raise NotImplementedError(
                "per-slot admission needs per-request extras; vlm/encdec "
                "targets are not yet supported by the scheduler")

    # ------------------------------------------------------------------
    def serve(self, requests: Sequence, rng: Optional[jax.Array] = None,
              max_iters: int = 100_000) -> Dict[str, Any]:
        """Run every request to completion; returns aggregate + per-request
        metrics. ``requests`` entries may be Request objects or raw prompt
        arrays (coerced with the engine's default budget)."""
        eng = self.engine
        B = eng.batch
        default_budget = eng.ecfg.max_new_tokens

        reqs = [r if isinstance(r, Request) else Request(r) for r in requests]
        t_start = time.perf_counter()
        for r in reqs:
            if r.status != QUEUED:
                raise ValueError(
                    f"request {r.rid} is {r.status}; Request objects are "
                    "single-use — submit a fresh one")
            r.t_submit = t_start
            if r.max_new_tokens is None:
                r.max_new_tokens = default_budget
            # prompt + budget + worst-case speculative overshoot must fit the
            # cache, else the slot could never reach its budget
            need = (r.prompt.size + eng.pos_offset + r.max_new_tokens
                    + eng.ecfg.K + 1)
            if need > eng.ecfg.max_len:
                raise ValueError(
                    f"request {r.rid}: prompt {r.prompt.size} + "
                    f"max_new_tokens {r.max_new_tokens} (+K overshoot) "
                    f"exceeds max_len {eng.ecfg.max_len}")
            if eng.paged:
                n = eng.pages_needed(r.prompt.size, r.max_new_tokens)
                if n > eng.pool_pages:
                    raise ValueError(
                        f"request {r.rid}: needs {n} KV pages but the pool "
                        f"only has {eng.pool_pages}; it could never be "
                        "admitted")
        queue = deque(reqs)

        state = eng.blank_state(rng)
        active = np.zeros((B,), bool)
        max_new = np.zeros((B,), np.int32)
        slot_req: List[Optional[Request]] = [None] * B
        finished: List[Request] = []
        n_iters = 0

        def finish(s: int):
            req = slot_req[s]
            req.status = FINISHED
            req.t_finish = time.perf_counter()
            active[s] = False
            slot_req[s] = None
            finished.append(req)
            # paged engines MUST free (pages return to the pool); contiguous
            # freeing is cosmetic and stays opt-out
            if self.free_on_finish or eng.paged:
                nonlocal state
                state = eng.free_slot(state, s)

        def clip_and_check_done(req: Request) -> bool:
            """Trim at EOS / budget; True when the request is complete."""
            out = req.out_tokens
            done = False
            if self.eos_id is not None and self.eos_id in out:
                del out[out.index(self.eos_id) + 1:]
                done = True
            if len(out) >= req.max_new_tokens:
                del out[req.max_new_tokens:]     # speculative overshoot
                done = True
            return done

        while queue or active.any():
            # ---- admission: prefill queued requests into free slots -------
            # (FIFO: when the head request doesn't fit the page pool we wait
            # for frees rather than admit around it)
            for s in range(B):
                if active[s] or not queue:
                    continue
                if not eng.can_admit(queue[0].prompt.size,
                                     queue[0].max_new_tokens):
                    break
                req = queue.popleft()
                req.status = PREFILLING
                req.slot = s
                req.t_admit = time.perf_counter()
                state, first, last = eng.prefill_into_slot(
                    state, req.prompt, s, max_new=req.max_new_tokens)
                req.out_tokens.append(first)
                req._prev_new, req._prev_last = 1, last
                req.status = DECODING
                slot_req[s] = req
                active[s] = True
                max_new[s] = req.max_new_tokens
                if clip_and_check_done(req):     # EOS at the very first token
                    finish(s)

            if not active.any():
                if queue and not eng.can_admit(queue[0].prompt.size,
                                               queue[0].max_new_tokens):
                    raise RuntimeError(
                        "no active slot and the head request cannot be "
                        "admitted — page pool leak?")
                continue                         # everything died at prefill

            # ---- speculative iterations over all live slots ---------------
            # (several per sync when sync_every > 1 — jax pipelines the
            # dispatches; budget freezes happen on device regardless)
            act_dev, mn_dev = jnp.asarray(active), jnp.asarray(max_new)
            for _ in range(self.sync_every):
                state = eng.step(state, act_dev, mn_dev)
                n_iters += 1
            if n_iters > max_iters:
                raise RuntimeError("scheduler exceeded max_iters")

            # ---- sync: harvest newly committed tokens, retire slots -------
            new_count = np.asarray(state["new_count"])
            slot_iters = np.asarray(state["slot_iters"])
            last = np.asarray(state["last"])
            tokens = np.asarray(state["tokens"])
            for s in range(B):
                req = slot_req[s]
                if req is None or not active[s]:
                    continue
                req.iters = int(slot_iters[s])   # device-exact (freeze-aware)
                if new_count[s] > req._prev_new:
                    req.out_tokens.extend(
                        tokens[s, req._prev_last + 1:last[s] + 1].tolist())
                    req._prev_new = int(new_count[s])
                    req._prev_last = int(last[s])
                if clip_and_check_done(req):
                    finish(s)

        wall = time.perf_counter() - t_start
        return self._report(finished, wall, n_iters)

    # ------------------------------------------------------------------
    def _report(self, finished: List[Request], wall: float,
                n_iters: int) -> Dict[str, Any]:
        results = [{
            "rid": r.rid,
            "tokens": np.asarray(r.out_tokens, np.int32),
            "n_new": len(r.out_tokens),
            "iters": r.iters,
            "acceptance_length": r.acceptance_length,
            "wait_s": r.t_admit - r.t_submit,
            "latency_s": r.t_finish - r.t_submit,
        } for r in sorted(finished, key=lambda r: r.rid)]
        total = sum(r["n_new"] for r in results)
        return {
            "results": results,
            "n_requests": len(results),
            "iterations": n_iters,
            "total_new_tokens": total,
            "wall_s": wall,
            "otps": total / max(wall, 1e-9),
            "mean_acceptance_length": float(np.mean(
                [r["acceptance_length"] for r in results])) if results else 0.0,
            "mean_latency_s": float(np.mean(
                [r["latency_s"] for r in results])) if results else 0.0,
        }


def serve_round_based(engine: Engine, prompts: Sequence,
                      budgets: Optional[Sequence[int]] = None,
                      batch: Optional[int] = None) -> Dict[str, Any]:
    """The pre-scheduler baseline (previously examples/serve_batched.py's
    ``serve_queue``): fixed batch slots, queue refilled only *between* full
    generation rounds — a finished row idles until the round's slowest member
    drains. Honors per-request ``budgets`` (rows freeze on device at their
    own max_new, like HF-generate-style static batching with early stop) so
    benchmarks/table11_continuous.py compares the two disciplines on the
    same workload."""
    batch = batch or engine.batch
    default = engine.ecfg.max_new_tokens
    queue = [np.asarray(p, np.int32) for p in prompts]
    buds = list(budgets) if budgets is not None else [default] * len(queue)
    toks, rounds, al_num, al_den = 0, 0, 0, 0
    t0 = time.perf_counter()
    while queue:
        cur, queue = queue[:batch], queue[batch:]
        bud, buds = buds[:len(cur)], buds[len(cur):]
        n_real = len(cur)
        while len(cur) < batch:                  # pad final round
            cur.append(cur[-1])
            bud.append(0)                        # padded rows stay frozen
        state = engine.prefill(jnp.stack(cur))
        max_new = jnp.asarray(np.maximum(bud, 1), jnp.int32)
        it = 0
        while True:
            state = engine.step(state, max_new=max_new)
            it += 1
            if it % 4 == 0 or it < 2:
                nc = np.asarray(state["new_count"])
                if (nc >= np.asarray(bud))[:n_real].all():
                    break
        nc = np.asarray(state["new_count"])[:n_real]
        toks += int(np.minimum(nc, bud[:n_real]).sum())  # trim overshoot
        al_num += int(np.asarray(state["committed"]))
        al_den += max(int(np.asarray(state["row_iters"])), 1)
        rounds += 1
    wall = time.perf_counter() - t0
    return {
        "otps": toks / max(wall, 1e-9),
        "total_new_tokens": toks,
        "wall_s": wall,
        "mean_acceptance_length": al_num / max(al_den, 1),
        "rounds": rounds,
    }
