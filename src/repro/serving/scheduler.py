"""Event-driven continuous-batching scheduler — the framework's
request-lifecycle layer over serving/engine.py (what vLLM's scheduler is to
its model runner, and what the paper's deployed-serving numbers §5.4
implicitly rely on).

Request lifecycle::

    QUEUED ──arrive──► (eligible) ──admit──► PREFILLING ──► DECODING ──┐
      ▲                                          ▲                    │
      │                                          │            EOS / budget
      └────────── preempted (pages freed, ───────┘                    │
                  tokens kept host-side)                          FINISHED

    (any state before FINISHED) ──abort──► ABORTED   [streaming driver:
    pages freed immediately, partial output retained host-side]

The loop core (admit → grow → dispatch → harvest) is a set of Scheduler
methods shared by TWO drivers: the deterministic virtual-clock ``serve()``
below, and the wall-clock ``serving/streaming.AsyncEngine`` that streams
``(token, logprob)`` pairs as syncs commit. Every losslessness/churn
property pinned against ``serve()`` therefore exercises the streaming
path's scheduling logic too — the drivers differ only in who advances the
clock and who consumes the emit buffer.

The engine's decode state is a fixed-shape batch of B *slots*; every
speculative iteration steps all B rows under a per-slot active mask. When a
request finishes (per-request ``max_new_tokens`` budget or EOS), its slot is
freed *immediately* — mid-stream — and the next eligible request is prefilled
straight into the live batch (``Engine.prefill_into_slot``), not held until
the whole batch drains.

Arrival times and the virtual clock
-----------------------------------
Requests carry an ``arrival_time`` (virtual time units). The scheduler runs a
deterministic, step-cost-driven **virtual clock**: every dispatched
speculative iteration advances it by ``iter_cost``, every admission prefill
by ``prefill_cost``, and when nothing is live the clock jumps to the next
arrival. No request is admitted before its arrival; among arrived requests
admission is FIFO by ``(arrival_time, submission order)`` with head-of-line
blocking (when the head doesn't fit the page pool the scheduler waits for
frees — or preempts — rather than admitting around it). Because the clock is
derived from step counts, not wall time, async traces replay bit-identically
on CPU test runs; wall-clock metrics are kept alongside for throughput.

Preemption (paged layout)
-------------------------
Under incremental page growth (``EngineConfig(kv_growth="incremental")``) a
slot claims pages only as its length crosses page boundaries, so the pool can
genuinely run out mid-decode. When growth fails — or when the queue head
would starve behind lower-priority runners — the lowest-priority running slot
(latest ``(arrival_time, submission)``) is evicted: its pages return to the
pool and its prompt + generated tokens are retained host-side. It is later
re-admitted by **recompute-prefill** (prompt + generated-so-far becomes the
new prefill), token-for-token losslessly for EVERY decoding policy: greedy
speculative output is a pure function of the prefix, and a seeded sampled
request's continuation is a pure function of ``(seed, prefix)`` — its
per-step keys are ``fold_in(seed, position)`` counters, re-derived over the
recomputed prefix (the resume prefill rebuilds the eviction's exact
step-boundary state and commits nothing new; serving/sampling.py).
tests/test_async_serving.py pins both, per family. Re-admission of a
preempted request gates on its *full* remaining need so the same pressure
cannot immediately re-evict it. With ``EngineConfig(swap="host")`` an
eviction instead parks the victim's pages + per-slot rows in a host-side
pool and the resume is a bitwise device scatter (no prefill re-paid); the
scheduler falls back to recompute-prefill per eviction whenever the host
pool is full or the bytes-moved cost model says recompute is cheaper —
see the ``swap`` knobs below, tests/test_swap.py, and docs/serving.md.

Row independence is the correctness backbone: attention, cache updates, and
verification are all per-row, so admitting into slot *i* cannot change what
slot *j* emits (tests/test_scheduler.py asserts this token-for-token; note
MoE targets with capacity-based routing couple rows and are excluded from
that guarantee).

Termination is host-driven: after each iteration the scheduler reads back
the small per-slot counters plus newly committed tokens, detects per-request
EOS (output trimmed at the first EOS, vLLM semantics) and budget exhaustion,
and retires slots. Speculative commits can overshoot a budget by up to K;
overshoot tokens are trimmed from the emitted output.

The scheduler is device-layout agnostic: it only ever calls the Engine's
jitted entry points and reads back small replicated counters, so a
model-sharded engine (``EngineConfig(shard_model=True)`` — weights and KV
page pools storage-sharded over a device mesh, docs/sharding.md) slots in
with zero changes here and identical token streams (pinned by the sharded
cases in tests/test_serving.py and tests/test_async_serving.py).

Quickstart::

    eng = Engine(tcfg, dcfg, tparams, dparams, EngineConfig(...), batch=4)
    sched = Scheduler(eng, eos_id=None)
    report = sched.serve([Request(p, arrival_time=t) for p, t in work])
    report["otps"], report["p99_latency_vt"], report["results"][0]["tokens"]
"""
from __future__ import annotations

import bisect
import itertools
import time
import zlib
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import make_extras
from repro.serving.engine import Engine
from repro.serving.sampling import SamplingParams
from repro.serving.speculation import SpeculationConfig, SpeculationController

QUEUED = "queued"
PREFILLING = "prefilling"
DECODING = "decoding"
FINISHED = "finished"
ABORTED = "aborted"

_rid_counter = itertools.count()


@dataclass(eq=False)          # identity semantics: requests hold numpy
class Request:                # arrays, and membership tests (abort from
                              # the wait queue) must mean THIS request
    """One generation request. ``prompt`` is a 1-D int32 token array; the
    prefill commits the first generated token, which counts toward
    ``max_new_tokens`` (None = the engine's default budget).

    ``sampling`` is the request's decoding policy (temperature / top-k /
    top-p / seed / stop tokens — serving/sampling.SamplingParams); None
    falls back to the engine default (``EngineConfig.sampling``, greedy
    unless configured otherwise). A batch may freely mix greedy and sampled
    requests: policy is a per-slot row of the device state, not an engine
    mode. Budget precedence: ``max_new_tokens`` here, else
    ``sampling.max_new_tokens``, else the engine default.

    ``arrival_time`` is in virtual time units — the scheduler will not admit
    the request before its arrival. ``extras`` carries per-request modality
    inputs (vision embeds / encoder embeds, leading batch axis 1, as built
    by ``models.make_extras(cfg, 1, "prefill", key)``); for vlm/encdec
    targets without explicit extras a deterministic stub (keyed by the
    prompt bytes) is synthesized at admission."""
    prompt: Any
    max_new_tokens: Optional[int] = None
    arrival_time: float = 0.0
    extras: Optional[dict] = None
    sampling: Optional[SamplingParams] = None
    rid: int = field(default_factory=lambda: next(_rid_counter))
    # lifecycle (managed by the scheduler)
    status: str = QUEUED
    slot: Optional[int] = None
    out_tokens: List[int] = field(default_factory=list)
    # raw-target logprob of each out_tokens entry (engine._token_logprob
    # convention), maintained in lockstep with out_tokens
    out_logprobs: List[float] = field(default_factory=list)
    # metrics
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_finish: float = 0.0
    vt_admit: Optional[float] = None   # virtual clock at first admission
    vt_finish: float = 0.0
    n_preempt: int = 0
    n_swap: int = 0                # preemptions that swapped to host (the
    #                                rest resumed by recompute-prefill)
    iters: int = 0                 # decode iterations this request was live
    cached_tokens: int = 0         # prompt positions served from the prefix
    #                                cache across all admissions (0 = cold)
    # internal bookkeeping
    _prev_new: int = 0             # device-side new_count at last sync
    _prev_last: int = 0            # device-side last position at last sync
    _iters_base: int = 0           # iters accumulated before the last resume
    _committed: int = 0            # tokens committed across all admissions
    _prefills: int = 0             # prefill-committed tokens (1 + resumes)
    _seq: int = 0                  # submission index (FIFO tie-break)
    _scanned: int = 0              # out_tokens prefix already stop-scanned
    _emitted: int = 0              # out_tokens prefix already streamed out
    _stop_set: Optional[frozenset] = None   # stop ids, frozen at submission

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError("empty prompt")
        if not (self.arrival_time >= 0.0 and np.isfinite(self.arrival_time)):
            raise ValueError(f"bad arrival_time {self.arrival_time!r}")

    @property
    def acceptance_length(self) -> float:
        """Mean tokens committed per decode iteration (prefill-committed
        tokens excluded, one per admission) — the paper's AL, per request."""
        return (self._committed - self._prefills) / max(self.iters, 1)


class Scheduler:
    """Event-driven continuous-batching loop over an Engine's B slots.

    ``eos_id`` — token id that terminates a request (output trimmed at the
    first occurrence, which the losslessness tests rely on being identical
    across drafter modes). ``free_on_finish`` — blank freed slots' cache rows
    (optional; admission fully overwrites a slot either way).

    ``sync_every`` — speculative iterations dispatched between host syncs.
    1 gives the most responsive admission/EOS handling; higher values let jax
    pipeline dispatch (the whole-batch Engine.run polls every 8) at the cost
    of slots idling up to sync_every-1 iterations after finishing, and of
    page growth reserving capacity for the whole block up front. Outputs
    are identical either way: per-slot budgets freeze rows ON DEVICE, and
    EOS/budget trimming is positional, not timing-dependent.

    ``iter_cost`` / ``prefill_cost`` — virtual-clock cost of one speculative
    iteration / one admission prefill. The defaults (1.0 each) make the clock
    an iteration counter; scale them to calibrated step times to model a
    specific accelerator without losing determinism.

    ``preempt`` — evict the lowest-priority running slot when the page pool
    is exhausted (growth failure or queue-head starvation), resuming later by
    recompute-prefill (default: enabled). The resume is token-for-token
    lossless for every decoding policy: greedy continuation is a pure
    function of the prefix, and seeded sampling re-derives its per-step keys
    from ``fold_in(seed, position)`` over the recomputed prefix
    (``Engine.prefill_into_slot(resume=True)`` restarts verification at the
    exact step boundary the eviction stopped at). ``preempt=False`` stalls
    slots on pool exhaustion instead.

    ``swap`` — swap-to-host preemption (defaults to the engine's
    ``EngineConfig(swap=...)`` setting): an eviction copies the victim's
    pages + per-slot rows to the engine's host pool and the resume becomes
    a device scatter (``Engine.swap_in_slot``) instead of a
    recompute-prefill — bitwise the eviction-time state, so streams are
    unchanged. Per eviction the scheduler picks swap only when the
    bytes-moved cost model says it beats recomputing the prefix —
    ``2 * bytes * swap_cost_per_byte <= prefill_cost +
    prefill_cost_per_token * prefix_tokens`` — AND the host pool can hold
    the snapshot; otherwise (host pool exhausted, or short cheap prefixes)
    it falls back to recompute-prefill, losslessly. ``swap_cost_per_byte``
    / ``prefill_cost_per_token`` extend the virtual clock the same way:
    swap-out/in advance it by bytes moved, admission prefills by
    ``prefill_cost + per-token * prefix`` (both default 0.0 extra —
    existing traces replay bitwise).

    ``adaptive_k`` — per-request dynamic draft length
    (serving/speculation.py): ``True`` enables the
    :class:`SpeculationController` with default knobs, a
    :class:`SpeculationConfig` enables it with those knobs, ``None``/
    ``False`` keeps the fixed ``EngineConfig.K`` (bitwise the
    pre-controller scheduler). When enabled, each request's acceptance EMA
    — keyed by rid, surviving preemption — sets its ``k_row`` at admission
    and at every harvest, and incremental page growth reserves the
    per-row ``k_row + 1`` commit stride instead of the worst-case
    ``K + 1`` (the pool-pressure win). Streams are unchanged for greedy
    requests and stay bitwise deterministic for sampled ones: ``k_row``
    is a pure function of the request's own committed stream.
    """

    def __init__(self, engine: Engine, eos_id: Optional[int] = None,
                 free_on_finish: bool = True, sync_every: int = 1,
                 iter_cost: float = 1.0, prefill_cost: float = 1.0,
                 preempt: Optional[bool] = None,
                 adaptive_k: Any = None,
                 swap: Optional[bool] = None,
                 swap_cost_per_byte: float = 0.0,
                 prefill_cost_per_token: float = 0.0):
        self.engine = engine
        self.eos_id = eos_id
        self.free_on_finish = free_on_finish
        self.sync_every = max(int(sync_every), 1)
        self.iter_cost = float(iter_cost)
        self.prefill_cost = float(prefill_cost)
        self.preempt = True if preempt is None else bool(preempt)
        self.swap = (engine.swap_enabled if swap is None else bool(swap))
        if self.swap and not engine.swap_enabled:
            raise ValueError(
                "Scheduler(swap=True) needs EngineConfig(swap='host')")
        self.swap_cost_per_byte = float(swap_cost_per_byte)
        self.prefill_cost_per_token = float(prefill_cost_per_token)
        if adaptive_k is None or adaptive_k is False:
            self.spec: Optional[SpeculationController] = None
        elif isinstance(adaptive_k, SpeculationController):
            self.spec = adaptive_k
        else:
            cfg = adaptive_k if isinstance(adaptive_k, SpeculationConfig) \
                else None
            self.spec = SpeculationController(engine.ecfg.K, cfg)
        # session state (created by _begin_session; one live session per
        # Scheduler — serve() and a streaming.AsyncEngine each own theirs)
        self._wall_t0: Optional[float] = None

    # ------------------------------------------------------------------
    # shared loop core — the step/admit/preempt/harvest machinery both
    # drivers call: the deterministic virtual-clock serve() below and the
    # wall-clock streaming.AsyncEngine. Session state lives on the
    # instance between _begin_session() and _end_session(); the only
    # driver-visible difference is who advances self._clock (_advance).
    # ------------------------------------------------------------------
    def _prio(self, r: Request) -> Tuple[float, int]:
        return (r.arrival_time, r._seq)

    @staticmethod
    def _committed_stream(req: Request) -> np.ndarray:
        """prompt + emitted tokens — what a freed slot's pages verifiably
        hold; the engine's prefix cache indexes its full pages so later
        requests (or this one's resume) admit against them."""
        return np.concatenate(
            [req.prompt, np.asarray(req.out_tokens, np.int32)])

    def _begin_session(self) -> None:
        eng = self.engine
        B = eng.batch
        # a prefix-cache engine resumes from the previous session's pool
        # (cached page content lives in the state arrays); otherwise blank
        self._state = eng.serve_state()
        self._active = np.zeros((B,), bool)
        self._max_new = np.zeros((B,), np.int32)
        # per-slot effective draft length (adaptive-K max-K mask); full K
        # when the controller is off — bitwise the pre-adaptive step
        self._k_row = np.full((B,), eng.ecfg.K, np.int32)
        self._slot_req: List[Optional[Request]] = [None] * B
        self._waiting: List[Request] = []     # arrived, sorted by _prio
        self._finished: List[Request] = []    # completed AND aborted
        self._events: List[Tuple[float, str, int]] = []
        self._emit: List[Tuple[Request, List[int], List[float]]] = []
        self._clock = 0.0
        self._n_iters = 0
        self._n_preempt = 0
        self._n_swap = 0            # swap-to-host evictions
        self._n_recompute = 0       # recompute-prefill evictions
        self._n_swap_drop = 0       # handles dropped for pressure relief
        self._recomputed_tokens = 0  # prefix tokens re-fed by resume
        #                              prefills (net of prefix-cache hits)
        self._next_seq = 0
        self._wall_t0 = None        # None → virtual clock (_advance adds)
        self._t_start = time.perf_counter()

    def _advance(self, cost: float) -> None:
        """Advance the session clock past one unit of work: virtual
        sessions add the deterministic step cost; wall sessions re-read
        elapsed real time (the cost argument is a fiction there)."""
        if self._wall_t0 is None:
            self._clock += cost
        else:
            self._clock = time.perf_counter() - self._wall_t0

    def _event(self, kind: str, rid: int, t: Optional[float] = None) -> None:
        """Append to the event trace, keeping it sorted by time. Almost
        every event is stamped at the current clock (monotone appends); an
        out-of-order stamp — an arrival whose time the idle clock already
        jumped past — is insorted so the trace stays non-decreasing
        (pinned by tests/test_async_serving.py)."""
        t = self._clock if t is None else t
        ev = (t, kind, rid)
        if self._events and t < self._events[-1][0]:
            bisect.insort(self._events, ev, key=lambda e: e[0])
        else:
            self._events.append(ev)

    def _prepare(self, r: Request, t_submit: Optional[float] = None) -> None:
        """Validate + default-fill one request and assign its FIFO sequence
        number. Raises ValueError before any state is touched."""
        eng = self.engine
        if r.status != QUEUED or r.out_tokens:
            raise ValueError(
                f"request {r.rid} is {r.status}; Request objects are "
                "single-use — submit a fresh one")
        if r.sampling is None:
            r.sampling = eng.ecfg.sampling
        if r.max_new_tokens is None:
            r.max_new_tokens = (r.sampling.max_new_tokens
                                if r.sampling.max_new_tokens is not None
                                else eng.ecfg.max_new_tokens)
        # prompt + budget + worst-case speculative overshoot must fit the
        # cache, else the slot could never reach its budget
        need = (r.prompt.size + eng.pos_offset + r.max_new_tokens
                + eng.ecfg.K + 1)
        if need > eng.ecfg.max_len:
            raise ValueError(
                f"request {r.rid}: prompt {r.prompt.size} + "
                f"max_new_tokens {r.max_new_tokens} (+K overshoot) "
                f"exceeds max_len {eng.ecfg.max_len}")
        if eng.paged:
            n = eng.pages_needed(r.prompt.size, r.max_new_tokens)
            if n > eng.pool_pages:
                raise ValueError(
                    f"request {r.rid}: needs {n} KV pages but the pool "
                    f"only has {eng.pool_pages}; it could never be "
                    "admitted")
        r.t_submit = (time.perf_counter() if t_submit is None else t_submit)
        r._seq = self._next_seq
        self._next_seq += 1
        # freeze the stop set once — _clip_and_check_done runs per sync
        stops = set(r.sampling.stop_token_ids)
        if self.eos_id is not None:
            stops.add(self.eos_id)
        r._stop_set = frozenset(stops)

    def _flush(self, req: Request) -> None:
        """Queue newly FINAL tokens (scanned by _clip_and_check_done, so
        nothing past a stop token or budget — a later sync can never trim
        them) for the streaming driver. The batch driver discards the
        buffer each pass."""
        if len(req.out_tokens) > req._emitted:
            self._emit.append((req, req.out_tokens[req._emitted:],
                               req.out_logprobs[req._emitted:]))
            req._emitted = len(req.out_tokens)

    def _finish_slot(self, s: int) -> None:
        eng = self.engine
        req = self._slot_req[s]
        req.status = FINISHED
        # wall stamp AFTER device commit: both call sites sit downstream of
        # a blocking host readback of the request's committed tokens (the
        # harvest np.asarray / the admission prefill's last-position read),
        # so sync_every pipelining can't leave the stamped work in flight
        req.t_finish = time.perf_counter()
        req.vt_finish = self._clock
        self._active[s] = False
        self._slot_req[s] = None
        self._finished.append(req)
        if self.spec is not None:
            self.spec.finish(req.rid)
        self._event("finish", req.rid)
        # paged engines MUST free (pages return to the pool); contiguous
        # freeing is cosmetic and stays opt-out
        if self.free_on_finish or eng.paged:
            self._state = eng.free_slot(
                self._state, s, final_tokens=self._committed_stream(req))

    def _abort(self, req: Request) -> bool:
        """Cancel a request NOW: a queued request leaves the wait queue; a
        running one has its slot freed immediately — pages return to the
        pool (free_slot), already-harvested tokens stay valid host-side.
        Returns False when the request already finished/aborted (too late
        to cancel). Only the streaming driver calls this; the batch
        serve() has no cancellation surface."""
        if req.status in (FINISHED, ABORTED):
            return False
        if req.slot is not None:
            s = req.slot
            self._active[s] = False
            self._slot_req[s] = None
            self._state = self.engine.free_slot(
                self._state, s, final_tokens=self._committed_stream(req))
        elif req in self._waiting:
            self._waiting.remove(req)
        # a swapped-out request holds host-pool bytes (and resident page
        # references) while queued — release them NOW, not at drain
        self.engine.drop_swap(req.rid)
        req.status = ABORTED
        req.slot = None
        req.t_finish = time.perf_counter()
        req.vt_finish = self._clock
        self._finished.append(req)
        if self.spec is not None:
            self.spec.finish(req.rid)
        self._event("abort", req.rid)
        return True

    def _swap_beats_recompute(self, req: Request, s: int) -> bool:
        """Swap-vs-recompute policy for evicting slot ``s``: swap when the
        virtual cost of moving the snapshot's bytes BOTH ways is at most
        the cost of re-feeding the committed prefix through a resume
        prefill, and the host pool can actually hold it. With the default
        zero byte cost, swap always wins while the host pool has room —
        the cost model only bites once ``swap_cost_per_byte`` /
        ``prefill_cost_per_token`` are calibrated (table 19 does)."""
        eng = self.engine
        if not self.swap:
            return False
        est = eng.swap_bytes_estimate(s)
        if not eng.host_pool.can_store(est):
            return False        # host pool exhausted → recompute fallback
        prefix = req.prompt.size + len(req.out_tokens)
        return (2.0 * est * self.swap_cost_per_byte
                <= self.prefill_cost
                + self.prefill_cost_per_token * prefix)

    def _preempt_slot(self, s: int) -> None:
        """Evict slot s, re-queueing the request at its original priority.
        Two disciplines: swap-to-host (state parked in the engine's host
        pool, resume is a device scatter) when enabled and worth it under
        the bytes-vs-tokens cost model, else recompute-prefill (pages
        freed, prompt + generated tokens retained host-side, prefix
        re-fed at resume). Both are token-for-token lossless; the swap
        path additionally skips re-paying the prefill FLOPs."""
        eng = self.engine
        req = self._slot_req[s]
        swapped = False
        if self._swap_beats_recompute(req, s):
            self._state, swapped = eng.swap_out_slot(self._state, s, req.rid)
        req.status = QUEUED
        req.slot = None
        req.n_preempt += 1
        req._iters_base = req.iters
        self._n_preempt += 1
        self._active[s] = False
        self._slot_req[s] = None
        if swapped:
            req.n_swap += 1
            self._n_swap += 1
            self._advance(self.swap_cost_per_byte * eng.swap_last_bytes)
            self._event("swap_out", req.rid)
        else:
            self._n_recompute += 1
            self._state = eng.free_slot(
                self._state, s, final_tokens=self._committed_stream(req))
            self._event("preempt", req.rid)
        bisect.insort(self._waiting, req, key=self._prio)

    def _drop_one_swap(self, exclude: Optional[Request] = None) -> bool:
        """Pressure relief of last resort. A swap handle pins its resident
        (cache-shared) pages at refcount >= 2, where a recompute eviction
        would have left them evictable — so a device pool wedged behind
        swapped prefixes must degrade to the recompute discipline, never
        deadlock: drop the LOWEST-priority swapped handle (that request
        resumes by recompute-prefill, still lossless) and let the caller
        re-try admission/growth. Returns False when nothing is droppable."""
        eng = self.engine
        cands = [r for r in self._waiting
                 if r is not exclude and eng.has_swap(r.rid)]
        if not cands:
            return False
        victim = max(cands, key=self._prio)
        eng.drop_swap(victim.rid)
        self._n_swap_drop += 1
        self._event("swap_drop", victim.rid)
        return True

    def _lowest_prio_active(self) -> Optional[int]:
        live = [s for s in range(self.engine.batch) if self._active[s]]
        if not live:
            return None
        return max(live, key=lambda s: self._prio(self._slot_req[s]))

    def _head_admissible(self, req: Request) -> bool:
        # resumed requests gate on their full remaining need (anti-
        # thrash: a victim must not be re-evicted by the pressure that
        # evicted it); fresh ones on the initial claim only. The
        # admission prompt is passed along so a prefix-cache engine
        # gates on the EFFECTIVE need — pages the prompt will map from
        # the cache never touch the free list. ``resume`` mirrors the
        # prefill_into_slot flag so the gate prices the exact claim (a
        # no-commit sampled resume needs one position less —
        # Engine.initial_pages)
        eng = self.engine
        plen = req.prompt.size + len(req.out_tokens)
        rem = req.max_new_tokens - len(req.out_tokens)
        if eng.has_swap(req.rid):
            # swapped resume: priced at its DEVICE-page need only — fresh
            # pages for the host spans (+ remaining lifetime growth under
            # the full gate); resident pages are already on device
            return eng.can_swap_in(req.rid, plen, rem,
                                   full=req.n_preempt > 0)
        stream = req.prompt
        resume = False
        if req.out_tokens:
            stream = self._committed_stream(req)
            if not req.sampling.is_greedy:
                stream = stream[:-1]   # sampled resume prefills [:-1]
                resume = True
        return eng.can_admit(plen, rem, full=req.n_preempt > 0,
                             tokens=stream, resume=resume)

    def _clip_and_check_done(self, req: Request) -> bool:
        """Trim at the first stop token (scheduler ``eos_id`` or the
        request's ``SamplingParams.stop_token_ids``) / budget; True when
        the request is complete.

        Incremental: only tokens appended since the previous call are
        scanned (the ``req._scanned`` cursor) — a stop token can never
        survive an earlier scan, so this equals the full rescan at O(n)
        total work per stream instead of O(n²). It is also what makes
        streaming sound: every position below ``_scanned`` is FINAL
        (no later sync trims at or before it), so _flush may emit exactly
        that prefix and never retract a token."""
        out = req.out_tokens
        done = False
        for i in range(req._scanned, len(out)):
            if out[i] in req._stop_set:
                del out[i + 1:]
                del req.out_logprobs[i + 1:]
                done = True
                break
        if len(out) >= req.max_new_tokens:
            del out[req.max_new_tokens:]         # speculative overshoot
            del req.out_logprobs[req.max_new_tokens:]
            done = True
        req._scanned = len(out)
        return done

    def _swap_admit(self, req: Request, s: int) -> None:
        """Resume a swapped-out request: scatter its host snapshot back
        into (empty) slot ``s`` — no prefill, no re-sampling, the restored
        state is bitwise the eviction-time step boundary for every
        decoding policy. Mirrors the resume conventions of ``_admit``:
        committed counters restart at 0 against the remaining budget."""
        eng = self.engine
        remaining = req.max_new_tokens - len(req.out_tokens)
        req.status = PREFILLING
        req.slot = s
        self._state, last = eng.swap_in_slot(self._state, s, req.rid)
        self._advance(self.swap_cost_per_byte * eng.swap_last_bytes)
        self._event("swap_in", req.rid)
        req._prev_new, req._prev_last = 0, last
        req.status = DECODING
        self._slot_req[s] = req
        self._active[s] = True
        self._max_new[s] = remaining
        if self.spec is not None:
            self._k_row[s] = self.spec.k_for(req.rid)

    def _admit(self, req: Request, s: int) -> None:
        eng = self.engine
        if eng.has_swap(req.rid):
            self._swap_admit(req, s)
            return
        # recompute-prefill resume: the prefix is prompt + everything
        # generated before eviction. Greedy continuation from that
        # prefix is exactly the uninterrupted stream (the prefill's
        # argmax commit equals the verify path's token); a sampled
        # request instead resumes via resume=True — the prefill rebuilds
        # the eviction's step-boundary state and commits nothing new, so
        # the next step restarts seeded verification at the same
        # committed prefix — and fold_in key — the uninterrupted run's
        # step boundary had
        prompt = (self._committed_stream(req) if req.out_tokens
                  else req.prompt)
        resume = bool(req.out_tokens) and not req.sampling.is_greedy
        remaining = req.max_new_tokens - len(req.out_tokens)
        req.status = PREFILLING
        req.slot = s
        first_admission = req.vt_admit is None
        if first_admission:
            req.vt_admit = self._clock
        extras = req.extras
        if extras is None and eng.tcfg.family in ("vlm", "encdec"):
            # deterministic stub frontend inputs keyed by the PROMPT
            # (not the process-global rid), so re-serving the same
            # workload with fresh Request objects replays identical
            # extras; cached on the request so a preemption resume
            # (longer recompute prompt) also replays them
            seed = zlib.crc32(req.prompt.tobytes()) & 0x7FFFFFFF
            extras = make_extras(eng.tcfg, 1, "prefill",
                                 jax.random.fold_in(jax.random.PRNGKey(0),
                                                    seed))
            req.extras = extras
        self._event("admit", req.rid)
        self._state, first, last = eng.prefill_into_slot(
            self._state, prompt, s, extras=extras, sampling=req.sampling,
            max_new=remaining, resume=resume)
        if first_admission:
            # wall stamp AFTER the prefill: prefill_into_slot's host
            # readback of the committed position sequences every queued
            # device dispatch before it, so t_admit marks work actually
            # committed, not an enqueue (the virtual vt_admit keeps the
            # admission-decision timestamp)
            req.t_admit = time.perf_counter()
        req.cached_tokens += eng.last_hit_tokens
        if req.n_preempt:
            # prefix positions this resume actually re-forwarded (net of
            # prefix-cache hits) — the FLOP bill swap-to-host avoids
            self._recomputed_tokens += max(
                int(prompt.size) - eng.last_hit_tokens, 0)
        self._advance(self.prefill_cost
                      + self.prefill_cost_per_token * int(prompt.size))
        if first is None:               # no-commit resume (sampled)
            req._prev_new, req._prev_last = 0, last
        else:
            req.out_tokens.append(first)
            req.out_logprobs.append(eng.last_logprob)
            req._committed += 1
            req._prefills += 1
            req._prev_new, req._prev_last = 1, last
        req.status = DECODING
        self._slot_req[s] = req
        self._active[s] = True
        self._max_new[s] = remaining
        if self.spec is not None:
            # rid-keyed: a resume continues from the acceptance state the
            # stream had at eviction, a fresh rid starts optimistic
            self._k_row[s] = self.spec.k_for(req.rid)
        done = self._clip_and_check_done(req)
        self._flush(req)
        if done:                         # EOS at the very first token
            self._finish_slot(s)

    def _admit_waiting(self) -> None:
        """Admit eligible requests into free slots, FIFO by (arrival,
        submission) with head-of-line blocking; preemption resolves
        starvation when the head outranks a runner. Free slots are
        recomputed per admission — a slot freed by a preemption (or an
        EOS-at-prefill) is reusable immediately, not after the next sync
        block."""
        B = self.engine.batch
        while self._waiting:
            free = [s for s in range(B) if not self._active[s]
                    and self._slot_req[s] is None]
            if not free:
                break
            head = self._waiting[0]
            if not self._head_admissible(head):
                if self.preempt:
                    while not self._head_admissible(head):
                        v = self._lowest_prio_active()
                        if v is None or (self._prio(self._slot_req[v])
                                         <= self._prio(head)):
                            break
                        self._preempt_slot(v)
                # swap handles pin resident pages a recompute eviction
                # would have released — drop lower-priority handles until
                # the head fits, so swap can only ever ADD admissible
                # schedules, never wedge one. (Dropping the head's OWN
                # handle never helps: a swapped resume needs at most the
                # pages its recompute twin would, so it stays excluded.)
                while (not self._head_admissible(head)
                       and self._drop_one_swap(exclude=head)):
                    pass
                if not self._head_admissible(head):
                    break                # head waits for frees (FIFO)
            self._admit(self._waiting.pop(0), free[0])

    def _grow(self) -> np.ndarray:
        """Capacity pass: grow each live slot to cover the coming sync
        block (incremental paged growth); on pool exhaustion preempt the
        lowest-priority slot, or stall when preemption is off. Returns the
        run mask; raises when nothing can step at all."""
        eng = self.engine
        B = eng.batch
        stalled = np.zeros((B,), bool)
        if eng.incremental:
            by_prio = sorted(np.flatnonzero(self._active),
                             key=lambda s: self._prio(self._slot_req[s]))
            for s in by_prio:
                if not self._active[s]:      # already evicted this pass
                    continue
                req = self._slot_req[s]
                cap = (req.prompt.size + eng.pos_offset
                       + req.max_new_tokens + eng.ecfg.K + 1)
                # a step at position c writes KV c..c+stride-1 and moves
                # c by at most stride, so sync_every steps need length
                # last + sync_every*stride, exactly. Under adaptive K the
                # row's stride is k_row + 1, not the worst-case K + 1 —
                # a hard row reserves (and can be preempted for) fewer
                # pages. Writes past the row's allocation are dropped by
                # scatter and equivalent to commit-invalidated entries,
                # so the shorter reservation stays bitwise lossless.
                if self.spec is not None \
                        and eng.ecfg.drafter_mode != "none":
                    stride = int(self._k_row[s]) + 1
                else:
                    stride = eng.commit_stride
                target = min(req._prev_last + self.sync_every * stride, cap)
                self._state, ok = eng.ensure_capacity(self._state, int(s),
                                                      target)
                while not ok and self.preempt:
                    v = self._lowest_prio_active()
                    self._preempt_slot(v)
                    if v == s:
                        break
                    self._state, ok = eng.ensure_capacity(self._state,
                                                          int(s), target)
                while not ok and self._active[s] \
                        and self._drop_one_swap():
                    # growth wedged behind handle-pinned pages: fall
                    # swapped waiters back to recompute and retry
                    self._state, ok = eng.ensure_capacity(self._state,
                                                          int(s), target)
                if not ok and self._active[s]:
                    stalled[s] = True        # retry once pages free up
        run = self._active & ~stalled
        if not run.any():
            raise RuntimeError(
                "page pool exhausted and every live slot is stalled; "
                "enable preemption (Scheduler(preempt=True)) or grow "
                "pool_pages")
        return run

    def _dispatch(self, run: np.ndarray) -> None:
        """sync_every speculative iterations over the live slots (jax
        pipelines the dispatches; budget freezes happen on device
        regardless)."""
        eng = self.engine
        act_dev, mn_dev = jnp.asarray(run), jnp.asarray(self._max_new)
        kr_dev = jnp.asarray(self._k_row)
        for _ in range(self.sync_every):
            self._state = eng.step(self._state, act_dev, mn_dev, kr_dev)
            self._n_iters += 1
            self._advance(self.iter_cost)

    def _harvest(self) -> None:
        """Read back the per-slot counters + newly committed tokens and
        logprobs, stop/budget-trim each stream (incremental scan), flush
        final tokens to the emit buffer, retire finished slots. The
        np.asarray readbacks block on every dispatched step, so wall
        stamps taken downstream mark committed work."""
        state = self._state
        new_count = np.asarray(state["new_count"])
        slot_iters = np.asarray(state["slot_iters"])
        last = np.asarray(state["last"])
        tokens = np.asarray(state["tokens"])
        logprobs = np.asarray(state["logprobs"])
        for s in range(self.engine.batch):
            req = self._slot_req[s]
            if req is None or not self._active[s]:
                continue
            prev_iters, prev_comm = req.iters, req._committed
            req.iters = req._iters_base + int(slot_iters[s])
            if new_count[s] > req._prev_new:
                lo, hi = req._prev_last + 1, last[s] + 1
                req.out_tokens.extend(tokens[s, lo:hi].tolist())
                req.out_logprobs.extend(
                    logprobs[s, lo:hi].astype(float).tolist())
                req._committed += int(new_count[s]) - req._prev_new
                req._prev_new = int(new_count[s])
                req._prev_last = int(last[s])
            if self.spec is not None:
                # fold THIS request's decode delta (committed tokens over
                # engine iterations since the last sync) into its
                # acceptance EMA and refresh the slot's draft length;
                # zero-iteration windows (frozen rows) carry no signal
                d_it = req.iters - prev_iters
                if d_it > 0:
                    self.spec.observe(req.rid, req._committed - prev_comm,
                                      d_it)
                    self._k_row[s] = self.spec.k_for(req.rid)
            done = self._clip_and_check_done(req)
            self._flush(req)
            if done:
                self._finish_slot(s)

    def _end_session(self, wall: float) -> Dict[str, Any]:
        # keep cached pages warm across serves
        self.engine.retain_state(self._state)
        return self._report(self._finished, wall, self._n_iters,
                            self._clock, self._events, self._n_preempt)

    # ------------------------------------------------------------------
    def serve(self, requests: Sequence,
              max_iters: int = 100_000) -> Dict[str, Any]:
        """Run every request to completion; returns aggregate + per-request
        metrics (wall-clock and virtual-time). ``requests`` entries may be
        Request objects or raw prompt arrays (coerced with the engine's
        default budget and sampling policy, arrival 0).

        This is the deterministic VIRTUAL-CLOCK driver of the shared loop
        core (admit → grow → dispatch → harvest); the wall-clock streaming
        twin is serving/streaming.AsyncEngine. Identical per-request token
        streams either way — row independence plus per-request seeded
        sampling make each stream a pure function of (prompt, policy),
        never of driver timing."""
        reqs = [r if isinstance(r, Request) else Request(r) for r in requests]
        self._begin_session()
        for r in reqs:
            self._prepare(r, t_submit=self._t_start)
        pending = deque(sorted(reqs, key=self._prio))   # not yet arrived

        while pending or self._waiting or self._active.any():
            # ---- arrivals: move everything whose time has come -----------
            # (the arrive event is stamped at the true arrival_time, which
            # the idle clock may already have jumped past — _event insorts
            # it so the trace stays time-sorted)
            while pending and pending[0].arrival_time <= self._clock + 1e-9:
                r = pending.popleft()
                bisect.insort(self._waiting, r, key=self._prio)
                self._event("arrive", r.rid, t=r.arrival_time)
            # ---- idle: nothing eligible, nothing running → jump the clock
            if not self._waiting and not self._active.any():
                self._clock = max(self._clock, pending[0].arrival_time)
                continue

            self._admit_waiting()
            if not self._active.any():
                if self._waiting:
                    raise RuntimeError(
                        "no active slot and the head request cannot be "
                        "admitted — page pool leak?")
                continue                     # everything died at prefill

            run = self._grow()
            self._dispatch(run)
            if self._n_iters > max_iters:
                raise RuntimeError("scheduler exceeded max_iters")
            self._harvest()
            self._emit.clear()               # batch driver: nobody streams

        wall = time.perf_counter() - self._t_start
        return self._end_session(wall)

    # ------------------------------------------------------------------
    def _report(self, finished: List[Request], wall: float, n_iters: int,
                makespan_vt: float, events: List[Tuple[float, str, int]],
                n_preempt: int) -> Dict[str, Any]:
        """Aggregate + per-request metrics. Clock columns, honestly:

        - ``*_s`` — HOST WALL stamps. t_admit is taken after the admission
          prefill's committed-position readback and t_finish after the
          harvest readback of the finishing sync, so both mark device work
          that actually committed (never a queued dispatch); resolution is
          the sync boundary (``sync_every`` iterations).
        - ``*_vt`` — the deterministic clock: virtual step-cost units under
          serve() (bit-identical across replays), wall seconds since
          session start under the streaming driver (same code path, the
          clock source is real time there).

        Aborted requests (streaming driver only) appear in ``results`` with
        ``aborted: True`` and their partial output; aggregate latency/AL
        stats cover completed requests only, token totals cover both (the
        work was done either way)."""
        results = [{
            "rid": r.rid,
            "tokens": np.asarray(r.out_tokens, np.int32),
            "logprobs": np.asarray(r.out_logprobs, np.float32),
            "n_new": len(r.out_tokens),
            "iters": r.iters,
            "acceptance_length": r.acceptance_length,
            "arrival_time": r.arrival_time,
            "n_preempt": r.n_preempt,
            "n_swap": r.n_swap,
            "cached_tokens": r.cached_tokens,
            "aborted": r.status == ABORTED,
            "wait_s": r.t_admit - r.t_submit,
            "latency_s": r.t_finish - r.t_submit,
            "wait_vt": (r.vt_admit - r.arrival_time
                        if r.vt_admit is not None else float("nan")),
            "latency_vt": r.vt_finish - r.arrival_time,
            **({"k_final":
                self.spec.request_report(r.rid)["k_final"]}
               if self.spec is not None else {}),
        } for r in sorted(finished, key=lambda r: r.rid)]
        total = sum(r["n_new"] for r in results)
        done = [r for r in results if not r["aborted"]]
        lat_vt = [r["latency_vt"] for r in done] or [0.0]
        wait_vt = [r["wait_vt"] for r in done
                   if not np.isnan(r["wait_vt"])] or [0.0]
        # iteration-WEIGHTED acceptance length: total decode-committed
        # tokens over total decode iterations (completed requests). The
        # per-request mean stays alongside, but a 1-iteration straggler
        # must not weigh the same as a 500-iteration stream — benchmarks
        # report this aggregate.
        done_reqs = [r for r in finished if r.status == FINISHED]
        dec_tok = sum(r._committed - r._prefills for r in done_reqs)
        dec_it = sum(r.iters for r in done_reqs)
        hp = self.engine.host_pool            # None unless swap="host"
        return {
            "results": results,
            "n_requests": len(results),
            "iterations": n_iters,
            "total_new_tokens": total,
            "wall_s": wall,
            "otps": total / max(wall, 1e-9),
            "mean_acceptance_length": float(np.mean(
                [r["acceptance_length"] for r in done])) if done else 0.0,
            "weighted_acceptance_length": dec_tok / max(dec_it, 1),
            **({"speculation": self.spec.report()}
               if self.spec is not None else {}),
            "mean_latency_s": float(np.mean(
                [r["latency_s"] for r in done])) if done else 0.0,
            # deterministic-clock latency profile + churn trace
            "makespan_vt": makespan_vt,
            "otps_vt": total / max(makespan_vt, 1e-9),
            "preemptions": n_preempt,
            # preemption-kind split (honest degradation accounting): every
            # eviction is exactly one of swap-to-host or recompute-prefill;
            # swap_drops counts handles later demoted to recompute under
            # pressure relief, and recomputed_prefill_tokens is the prefix
            # FLOP bill the recompute resumes actually re-paid
            "preempt_swap": self._n_swap,
            "preempt_recompute": self._n_recompute,
            "swap_drops": self._n_swap_drop,
            "recomputed_prefill_tokens": self._recomputed_tokens,
            "host_pool": {
                # `is not None`: an empty HostPagePool is falsy (__len__)
                "capacity_bytes": hp.capacity if hp is not None else 0,
                "used_bytes": hp.used_bytes if hp is not None else 0,
                "peak_bytes": hp.peak_used if hp is not None else 0,
            },
            # device-pool high-water mark (0 for contiguous engines) — read
            # AFTER Engine.reset_stats() between phases for per-phase peaks
            "peak_pages": (self.engine.allocator.peak_used
                           if self.engine.paged else 0),
            "aborted": len(results) - len(done),
            # prefix-cache effectiveness (0s on cache-off engines)
            "cache_hit_tokens": sum(r["cached_tokens"] for r in results),
            "cache_hit_requests": sum(
                1 for r in results if r["cached_tokens"] > 0),
            "p50_latency_vt": float(np.percentile(lat_vt, 50)),
            "p99_latency_vt": float(np.percentile(lat_vt, 99)),
            "p50_wait_vt": float(np.percentile(wait_vt, 50)),
            "p99_wait_vt": float(np.percentile(wait_vt, 99)),
            "events": events,
        }


class LLMEngine:
    """vLLM-style front-end over Engine + Scheduler: offline batch
    generation with per-prompt :class:`SamplingParams`.

    Quickstart::

        llm = LLMEngine(engine, eos_id=2)
        outs = llm.generate(prompts, SamplingParams(temperature=0.8, seed=7))
        outs[0]["tokens"]            # np.int32 generated ids, stop-trimmed

    ``generate`` accepts one ``SamplingParams`` for every prompt or a list
    with one entry per prompt (None entries fall back to the engine
    default), so a single call — and a single batch — may mix greedy and
    sampled requests. Outputs are returned in prompt order; the full
    scheduler report of the last call (aggregate OTPS, latency percentiles,
    event trace) is kept on ``last_report``.
    """

    def __init__(self, engine: Engine, eos_id: Optional[int] = None,
                 **scheduler_kwargs):
        self.engine = engine
        self.scheduler = Scheduler(engine, eos_id=eos_id, **scheduler_kwargs)
        self.last_report: Optional[Dict[str, Any]] = None

    def generate(self, prompts: Sequence,
                 sampling_params=None) -> List[Dict[str, Any]]:
        """Generate a completion for every prompt; returns one result dict
        per prompt (``tokens``, ``n_new``, ``acceptance_length``, ...) in
        prompt order."""
        n = len(prompts)
        if sampling_params is None or isinstance(sampling_params,
                                                 SamplingParams):
            sampling_params = [sampling_params] * n
        if len(sampling_params) != n:
            raise ValueError(
                f"{len(sampling_params)} sampling_params for {n} prompts")
        reqs = [Request(p, sampling=sp)
                for p, sp in zip(prompts, sampling_params)]
        order = {r.rid: i for i, r in enumerate(reqs)}
        self.last_report = self.scheduler.serve(reqs)
        return sorted(self.last_report["results"],
                      key=lambda res: order[res["rid"]])


def serve_round_based(engine: Engine, prompts: Sequence,
                      budgets: Optional[Sequence[int]] = None,
                      batch: Optional[int] = None) -> Dict[str, Any]:
    """The pre-scheduler baseline (previously examples/serve_batched.py's
    ``serve_queue``): fixed batch slots, queue refilled only *between* full
    generation rounds — a finished row idles until the round's slowest member
    drains. Honors per-request ``budgets`` (rows freeze on device at their
    own max_new, like HF-generate-style static batching with early stop) so
    benchmarks/table11_continuous.py compares the two disciplines on the
    same workload."""
    batch = batch or engine.batch
    default = engine.ecfg.max_new_tokens
    queue = [np.asarray(p, np.int32) for p in prompts]
    buds = list(budgets) if budgets is not None else [default] * len(queue)
    toks, rounds, al_num, al_den = 0, 0, 0, 0
    t0 = time.perf_counter()
    while queue:
        cur, queue = queue[:batch], queue[batch:]
        bud, buds = buds[:len(cur)], buds[len(cur):]
        n_real = len(cur)
        while len(cur) < batch:                  # pad final round
            cur.append(cur[-1])
            bud.append(0)                        # padded rows stay frozen
        state = engine.prefill(jnp.stack(cur))
        max_new = jnp.asarray(np.maximum(bud, 1), jnp.int32)
        it = 0
        while True:
            state = engine.step(state, max_new=max_new)
            it += 1
            if it % 4 == 0 or it < 2:
                nc = np.asarray(state["new_count"])
                if (nc >= np.asarray(bud))[:n_real].all():
                    break
        # nc already holds a post-break readback: the poll loop only exits
        # through the branch that just refreshed it — don't sync again
        nc = nc[:n_real]
        toks += int(np.minimum(nc, bud[:n_real]).sum())  # trim overshoot
        al_num += int(np.asarray(state["committed"]))
        al_den += max(int(np.asarray(state["row_iters"])), 1)
        rounds += 1
    wall = time.perf_counter() - t0
    return {
        "otps": toks / max(wall, 1e-9),
        "total_new_tokens": toks,
        "wall_s": wall,
        "mean_acceptance_length": al_num / max(al_den, 1),
        "rounds": rounds,
    }
