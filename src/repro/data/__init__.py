from repro.data.pipeline import (MTPBatch, MTPPipeline, markov_corpus,
                                 self_generated_corpus)

__all__ = ["MTPBatch", "MTPPipeline", "markov_corpus", "self_generated_corpus"]
