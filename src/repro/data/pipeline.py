"""Training data pipeline for P-EAGLE.

Corpora
-------
``markov_corpus``          — seeded synthetic token sequences with learnable
                             bigram structure (offline stand-in for UltraChat
                             etc.; the drafter-vs-target distillation is what
                             matters, not the text).
``self_generated_corpus``  — greedy rollouts *from the target model itself*:
                             the paper trains drafters on target-generated
                             reasoning traces, which makes labels == target
                             argmax. This is what lets a drafter reach AL > 1
                             against a frozen random target in benchmarks.

Batching
--------
``MTPPipeline`` packs sequences to fixed length, samples COD positions
(chain-closed, fixed-count — core/cod.py), pads to the static expanded
length, attaches labels (token[p+2], the EAGLE-shifted pairing), and — when
``segments > 1`` — applies Algorithm 1 to emit within-sequence
gradient-accumulation segments (paper §3.2).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Optional

import numpy as np

from repro.core import cod, partition


@dataclass
class MTPBatch:
    tokens: np.ndarray          # (B, n) original sequences
    pos: np.ndarray             # (B, M) expanded rope positions (-1 pad)
    depth: np.ndarray           # (B, M) prediction depths (-1 pad)
    labels: np.ndarray          # (B, M) target token ids (-1 ignore)
    weight: float = 1.0         # segment weight (valid-label count share)


def markov_corpus(seed: int, n_seqs: int, seq_len: int, vocab: int,
                  branch: int = 4) -> np.ndarray:
    """Sparse-transition Markov chain: each token has `branch` plausible
    successors — compressible structure a small model can learn."""
    rng = np.random.default_rng(seed)
    succ = rng.integers(0, vocab, size=(vocab, branch))
    seqs = np.zeros((n_seqs, seq_len), np.int32)
    state = rng.integers(0, vocab, size=n_seqs)
    for t in range(seq_len):
        seqs[:, t] = state
        pick = rng.integers(0, branch, size=n_seqs)
        state = succ[state, pick]
    return seqs


def self_generated_corpus(model, params, *, seed: int, n_seqs: int,
                          seq_len: int, prompt_len: int = 4,
                          batch: int = 8, extras_fn=None) -> np.ndarray:
    """Greedy rollouts from the target model (the paper's data regime:
    drafters train on target-generated traces)."""
    import jax
    import jax.numpy as jnp
    from repro.serving import Engine, EngineConfig

    rng = np.random.default_rng(seed)
    out = []
    vocab = model.cfg.vocab_size
    ecfg = EngineConfig(K=0, max_new_tokens=seq_len - prompt_len,
                        drafter_mode="none",
                        max_len=seq_len + model.cfg.vision_tokens + 8)
    eng = Engine(model.cfg, None, params, None, ecfg, batch)
    while len(out) * batch < n_seqs:
        prompts = jnp.asarray(
            rng.integers(0, vocab - 2, size=(batch, prompt_len)), jnp.int32)
        extras = extras_fn(batch) if extras_fn else {}
        r = eng.run(prompts, extras)
        off = eng.pos_offset
        out.append(r["tokens"][:, off:off + seq_len])
    return np.concatenate(out, axis=0)[:n_seqs].astype(np.int32)


class MTPPipeline:
    """Yields MTPBatch (full sequences) or lists of segment MTPBatches."""

    def __init__(self, corpus: np.ndarray, *, k_train: int, cod_rate: float,
                 batch: int, seed: int = 0, segments: int = 1,
                 shuffle: bool = True):
        self.corpus = corpus
        self.K = k_train
        self.r = cod_rate
        self.batch = batch
        self.segments = segments
        self.shuffle = shuffle
        self.rng = np.random.default_rng(seed)
        self.n = corpus.shape[1]
        self.M = cod.expanded_length(self.n, k_train, cod_rate)

    def _expand_row(self, row: np.ndarray):
        pos, depth = cod.sample_cod(self.rng, self.n, self.K, self.r)
        pos, depth = cod.pad_to(pos, depth, self.M)
        # EAGLE pairing: position p predicts token[p+2]
        tgt = pos + 2
        ok = (pos >= 0) & (tgt < self.n)
        labels = np.where(ok, row[np.clip(tgt, 0, self.n - 1)], -1)
        return pos, depth, labels

    def __iter__(self) -> Iterator:
        idx = np.arange(len(self.corpus))
        if self.shuffle:
            self.rng.shuffle(idx)
        for s in range(0, len(idx) - self.batch + 1, self.batch):
            rows = self.corpus[idx[s:s + self.batch]]
            pos = np.zeros((self.batch, self.M), np.int32)
            dep = np.zeros((self.batch, self.M), np.int32)
            lab = np.zeros((self.batch, self.M), np.int32)
            for b in range(self.batch):
                pos[b], dep[b], lab[b] = self._expand_row(rows[b])
            if self.segments <= 1:
                yield MTPBatch(rows, pos, dep, lab)
            else:
                yield self._segment_batch(rows, pos, dep, lab)

    def _segment_batch(self, rows, pos, dep, lab) -> List[MTPBatch]:
        """Algorithm 1 per row; segments are padded to a common static shape
        so one jitted segment-step serves all of them."""
        per_row = [partition.build_segments(
            pos[b][dep[b] >= 0], dep[b][dep[b] >= 0], self.n, self.segments)
            for b in range(self.batch)]
        n_seg = max(len(sr) for sr in per_row)
        kv_max = max(len(sg.kv_pos) for sr in per_row for sg in sr)
        kv_max = int(np.ceil(kv_max / 64) * 64)
        out: List[MTPBatch] = []
        total_valid = max(int((lab >= 0).sum()), 1)
        for si in range(n_seg):
            spos = np.full((self.batch, kv_max), -1, np.int32)
            sdep = np.full((self.batch, kv_max), -1, np.int32)
            slab = np.full((self.batch, kv_max), -1, np.int32)
            for b, sr in enumerate(per_row):
                if si >= len(sr):
                    continue
                sg = sr[si]
                m = len(sg.kv_pos)
                spos[b, :m] = sg.kv_pos
                sdep[b, :m] = sg.kv_depth
                # loss only on this segment's own queries
                row_lab = np.full(m, -1, np.int32)
                qsel = sg.q_in_kv
                full_lab = dict(zip(
                    zip(dep[b].tolist(), pos[b].tolist()), lab[b].tolist()))
                for j in qsel.tolist():
                    key = (int(sg.kv_depth[j]), int(sg.kv_pos[j]))
                    row_lab[j] = full_lab.get(key, -1)
                slab[b, :m] = row_lab
            w = float((slab >= 0).sum()) / total_valid
            out.append(MTPBatch(rows, spos, sdep, slab, weight=w))
        return out
