"""Configuration system for target architectures, drafters, and input shapes.

Every assigned architecture gets a ``ModelConfig`` in ``repro/configs/<id>.py``
citing its source. Reduced variants (for CPU smoke tests) are derived with
``reduced()``. Input shapes are global, paper-assigned workload points.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    # Which layers are MoE: "all" (DBRX) or "interleaved" (Llama-4: every 2nd).
    pattern: str = "all"
    n_shared_experts: int = 0          # Llama-4 has a shared expert
    capacity_factor: float = 1.25
    router_z_weight: float = 1e-3      # router z-loss (load-balance aux)
    aux_loss_weight: float = 1e-2


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 128              # SSD chunked-scan block
    conv_width: int = 4
    dt_rank: int = 0                   # unused by mamba2 (scalar dt per head)


@dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma-style RG-LRU + local attention."""
    lru_width: int = 0                 # defaults to d_model
    block_pattern: Tuple[str, ...] = ("recurrent", "recurrent", "attention")
    conv_width: int = 4


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                        # dense | moe | ssm | hybrid | encdec | vlm
    source: str                        # citation
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- MLP / norm ---
    mlp_variant: str = "swiglu"        # swiglu | geglu | relu2 | gelu
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    embed_scale: bool = False          # gemma scales embeddings by sqrt(d)

    # --- attention ---
    attn_pattern: Tuple[str, ...] = ("global",)   # cycled over layers
    window_size: int = 4096
    logit_softcap: float = 0.0         # gemma2 attn softcap
    final_softcap: float = 0.0         # gemma2 final-logit softcap
    qkv_bias: bool = False             # qwen2
    post_norms: bool = False           # gemma2 post-attn/post-ffn norms
    nope_on_global: bool = False       # llama-4 iRoPE: global layers skip RoPE
    rope_theta: float = 10_000.0
    query_scale: Optional[float] = None  # default 1/sqrt(head_dim)
    positional: str = "rope"           # rope | sinusoidal (whisper)

    # --- family extensions ---
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    hybrid: HybridConfig = field(default_factory=HybridConfig)

    # --- encoder-decoder (whisper) ---
    n_encoder_layers: int = 0
    encoder_seq: int = 1500            # mel frames after conv frontend (stub)

    # --- vlm ---
    vision_tokens: int = 0             # patch embeddings prepended (stub frontend)
    vision_dim: int = 0                # raw ViT dim before projector

    # --- long-context handling for long_500k ---
    # "native"        : arch family is sub-quadratic / locally-bounded already
    # "sliding_window": beyond-spec rolling-KV variant enabled for long_500k
    # "skip"          : documented skip (DESIGN.md §4)
    long_context: str = "sliding_window"
    long_window: int = 8192

    # --- numerics ---
    dtype: str = "bfloat16"
    use_pallas: bool = False           # TPU path; CPU dry-run uses blocked jnp

    def q_scale(self) -> float:
        return self.query_scale if self.query_scale is not None else self.head_dim ** -0.5

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_decoder(self) -> bool:
        return True  # all ten assigned archs have a decoder (whisper is enc-dec)

    def attn_kind(self, layer_idx: int) -> str:
        return self.attn_pattern[layer_idx % len(self.attn_pattern)]

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.moe.n_experts == 0:
            return False
        if self.moe.pattern == "all":
            return True
        return layer_idx % 2 == 1      # interleaved: odd layers are MoE

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """CPU-smoke variant of the same family: 2 layers, d_model<=256, <=4 experts."""
        d = min(self.d_model, 256)
        heads = min(self.n_heads, 4)
        kv = max(1, min(self.n_kv_heads, heads))
        hd = 32
        kw = dict(
            n_layers=2, d_model=d, n_heads=heads, n_kv_heads=kv, head_dim=hd,
            d_ff=min(self.d_ff, 512) or 0, vocab_size=min(self.vocab_size, 1024),
            dtype="float32", window_size=min(self.window_size, 64),
            long_window=64, encoder_seq=16 if self.n_encoder_layers else self.encoder_seq,
            n_encoder_layers=2 if self.n_encoder_layers else 0,
            vision_tokens=8 if self.vision_tokens else 0,
            vision_dim=64 if self.vision_dim else 0,
        )
        if self.moe.n_experts:
            # capacity_factor=n_experts => capacity >= T*top_k: no token drops,
            # so cached decode matches the full forward exactly in tests.
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=min(self.moe.top_k, 2),
                capacity_factor=4.0)
        if self.family == "ssm":
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=16, head_dim=16, chunk_size=8)
        if self.family == "hybrid":
            kw["hybrid"] = dataclasses.replace(self.hybrid, lru_width=d)
        return self.replace(**kw)


@dataclass(frozen=True)
class DrafterConfig:
    """P-EAGLE / AR-EAGLE drafter riding on a target ModelConfig."""
    n_layers: int = 4                  # paper §4.2: 4 layers for P-EAGLE
    d_model: int = 0                   # 0 => target d_model
    n_heads: int = 0                   # 0 => derived (d_model // 128, min 4)
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0                      # 0 => ~3.5 * d_model rounded to 128
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6

    # P-EAGLE specifics (paper §2)
    parallel: bool = True              # False => AR EAGLE-3 baseline
    k_train: int = 8                   # paper §4.4: train K=8
    k_infer: int = 5
    cod_rate: float = 0.8              # COD retention ratio r (paper §5.1)
    hidden_state_variant: str = "shared"
    # shared | depth_encoding | ntp_hidden | ntp_hidden_depth | regularized
    freeze_embeddings: bool = False    # paper §4.3: unfreeze (+5%)
    num_taps: int = 3                  # hidden states from layers 2, L/2, L-1
    # AR-baseline training options
    ttt_steps: int = 3                 # EAGLE-3 training-time-test unroll
    hca: bool = True                   # harmonized context alignment loss
    remat: bool = False                # checkpoint drafter blocks (training)
    flash_train: bool = True           # custom-VJP flash MTP attention

    def resolve(self, target: ModelConfig) -> "DrafterConfig":
        d = self.d_model or target.d_model
        heads = self.n_heads or max(4, d // 128)
        hd = self.head_dim or (d // heads)
        ff = self.d_ff or max(128, int(3.5 * d) // 128 * 128)
        return dataclasses.replace(
            self, d_model=d, n_heads=heads, n_kv_heads=self.n_kv_heads or heads,
            head_dim=hd, d_ff=ff)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # train | prefill | decode


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,   32, "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",  524_288,    1, "decode"),
}

# TPU v5e hardware model for the roofline (assignment constants).
HW = dict(
    peak_flops=197e12,        # bf16 FLOP/s per chip
    hbm_bw=819e9,             # B/s per chip
    ici_bw=50e9,              # B/s per link
)
