"""mamba2-780m [ssm] — SSD (state-space duality), attention-free. [arXiv:2405.21060]

48L, d_model=1536, vocab=50280, d_state=128, expand=2 (d_inner=3072),
SSD head_dim=64 => 48 SSD heads. O(1) decode state => long_500k native.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="mamba2-780m",
    family="ssm",
    source="arXiv:2405.21060 (Mamba-2)",
    n_layers=48,
    d_model=1536,
    n_heads=0,                  # attention-free
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,                     # no separate MLP; SSD block only (Mamba-2)
    vocab_size=50280,
    mlp_variant="swiglu",       # unused
    tie_embeddings=True,
    norm_eps=1e-5,
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, chunk_size=128, conv_width=4),
    long_context="native",
)
