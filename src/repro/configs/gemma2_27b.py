"""gemma2-27b [dense] — local/global alternating, logit softcap. [arXiv:2408.00118]

46L, d_model=4608, 32 heads (GQA kv=16), d_ff=36864 (GeGLU), vocab=256000,
head_dim=128, alternating local(4096)/global attention, attn logit softcap 50,
final logit softcap 30, query scale (d_model/n_heads)^-0.5 = 144^-0.5.

long_500k: native-ish — half the layers are 4096-window local; global layers
at decode are linear-per-token with the KV cache sharded over data+model.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma2-27b",
    family="dense",
    source="arXiv:2408.00118 (Gemma 2)",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    mlp_variant="geglu",
    embed_scale=True,
    tie_embeddings=True,
    attn_pattern=("local", "global"),
    window_size=4096,
    logit_softcap=50.0,
    final_softcap=30.0,
    post_norms=True,
    query_scale=(4608 / 32) ** -0.5,
    long_context="native",
)
