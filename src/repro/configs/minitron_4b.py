"""minitron-4b [dense] — pruned Nemotron. [arXiv:2407.14679]

32L, d_model=3072, 24 heads (GQA kv=8), d_ff=9216, vocab=256000,
squared-ReLU MLP (Nemotron family), untied embeddings, head_dim=128.

long_500k: beyond-spec sliding-window variant (window 8192).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="minitron-4b",
    family="dense",
    source="arXiv:2407.14679 (Minitron)",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    mlp_variant="relu2",
    tie_embeddings=False,
    rope_theta=10_000.0,
    long_context="sliding_window",
)
