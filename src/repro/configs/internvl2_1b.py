"""internvl2-1b [vlm] — InternViT + Qwen2-0.5B LM backbone. [arXiv:2404.16821]

LM: 24L, d_model=896, 14 heads (GQA kv=2), d_ff=4864, vocab=151655,
qwen2-style (QKV bias, SwiGLU). The InternViT-300M vision encoder + MLP
projector are a stub per the assignment: ``input_specs`` provides
(B, 256, vision_dim=1024) patch embeddings; the in-framework projector maps
them to d_model and they are early-fusion prepended to text embeddings.

long_500k: beyond-spec sliding-window variant (window 8192).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2-1b",
    family="vlm",
    source="arXiv:2404.16821 (InternVL2); LM=Qwen2-0.5B",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    mlp_variant="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    vision_tokens=256,
    vision_dim=1024,
    long_context="sliding_window",
)
