"""qwen2-1.5b [dense] — GQA, QKV bias. [arXiv:2407.10671]

28L, d_model=1536, 12 heads (GQA kv=2), d_ff=8960 (SwiGLU), vocab=151936,
head_dim=128, tied embeddings.

long_500k: beyond-spec sliding-window variant (window 8192).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-1.5b",
    family="dense",
    source="arXiv:2407.10671 (Qwen2)",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    mlp_variant="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    long_context="sliding_window",
)
