"""llama4-maverick-400b-a17b [moe] — 128 experts top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E]

48L, d_model=5120, 40 heads (GQA kv=8), d_ff=8192 per routed expert,
vocab=202048. MoE interleaved (every 2nd layer) with one shared expert —
Maverick's layout; 24 MoE layers x 128 x 3 x 5120 x 8192 ~= 386B routed
params + dense ~= 400B total, 17B active (top-1 + shared).

Attention: Llama-4 iRoPE — chunked local attention (8192) on 3 of 4 layers,
global NoPE layer every 4th => sub-quadratic locality, long_500k native.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="llama4-maverick-400b-a17b",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E (Maverick layout)",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    mlp_variant="swiglu",
    rope_theta=500_000.0,
    tie_embeddings=False,
    attn_pattern=("local", "local", "local", "global"),
    window_size=8192,
    nope_on_global=True,
    moe=MoEConfig(n_experts=128, top_k=1, pattern="interleaved", n_shared_experts=1),
    long_context="native",
)
