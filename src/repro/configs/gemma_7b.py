"""gemma-7b [dense] — GeGLU, head_dim=256, MHA. [arXiv:2403.08295]

28L, d_model=3072, 16 heads (kv=16 — MHA on 7b; MQA is the 2b variant),
d_ff=24576 (GeGLU), vocab=256000. Embeddings scaled by sqrt(d_model), tied.

long_500k: beyond-spec sliding-window variant (window 8192).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma-7b",
    family="dense",
    source="arXiv:2403.08295 (Gemma)",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    mlp_variant="geglu",
    embed_scale=True,
    tie_embeddings=True,
    long_context="sliding_window",
)
