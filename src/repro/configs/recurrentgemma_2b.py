"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2. [arXiv:2402.19427]

26L (but Griffin-2b is 26 blocks in pattern recurrent,recurrent,attention),
d_model=2560, 10 heads (GQA kv=1 => MQA), d_ff=7680 (GeGLU), vocab=256000,
lru_width=2560, local attention window 2048. O(1) recurrent state + bounded
window => long_500k native.
"""
from repro.configs.base import ModelConfig, HybridConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-2b",
    family="hybrid",
    source="arXiv:2402.19427 (Griffin / RecurrentGemma)",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    mlp_variant="geglu",
    embed_scale=True,
    tie_embeddings=True,
    attn_pattern=("local",),
    window_size=2048,
    hybrid=HybridConfig(lru_width=2560,
                        block_pattern=("recurrent", "recurrent", "attention")),
    long_context="native",
)
