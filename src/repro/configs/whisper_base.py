"""whisper-base [audio] — enc-dec, conv frontend stubbed. [arXiv:2212.04356]

6L encoder + 6L decoder, d_model=512, 8 heads (kv=8), d_ff=2048, vocab=51865.
Whisper uses absolute sinusoidal positions and GELU MLPs. The mel-spectrogram +
conv feature extractor is a stub per the assignment: ``input_specs`` provides
precomputed (B, 1500, 512) frame embeddings.

long_500k: SKIP — the Whisper decoder is architecturally capped at 448
positions; a 500k full-attention decoder cache contradicts the family.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-base",
    family="encdec",
    source="arXiv:2212.04356 (Whisper)",
    n_layers=6,                 # decoder layers
    n_encoder_layers=6,
    encoder_seq=1500,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    mlp_variant="gelu",
    positional="sinusoidal",
    tie_embeddings=True,
    long_context="skip",
)
