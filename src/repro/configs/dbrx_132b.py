"""dbrx-132b [moe] — 16 experts top-4, fine-grained. [hf:databricks/dbrx-base]

40L, d_model=6144, 48 heads (GQA kv=8), d_ff=10752 per expert, vocab=100352,
MoE on every layer. Full (global) attention; rope_theta=500000.

long_500k: SKIP — pure full-attention family, no faithful local variant.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="dbrx-132b",
    family="moe",
    source="hf:databricks/dbrx-base",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    mlp_variant="swiglu",
    rope_theta=500_000.0,
    tie_embeddings=False,
    moe=MoEConfig(n_experts=16, top_k=4, pattern="all"),
    long_context="skip",
)
