"""Config registry: ``get_config("<arch-id>")`` and the input-shape table."""
from __future__ import annotations

import importlib

from repro.configs.base import (
    HW, INPUT_SHAPES, DrafterConfig, HybridConfig, InputShape, ModelConfig,
    MoEConfig, SSMConfig,
)

_ARCH_MODULES = {
    "whisper-base": "whisper_base",
    "dbrx-132b": "dbrx_132b",
    "mamba2-780m": "mamba2_780m",
    "internvl2-1b": "internvl2_1b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "gemma-7b": "gemma_7b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "qwen2-1.5b": "qwen2_1_5b",
    "minitron-4b": "minitron_4b",
    "gemma2-27b": "gemma2_27b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = [
    "ARCH_IDS", "HW", "INPUT_SHAPES", "DrafterConfig", "HybridConfig",
    "InputShape", "ModelConfig", "MoEConfig", "SSMConfig", "all_configs",
    "get_config",
]
