"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.masks import mtp_mask_predicate

NEG_INF = -1e30


def attention_reference(q, k, v, *, scale, causal=True, window=0,
                        softcap=0.0):
    """q (B,Sq,H,hd), k/v (B,Skv,KV,hd). Dense-mask softmax attention."""
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    qr = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgd,bjkd->bkgqj", qr, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Skv)[None, :]
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok &= qp >= kp
    if window > 0:
        ok &= (qp - kp) < window
    s = jnp.where(ok, s, NEG_INF)
    denom_ok = ok.any(axis=1)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqj,bjkd->bkgqd", p, v,
                     preferred_element_type=jnp.float32)
    out = jnp.where(denom_ok[None, None, None, :, None], out, 0.0)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)


def mtp_attention_reference(q, k, v, pos, depth, *, scale):
    """MTP-masked attention with the closed-form predicate materialized
    densely. q/k/v (B,M,H|KV,hd); pos/depth (M,) int32 (-1 = padding)."""
    B, M, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qr = q.reshape(B, M, KV, G, hd)
    s = jnp.einsum("bqkgd,bjkd->bkgqj", qr, k,
                   preferred_element_type=jnp.float32) * scale
    ok = mtp_mask_predicate(depth, pos, depth, pos, np_mod=jnp)
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqj,bjkd->bkgqd", p, v,
                     preferred_element_type=jnp.float32)
    out = jnp.where(ok.any(axis=1)[None, None, None, :, None], out, 0.0)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, M, H, hd).astype(q.dtype)


def paged_decode_reference(q, k_pool, v_pool, pos_pool, block_table,
                           q_positions, *, scale, window=0):
    """Oracle for the paged kernel: materialize each row's contiguous view
    with a plain jnp gather (the cache_ops.gather_pages semantics — page 0
    for unallocated entries, positions forced to -1) and run the dense
    decode reference on it."""
    page = k_pool.shape[1]
    safe = jnp.clip(block_table, 0, None)                    # (B, nb)
    B, nb = block_table.shape

    def view(pool):
        g = jnp.take(pool, safe, axis=0)                     # (B, nb, page, ...)
        return g.reshape((B, nb * page) + pool.shape[2:])

    kpos = view(pos_pool)
    kpos = jnp.where(jnp.repeat(block_table < 0, page, axis=1), -1, kpos)
    return decode_reference(q, view(k_pool), view(v_pool), kpos, q_positions,
                            scale=scale, window=window)


def decode_reference(q, k, v, k_positions, q_positions, *, scale, window=0):
    """Single-block decode: q (B,T,H,hd) vs cache k/v (B,S,KV,hd) with
    per-slot absolute positions (B,S) (-1 = empty) and query positions
    (B,T)."""
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    qr = q.reshape(B, T, KV, G, hd)
    s = jnp.einsum("bqkgd,bjkd->bkgqj", qr, k,
                   preferred_element_type=jnp.float32) * scale
    ok = (k_positions[:, None, :] <= q_positions[:, :, None]) & \
         (k_positions[:, None, :] >= 0)
    if window > 0:
        ok &= (q_positions[:, :, None] - k_positions[:, None, :]) < window
    s = jnp.where(ok[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqj,bjkd->bkgqd", p, v,
                     preferred_element_type=jnp.float32)
    out = jnp.where(ok.any(axis=2)[:, None, None, :, None], out, 0.0)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, T, H, hd).astype(q.dtype)
