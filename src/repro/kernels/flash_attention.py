"""Flash attention for TPU (pl.pallas_call + BlockSpec VMEM tiling).

Target-model attention hot spot: causal or sliding-window, optional logit
softcap (gemma2), GQA via a grouped-query layout. Online softmax with
float32 VMEM scratch accumulators; K/V stream through VMEM in (block_k, hd)
tiles while a (block_q, hd) query tile stays resident — the classic
HBM→VMEM dataflow for the MXU.

Grid: (batch, q_heads, Sq/block_q, Skv/block_k); the innermost grid
dimension iterates KV blocks for a fixed query tile, accumulating into
scratch, and writes the output tile on the last iteration.

Validated on CPU with interpret=True against kernels/ref.py (the same
math as models/layers.blocked_attention).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, softcap: float,
                  block_q: int, block_k: int, n_kv_blocks: int,
                  kv_len: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)            # (block_q, hd)
    k = k_ref[...].astype(jnp.float32)            # (block_k, hd)
    v = v_ref[...].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    ok = k_pos < kv_len                # mask pad-to-block keys
    if causal:
        ok &= q_pos >= k_pos
    if window > 0:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    # mask p explicitly: fully-masked rows would see exp(-inf - -inf) = 1
    p = jnp.where(ok, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kj == n_kv_blocks - 1)
    def _done():
        l = l_scr[...]
        out = acc_scr[...] / jnp.maximum(l, 1e-30)[:, None]
        out = jnp.where((l > 0)[:, None], out, 0.0)
        o_ref[...] = out.astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    scale: float, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 128,
                    block_k: int = 128, kv_len: int = 0,
                    interpret: bool = False) -> jax.Array:
    """q (B, Sq, H, hd); k/v (B, Skv, KV, hd), H % KV == 0.

    Sq/Skv must be multiples of block_q/block_k (ops.py pads); ``kv_len``
    marks the number of real (unpadded) keys (0 => all)."""
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    kv_len = kv_len or Skv
    G = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0
    n_kv_blocks = Skv // block_k

    qt = q.transpose(0, 2, 1, 3)                  # (B, H, Sq, hd)
    kt = k.transpose(0, 2, 1, 3)                  # (B, KV, Skv, hd)
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, H, Sq // block_q, n_kv_blocks)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, softcap=softcap, block_q=block_q,
                          block_k=block_k, n_kv_blocks=n_kv_blocks,
                          kv_len=kv_len),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, block_q, hd),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((None, None, block_k, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((None, None, block_k, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
