"""Split-K decode attention (flash-decode) for TPU.

Serving hot spot: a tiny query block (the K+1 speculative verify tokens, or
the K parallel draft slots) against a long KV cache. The sequence dimension
is split across grid steps; each step reduces a (block_k, hd) cache tile
against the resident (T, hd) query tile with online-softmax scratch.

Cache slots carry absolute positions (-1 = empty) so ring (sliding-window)
caches and speculative invalidation mask correctly — the same convention as
models/layers.make_kv_cache.

``paged_decode_attention`` is the paged-KV twin (serving/cache_ops paged
layout): K/V live in a shared pool of fixed-size position pages and each
batch row owns a block table. The page id is scalar-prefetched into the
BlockSpec index map, so every grid step DMAs one page straight from the
pool — the gather happens in the index stream, and the contiguous per-slot
view the CPU path materializes (cache_ops.gather_state) never exists in
HBM. Unallocated table entries (-1) are masked in-kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float, window: int,
                   n_kv_blocks: int):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)             # (T, hd)
    k = k_ref[0].astype(jnp.float32)             # (block_k, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qp = qpos_ref[0][:, None]                    # (T, 1)
    kp = kpos_ref[0][None, :]                    # (1, block_k)
    ok = (kp <= qp) & (kp >= 0)
    if window > 0:
        ok &= (qp - kp) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.where(ok, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kj == n_kv_blocks - 1)
    def _done():
        l = l_scr[...]
        out = acc_scr[...] / jnp.maximum(l, 1e-30)[:, None]
        out = jnp.where((l > 0)[:, None], out, 0.0)
        o_ref[0] = out.astype(o_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     k_positions: jax.Array, q_positions: jax.Array, *,
                     scale: float, window: int = 0, block_k: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q (B,T,H,hd) small T; k/v (B,S,KV,hd); k_positions (B,S) int32;
    q_positions (B,T) int32."""
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    block_k = min(block_k, S)
    assert S % block_k == 0
    n_kv_blocks = S // block_k

    qt = q.transpose(0, 2, 1, 3)                 # (B, H, T, hd)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    grid = (B, H, n_kv_blocks)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, window=window,
                          n_kv_blocks=n_kv_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, T), lambda b, h, j: (b, 0)),
            pl.BlockSpec((1, block_k), lambda b, h, j: (b, j)),
            pl.BlockSpec((1, None, T, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, None, block_k, hd),
                         lambda b, h, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, None, block_k, hd),
                         lambda b, h, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, None, T, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((T,), jnp.float32),
            pltpu.VMEM((T,), jnp.float32),
            pltpu.VMEM((T, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q_positions, k_positions, qt, kt, vt)
    return out.transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# paged-KV decode attention (block-table gather in the index stream)
# ---------------------------------------------------------------------------

def _paged_kernel(bt_ref, qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale: float, window: int,
                  n_pages: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)             # (T, hd)
    k = k_ref[...].astype(jnp.float32)           # (page, hd)
    v = v_ref[...].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qp = qpos_ref[0][:, None]                    # (T, 1)
    kp = kpos_ref[...][None, :]                  # (1, page)
    ok = (kp <= qp) & (kp >= 0)
    if window > 0:
        ok &= (qp - kp) < window
    # unallocated page: the index map clamped it to page 0, whose positions
    # could alias a *live* request's — mask the whole contribution
    ok &= bt_ref[b, j] >= 0
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.where(ok, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == n_pages - 1)
    def _done():
        l = l_scr[...]
        out = acc_scr[...] / jnp.maximum(l, 1e-30)[:, None]
        out = jnp.where((l > 0)[:, None], out, 0.0)
        o_ref[0] = out.astype(o_ref.dtype)


def paged_decode_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, pos_pool: jax.Array,
                           block_table: jax.Array, q_positions: jax.Array, *,
                           scale: float, window: int = 0,
                           interpret: bool = False) -> jax.Array:
    """q (B,T,H,hd) small T; k_pool/v_pool (NP, page, KV, hd) shared page
    pool; pos_pool (NP, page) int32 absolute positions (-1 = empty);
    block_table (B, nb) int32 page ids (-1 = unallocated); q_positions
    (B,T) int32. Each batch row attends only to the pages its table names —
    one pool-resident page per grid step, no per-slot contiguous copy."""
    B, T, H, hd = q.shape
    NP, page, KV = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    nb = block_table.shape[1]
    G = H // KV

    qt = q.transpose(0, 2, 1, 3)                 # (B, H, T, hd)
    grid = (B, H, nb)

    def page_idx(b, h, j, bt):
        return jnp.maximum(bt[b, j], 0)

    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, T), lambda b, h, j, bt: (b, 0)),
            pl.BlockSpec((None, page),
                         lambda b, h, j, bt: (page_idx(b, h, j, bt), 0)),
            pl.BlockSpec((1, None, T, hd), lambda b, h, j, bt: (b, h, 0, 0)),
            pl.BlockSpec((None, page, None, hd),
                         lambda b, h, j, bt, G=G:
                         (page_idx(b, h, j, bt), 0, h // G, 0)),
            pl.BlockSpec((None, page, None, hd),
                         lambda b, h, j, bt, G=G:
                         (page_idx(b, h, j, bt), 0, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, None, T, hd),
                               lambda b, h, j, bt: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((T,), jnp.float32),
            pltpu.VMEM((T,), jnp.float32),
            pltpu.VMEM((T, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, scale=scale, window=window,
                          n_pages=nb),
        grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((B, H, T, hd), q.dtype),
        interpret=interpret,
    )(block_table, q_positions, pos_pool, qt, k_pool, v_pool)
    return out.transpose(0, 2, 1, 3)
