"""Split-K decode attention (flash-decode) for TPU.

Serving hot spot: a tiny query block (the K+1 speculative verify tokens, or
the K parallel draft slots) against a long KV cache. The sequence dimension
is split across grid steps; each step reduces a (block_k, hd) cache tile
against the resident (T, hd) query tile with online-softmax scratch.

Cache slots carry absolute positions (-1 = empty) so ring (sliding-window)
caches and speculative invalidation mask correctly — the same convention as
models/layers.make_kv_cache.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float, window: int,
                   n_kv_blocks: int):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)             # (T, hd)
    k = k_ref[0].astype(jnp.float32)             # (block_k, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qp = qpos_ref[0][:, None]                    # (T, 1)
    kp = kpos_ref[0][None, :]                    # (1, block_k)
    ok = (kp <= qp) & (kp >= 0)
    if window > 0:
        ok &= (qp - kp) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.where(ok, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kj == n_kv_blocks - 1)
    def _done():
        l = l_scr[...]
        out = acc_scr[...] / jnp.maximum(l, 1e-30)[:, None]
        out = jnp.where((l > 0)[:, None], out, 0.0)
        o_ref[0] = out.astype(o_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     k_positions: jax.Array, q_positions: jax.Array, *,
                     scale: float, window: int = 0, block_k: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q (B,T,H,hd) small T; k/v (B,S,KV,hd); k_positions (B,S) int32;
    q_positions (B,T) int32."""
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    block_k = min(block_k, S)
    assert S % block_k == 0
    n_kv_blocks = S // block_k

    qt = q.transpose(0, 2, 1, 3)                 # (B, H, T, hd)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    grid = (B, H, n_kv_blocks)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, window=window,
                          n_kv_blocks=n_kv_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, T), lambda b, h, j: (b, 0)),
            pl.BlockSpec((1, block_k), lambda b, h, j: (b, j)),
            pl.BlockSpec((1, None, T, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, None, block_k, hd),
                         lambda b, h, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, None, block_k, hd),
                         lambda b, h, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, None, T, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((T,), jnp.float32),
            pltpu.VMEM((T,), jnp.float32),
            pltpu.VMEM((T, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q_positions, k_positions, qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
