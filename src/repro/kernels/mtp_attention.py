"""MTP-masked flash attention — the paper's training hot spot, TPU-native.

The paper (§3.1) precomputes the (n_max·K)² cross-depth mask in HBM and
slices per example. On TPU that costs O(M²) HBM mask traffic per step. This
kernel instead evaluates the *closed-form* predicate

    attend ⇔ (g'=0 ∧ p' ≤ p−g) ∨ (p'−g' = p−g ∧ g' ≤ g)

inside VMEM from two int32 metadata vectors (depth, pos) of length M —
O(M) metadata instead of O(M²) mask bytes (DESIGN.md §3, beyond-paper
optimization; the paper-faithful precompute+slice path lives in
core/masks.py and is what Table-2 benchmarks compare against).

Padding (depth = -1) attends nothing; its output rows are zeroed.

Grid and dataflow mirror flash_attention.py; the metadata vectors ride in
as (block,)-tiled VMEM operands.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mtp_kernel(qd_ref, qp_ref, kd_ref, kp_ref, q_ref, k_ref, v_ref, o_ref,
                m_scr, l_scr, acc_scr, *, scale: float, block_q: int,
                block_k: int, n_kv_blocks: int):
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qg = qd_ref[...][:, None]          # (block_q, 1) depths
    qp = qp_ref[...][:, None]          # rope positions
    kg = kd_ref[...][None, :]          # (1, block_k)
    kp = kp_ref[...][None, :]
    anchor_q = qp - qg
    anchor_k = kp - kg
    ok = ((kg == 0) & (kp <= anchor_q)) | ((anchor_k == anchor_q) & (kg <= qg))
    ok &= (qg >= 0) & (kg >= 0)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    # explicit mask on p: fully-masked rows would otherwise see
    # exp(NEG_INF - NEG_INF) = 1
    p = jnp.where(ok, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kj == n_kv_blocks - 1)
    def _done():
        l = l_scr[...]
        out = acc_scr[...] / jnp.maximum(l, 1e-30)[:, None]
        out = jnp.where((l > 0)[:, None], out, 0.0)
        o_ref[...] = out.astype(o_ref.dtype)


def mtp_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  pos: jax.Array, depth: jax.Array, *, scale: float,
                  block_q: int = 128, block_k: int = 128,
                  interpret: bool = False) -> jax.Array:
    """q (B,M,H,hd); k/v (B,M,KV,hd); pos/depth (M,) int32 (-1 pad)."""
    B, M, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    block_q = min(block_q, M)
    block_k = min(block_k, M)
    assert M % block_q == 0 and M % block_k == 0
    n_kv_blocks = M // block_k

    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    grid = (B, H, M // block_q, n_kv_blocks)

    out = pl.pallas_call(
        functools.partial(_mtp_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, n_kv_blocks=n_kv_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q,), lambda b, h, i, j: (i,)),
            pl.BlockSpec((block_q,), lambda b, h, i, j: (i,)),
            pl.BlockSpec((block_k,), lambda b, h, i, j: (j,)),
            pl.BlockSpec((block_k,), lambda b, h, i, j: (j,)),
            pl.BlockSpec((None, None, block_q, hd),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((None, None, block_k, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((None, None, block_k, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, M, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(depth, pos, depth, pos, qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
