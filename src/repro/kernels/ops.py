"""Jit'd public wrappers around the Pallas kernels: pad-to-block, dispatch,
unpad. On CPU backends interpret=True is selected automatically so the same
call sites work in tests and in the TPU deployment path.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dk
from repro.kernels import flash_attention as _fa
from repro.kernels import mtp_attention as _mtp


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_seq(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@partial(jax.jit, static_argnames=("scale", "causal", "window", "softcap",
                                   "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, scale, causal=True, window=0, softcap=0.0,
                    block_q=128, block_k=128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    Sq, Skv = q.shape[1], k.shape[1]
    bq, bk = min(block_q, Sq), min(block_k, Skv)
    q2, pq = _pad_seq(q, 1, bq)
    k2, _ = _pad_seq(k, 1, bk)
    v2, _ = _pad_seq(v, 1, bk)
    # kv_len masks pad-to-block keys; padded q rows are discarded on unpad.
    out = _fa.flash_attention(q2, k2, v2, scale=scale, causal=causal,
                              window=window, softcap=softcap, block_q=bq,
                              block_k=bk, kv_len=Skv, interpret=interpret)
    return out[:, :Sq]


@partial(jax.jit, static_argnames=("scale", "block_q", "block_k",
                                   "interpret"))
def mtp_attention(q, k, v, pos, depth, *, scale, block_q=128, block_k=128,
                  interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    M = q.shape[1]
    bq, bk = min(block_q, M), min(block_k, M)
    mult = max(bq, bk)
    q2, pq = _pad_seq(q, 1, mult)
    k2, _ = _pad_seq(k, 1, mult)
    v2, _ = _pad_seq(v, 1, mult)
    pos2 = jnp.pad(pos, (0, pq), constant_values=-1)
    dep2 = jnp.pad(depth, (0, pq), constant_values=-1)
    out = _mtp.mtp_attention(q2, k2, v2, pos2, dep2, scale=scale,
                             block_q=bq, block_k=bk, interpret=interpret)
    return out[:, :M]


@partial(jax.jit, static_argnames=("scale", "window", "block_k",
                                   "interpret"))
def decode_attention(q, k, v, k_positions, q_positions, *, scale, window=0,
                     block_k=512, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    S = k.shape[1]
    bk = min(block_k, S)
    k2, pk = _pad_seq(k, 1, bk)
    v2, _ = _pad_seq(v, 1, bk)
    kp2 = jnp.pad(k_positions, ((0, 0), (0, pk)), constant_values=-1)
    return _dk.decode_attention(q, k2, v2, kp2, q_positions, scale=scale,
                                window=window, block_k=bk,
                                interpret=interpret)


@partial(jax.jit, static_argnames=("scale", "window", "interpret", "mesh"))
def paged_decode_attention(q, k_pool, v_pool, pos_pool, block_table,
                           q_positions, *, scale, window=0, interpret=None,
                           mesh=None):
    """Paged-KV decode: K/V in a (NP, page, KV, hd) pool, per-row
    (B, nb) block tables (-1 = unallocated). The page is the DMA tile, so
    no pad-to-block is needed — pool and tables are already page-granular.

    ``mesh``: pass the serving mesh when the pools are storage-sharded
    (EngineConfig(shard_model=True)). Pallas calls are SPMD-opaque — GSPMD
    cannot partition a kernel body — so sharded operands must be gathered
    *before* the call; the replication pin here makes that boundary
    explicit (and bitwise-exact: it is pure data movement) instead of
    leaving the gather to propagation at an unspecified point. The sharded
    engine's jnp twin (cache_ops.gather_state) pins the same boundary."""
    interpret = _default_interpret() if interpret is None else interpret
    if mesh is not None:
        repl = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec())
        q, k_pool, v_pool, pos_pool, block_table, q_positions = (
            jax.lax.with_sharding_constraint(x, repl)
            for x in (q, k_pool, v_pool, pos_pool, block_table, q_positions))
    return _dk.paged_decode_attention(q, k_pool, v_pool, pos_pool,
                                      block_table, q_positions, scale=scale,
                                      window=window, interpret=interpret)
