from repro.checkpoint.store import load_pytree, save_pytree, latest_step

__all__ = ["load_pytree", "save_pytree", "latest_step"]
