"""Pytree checkpointing: flattened-path npz + json metadata.

Layout: <dir>/step_<N>/<name>.npz — one npz per named pytree (drafter
params, optimizer state, ...), keys are '/'-joined tree paths, so restore
round-trips any nested dict/NamedTuple structure produced by this codebase.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for pe in path:
        parts.append(str(getattr(pe, "key", getattr(pe, "idx", getattr(pe, "name", pe)))))
    return "/".join(parts)


def _to_numpy(leaf):
    """bfloat16 has no native numpy dtype — store as a uint16 view and
    record the logical dtype in metadata."""
    arr = jax.device_get(leaf)
    if str(arr.dtype) == "bfloat16":
        return np.asarray(arr.view(np.uint16)), "bfloat16"
    return np.asarray(arr), str(arr.dtype)


def save_pytree(tree: Any, directory: str, name: str, step: int,
                metadata: Optional[dict] = None) -> str:
    d = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    arrays, dtypes = {}, {}
    for p, l in flat:
        key = _path_str(p)
        arrays[key], dtypes[key] = _to_numpy(l)
    fn = os.path.join(d, f"{name}.npz")
    np.savez(fn, **arrays)
    meta = dict(metadata or {})
    meta["step"] = step
    meta["dtypes"] = dtypes
    with open(os.path.join(d, f"{name}.meta.json"), "w") as f:
        json.dump(meta, f)
    return fn


def load_pytree(template: Any, directory: str, name: str,
                step: Optional[int] = None) -> Any:
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    base = os.path.join(directory, f"step_{step:08d}")
    fn = os.path.join(base, f"{name}.npz")
    data = np.load(fn)
    dtypes = {}
    meta_fn = os.path.join(base, f"{name}.meta.json")
    if os.path.exists(meta_fn):
        with open(meta_fn) as f:
            dtypes = json.load(f).get("dtypes", {})
    flat, tdef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, tmpl in flat:
        key = _path_str(p)
        arr = data[key]
        if dtypes.get(key) == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {tmpl.shape}")
        leaves.append(jax.numpy.asarray(arr).astype(tmpl.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"step_(\d+)$", f))]
    return max(steps) if steps else None
