"""Drafter training: the paper's scalable MTP training loop.

One jitted ``train_step`` covers both regimes:
- whole-sequence MTP training (train_4k dry-run shape), and
- *segmented* training (paper §3.2): the pipeline emits Algorithm-1 segments;
  ``segment_grads`` runs one forward/backward per segment and the
  GradAccumulator sums them into a single optimizer step. Because each query
  appears in exactly one segment with its full attention context, the summed
  gradient equals the unpartitioned gradient (tested in
  tests/test_partition.py::test_segmented_grads_match).

The AR EAGLE-3 baseline trains through ``losses.ttt_forward_loss``
(training-time-test unroll + optional HCA).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DrafterConfig, ModelConfig
from repro.core import drafter as D
from repro.core import losses
from repro.data.pipeline import MTPBatch, MTPPipeline
from repro.models import get_model
from repro.optim import (GradAccumulator, adamw_init, adamw_update,
                         apply_updates, linear_warmup_schedule)


@dataclass
class TrainConfig:
    lr: float = 1e-4                  # paper §5.1
    total_steps: int = 1000
    warmup_ratio: float = 0.0025
    weight_decay: float = 0.01
    max_grad_norm: float = 1.0
    depth_weight_decay: float = 1.0
    hca_weight: float = 0.1


def make_train_step(tcfg: ModelConfig, dcfg: DrafterConfig,
                    tc: TrainConfig) -> Callable:
    """Whole-batch drafter train step (also the dry-run's train_step)."""
    model = get_model(tcfg)
    sched = linear_warmup_schedule(tc.lr, tc.total_steps, tc.warmup_ratio)

    def step(tparams, dparams, opt_state, tokens, pos, depth, labels, rng,
             **extras):
        tout = model.forward(tparams, tokens, mode="train",
                             collect_taps=True, **extras)
        taps = jax.lax.stop_gradient(tout.taps)
        # VLM early fusion: taps cover [vision, text]; drafter positions
        # index the text region.
        if tcfg.family == "vlm" and taps.shape[1] != tokens.shape[1]:
            taps = taps[:, -tokens.shape[1]:]

        def loss_fn(dp):
            if dcfg.parallel:
                logits, hidden = D.mtp_forward(dcfg, tcfg, dp, tokens, taps,
                                               pos, depth, rng=rng)
                loss, metrics = losses.mtp_loss(
                    logits, labels, depth,
                    depth_weight_decay=tc.depth_weight_decay)
            else:
                loss, metrics = losses.ttt_forward_loss(
                    dcfg, tcfg, dp, tokens, taps, hca_weight=tc.hca_weight)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(dparams)
        updates, opt_state, om = adamw_update(
            grads, opt_state, dparams, lr=sched,
            weight_decay=tc.weight_decay, max_grad_norm=tc.max_grad_norm)
        dparams = apply_updates(dparams, updates)
        metrics.update(om)
        return dparams, opt_state, metrics

    return jax.jit(step)


def make_segment_step(tcfg: ModelConfig, dcfg: DrafterConfig,
                      tc: TrainConfig):
    """(taps once per sequence) + (grads per segment) + (apply once)."""
    model = get_model(tcfg)
    sched = linear_warmup_schedule(tc.lr, tc.total_steps, tc.warmup_ratio)

    @jax.jit
    def taps_fn(tparams, tokens, **extras):
        tout = model.forward(tparams, tokens, mode="train",
                             collect_taps=True, **extras)
        taps = tout.taps
        if tcfg.family == "vlm" and taps.shape[1] != tokens.shape[1]:
            taps = taps[:, -tokens.shape[1]:]
        return jax.lax.stop_gradient(taps)

    @jax.jit
    def seg_grads(dparams, tokens, taps, pos, depth, labels, rng):
        def loss_fn(dp):
            logits, _ = D.mtp_forward(dcfg, tcfg, dp, tokens, taps,
                                      pos, depth, rng=rng)
            return losses.mtp_loss(logits, labels, depth,
                                   depth_weight_decay=tc.depth_weight_decay)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(dparams)
        return grads, metrics

    @jax.jit
    def apply_fn(dparams, opt_state, grads):
        updates, opt_state, om = adamw_update(
            grads, opt_state, dparams, lr=sched,
            weight_decay=tc.weight_decay, max_grad_norm=tc.max_grad_norm)
        return apply_updates(dparams, updates), opt_state, om

    return taps_fn, seg_grads, apply_fn


class Trainer:
    """Epoch loop over an MTPPipeline; handles both whole-sequence and
    segmented (within-sequence accumulation) batches."""

    def __init__(self, tcfg: ModelConfig, dcfg: DrafterConfig,
                 tparams: dict, tc: TrainConfig, *, seed: int = 0,
                 extras: Optional[dict] = None):
        self.tcfg, self.dcfg, self.tc = tcfg, dcfg, tc
        self.tparams = tparams
        self.extras = extras or {}
        key = jax.random.PRNGKey(seed)
        self.dparams = D.init_params(dcfg, tcfg, key)
        self.opt_state = adamw_init(self.dparams)
        self.rng = jax.random.fold_in(key, 7)
        self._step = make_train_step(tcfg, dcfg, tc)
        self._taps, self._seg_grads, self._apply = make_segment_step(
            tcfg, dcfg, tc)
        self._accum = None
        self.metrics_log = []

    def _advance_rng(self):
        # training data-order stream: draws are sequential by construction
        # and never replayed per-position, so split-and-carry is the intent
        self.rng, sub = jax.random.split(self.rng)  # repro-lint: disable=PRNG01
        return sub

    def train_batch(self, batch) -> dict:
        if isinstance(batch, MTPBatch):
            self.dparams, self.opt_state, m = self._step(
                self.tparams, self.dparams, self.opt_state,
                jnp.asarray(batch.tokens), jnp.asarray(batch.pos),
                jnp.asarray(batch.depth), jnp.asarray(batch.labels),
                self._advance_rng(), **self.extras)
            return {k: float(v) for k, v in m.items()}
        # segmented: within-sequence gradient accumulation (paper §3.2)
        segs = batch
        if self._accum is None:
            self._accum = GradAccumulator(self.dparams)
        taps = self._taps(self.tparams, jnp.asarray(segs[0].tokens),
                          **self.extras)
        acc = self._accum.init()
        last_m = {}
        for sg in segs:
            grads, m = self._seg_grads(
                self.dparams, jnp.asarray(sg.tokens), taps,
                jnp.asarray(sg.pos), jnp.asarray(sg.depth),
                jnp.asarray(sg.labels), self._advance_rng())
            acc = GradAccumulator.add(acc, grads, float(m["valid_tokens"]))
            last_m = m
        self.dparams, self.opt_state, om = self._apply(
            self.dparams, self.opt_state, GradAccumulator.mean(acc))
        out = {k: float(v) for k, v in last_m.items()}
        out.update({k: float(v) for k, v in om.items()})
        return out

    def train(self, pipeline: MTPPipeline, epochs: int = 1,
              log_every: int = 0) -> list:
        step = 0
        for ep in range(epochs):
            for batch in pipeline:
                m = self.train_batch(batch)
                m["epoch"] = ep
                self.metrics_log.append(m)
                step += 1
                if log_every and step % log_every == 0:
                    print(f"step {step}: loss={m['loss']:.4f} "
                          f"acc={m.get('acc', 0):.3f} "
                          f"mtp_acc={m.get('mtp_acc', 0):.3f}")
        return self.metrics_log
