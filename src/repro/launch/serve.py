"""Serving launcher: load a trained drafter checkpoint and serve batched
speculative decoding, printing OTPS/acceptance stats.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --ckpt results/ckpt --mode parallel --k 5
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_pytree
from repro.configs import DrafterConfig, get_config
from repro.core import drafter as D
from repro.models import get_model, make_extras
from repro.serving import Engine, EngineConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default="results/ckpt")
    ap.add_argument("--mode", default="parallel",
                    choices=["parallel", "ar", "none"])
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()

    reduced = args.reduced or jax.default_backend() != "tpu"
    tcfg = get_config(args.arch)
    if reduced:
        tcfg = tcfg.reduced()
    model = get_model(tcfg)
    key = jax.random.PRNGKey(0)
    tparams = model.init(key)

    dcfg = dparams = None
    if args.mode != "none":
        dcfg = DrafterConfig(n_layers=args.layers,
                             k_infer=args.k).resolve(tcfg)
        tmpl = D.init_params(dcfg, tcfg, key)
        try:
            dparams = load_pytree(tmpl, args.ckpt, f"drafter_{args.arch}")
            print("loaded drafter checkpoint")
        except Exception as e:
            print(f"no checkpoint ({e}); using random drafter")
            dparams = tmpl

    eng = Engine(tcfg, dcfg, tparams, dparams,
                 EngineConfig(K=args.k, max_new_tokens=args.max_new,
                              drafter_mode=args.mode, max_len=256),
                 args.batch)
    prompts = jax.random.randint(key, (args.batch, 8), 0,
                                 tcfg.vocab_size - 2)
    extras = (make_extras(tcfg, args.batch, "prefill", key)
              if tcfg.family in ("vlm", "encdec") else {})
    r = eng.run(prompts, extras)
    r = eng.run(prompts, extras)   # steady-state timing
    print(f"mode={args.mode} K={args.k}: OTPS={r['otps']:.1f} "
          f"AL={r['acceptance_length']:.2f} "
          f"({r['new_tokens']} tokens, {r['iterations']} iterations)")


if __name__ == "__main__":
    main()
