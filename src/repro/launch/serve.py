"""Serving launcher: load a trained drafter checkpoint and serve a stream of
requests through the event-driven continuous-batching scheduler, printing
per-request and aggregate OTPS / acceptance / latency stats.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --ckpt results/ckpt --mode parallel --k 5 --requests 12

``--temperature/--top-p/--top-k/--seed`` set the per-request decoding
policy (serving/sampling.SamplingParams): temperature 0 (default) is greedy
verification; temperature > 0 runs seeded lossless rejection sampling
against the warped target distribution, each request on its own
deterministic PRNG stream (``seed + i``, bitwise reproducible across runs
and slot placements). ``--mixed-sampling`` alternates greedy and sampled
requests through ONE batch — the mixed-policy step the redesign enables.

``--mean-gap G`` spaces request arrivals by Exp(G) gaps on the scheduler's
deterministic virtual clock (0 = everything arrives at t=0); async runs
report virtual-time p50/p99 latency and queue wait plus preemption counts.
``--kv-growth upfront`` restores PR-2's static admission sizing,
``--no-preempt`` disables eviction (slots stall on pool exhaustion instead).
``--swap host`` turns preemption into swap-to-host: the victim's pages move
to a byte-budgeted host pool (``--host-pool-bytes``) and resume is a device
scatter instead of a recompute-prefill — same token streams, no prefill
FLOPs re-paid.
``--round-based`` serves the same queue with the pre-scheduler baseline
(batch refilled only between full generation rounds) for comparison.
vlm/encdec targets serve through the scheduler like everything else —
per-request frontend extras (vision/encoder embeds) are synthesized as
deterministic stubs at admission.

``--shard-model N`` serves model-sharded: weights and full-length KV (page
pools included) are storage-sharded over a 1-D ``("model",)`` mesh of N
devices, token-for-token identical to the single-device engine (see
docs/sharding.md). On this CPU container, force host devices first:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.serve --shard-model 8 ...
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.checkpoint import load_pytree
from repro.configs import DrafterConfig, get_config
from repro.core import drafter as D
from repro.models import get_model
from repro.serving import (Engine, EngineConfig, Request, SamplingParams,
                           Scheduler, serve_round_based)
from repro.sharding.utils import serving_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default="results/ckpt")
    ap.add_argument("--mode", default="parallel",
                    choices=["parallel", "ar", "none"])
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="per-request sampling temperature (0 = greedy "
                         "verification, the lossless-vs-AR default)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 disables)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k filter (0 disables)")
    ap.add_argument("--seed", type=int, default=0,
                    help="base sampling seed; request i uses seed + i "
                         "(deterministic per-request PRNG streams)")
    ap.add_argument("--mixed-sampling", action="store_true",
                    help="alternate greedy and sampled requests in one "
                         "batch (even i greedy, odd i at --temperature)")
    ap.add_argument("--sync-every", type=int, default=1,
                    help="speculative iterations between scheduler host syncs")
    ap.add_argument("--round-based", action="store_true",
                    help="also run the round-based baseline on the same queue")
    ap.add_argument("--kv-layout", default="contiguous",
                    choices=["contiguous", "paged"],
                    help="paged = block-table KV pool; admission claims "
                         "ceil(need/page) pages instead of a max_len row")
    ap.add_argument("--page-size", type=int, default=16,
                    help="positions per KV page (paged layout)")
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="page-pool size; 0 = batch * max_len/page_size")
    ap.add_argument("--no-bucket", action="store_true",
                    help="disable power-of-two bucketing of admission "
                         "prefills (retraces per distinct prompt length)")
    ap.add_argument("--mean-gap", type=float, default=0.0,
                    help="mean exponential inter-arrival gap in virtual "
                         "steps (Poisson arrivals); 0 = all requests at t=0")
    ap.add_argument("--kv-growth", default="incremental",
                    choices=["incremental", "upfront"],
                    help="paged admission sizing: grow pages as slots "
                         "lengthen (incremental) or reserve prompt+budget "
                         "up front (PR-2 baseline)")
    ap.add_argument("--no-preempt", action="store_true",
                    help="never evict a running slot on pool exhaustion; "
                         "slots stall until pages free up")
    ap.add_argument("--swap", default="none", choices=["none", "host"],
                    help="preemption flavor: host = copy the victim's pages "
                         "(KV + stream state + sampling rows) to a host "
                         "pool and resume by device scatter instead of "
                         "recompute-prefill (paged layout only; lossless "
                         "either way)")
    ap.add_argument("--host-pool-bytes", type=int, default=0,
                    help="host swap-pool byte budget (0 = unbounded); when "
                         "full, preemption falls back to recompute-prefill")
    ap.add_argument("--adaptive-k", action="store_true",
                    help="per-request dynamic draft length: an acceptance "
                         "EMA per request sets k_row <= K via the jitted "
                         "step's max-K mask (serving/speculation.py); easy "
                         "rows speculate deep, hard rows stop burning "
                         "verify FLOPs and page headroom")
    ap.add_argument("--draft-sampling", action="store_true",
                    help="sample drafts from the row-warped drafter "
                         "distribution for temperature > 0 requests (the "
                         "rejection proposal q becomes that distribution "
                         "instead of the argmax one-hot); greedy requests "
                         "are unchanged")
    ap.add_argument("--shard-model", type=int, default=0, metavar="N",
                    help="storage-shard weights + full-length KV over a 1-D "
                         "(model,) mesh of N devices (0 = single-device); "
                         "lossless — output is token-for-token identical")
    args = ap.parse_args()
    if args.mixed_sampling and args.temperature <= 0:
        raise SystemExit(
            "--mixed-sampling alternates greedy and sampled requests, but "
            "--temperature is 0 (greedy) so every request would be greedy; "
            "pass --temperature > 0, e.g. --temperature 0.8")
    if args.shard_model > jax.device_count():
        raise SystemExit(
            f"--shard-model {args.shard_model} needs {args.shard_model} "
            f"devices but jax sees {jax.device_count()}; on CPU set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N first")

    reduced = args.reduced or jax.default_backend() != "tpu"
    tcfg = get_config(args.arch)
    if reduced:
        tcfg = tcfg.reduced()
    model = get_model(tcfg)
    key = jax.random.PRNGKey(0)
    tparams = model.init(key)

    dcfg = dparams = None
    if args.mode != "none":
        dcfg = DrafterConfig(n_layers=args.layers,
                             k_infer=args.k).resolve(tcfg)
        tmpl = D.init_params(dcfg, tcfg, key)
        try:
            dparams = load_pytree(tmpl, args.ckpt, f"drafter_{args.arch}")
            print("loaded drafter checkpoint")
        except Exception as e:
            print(f"no checkpoint ({e}); using random drafter")
            dparams = tmpl

    mesh = serving_mesh(args.shard_model) if args.shard_model else None
    eng = Engine(tcfg, dcfg, tparams, dparams,
                 EngineConfig(K=args.k, max_new_tokens=args.max_new,
                              drafter_mode=args.mode, max_len=256,
                              kv_layout=args.kv_layout,
                              page_size=args.page_size,
                              pool_pages=args.pool_pages,
                              bucket_prefill=not args.no_bucket,
                              kv_growth=args.kv_growth,
                              shard_model=args.shard_model > 0, mesh=mesh,
                              draft_sampling=args.draft_sampling,
                              swap=args.swap,
                              host_pool_bytes=args.host_pool_bytes),
                 args.batch)
    if mesh is not None:
        print(f"model-sharded over {mesh.shape['model']} devices "
              f"(mesh axes {mesh.axis_names}); storage-sharded weights + "
              "KV pools, replicated compute — lossless")
    rng = np.random.default_rng(3)
    # varied prompt lengths exercise bucketed admission; the round-based
    # baseline prefills whole batches, so give it equal lengths to compare
    # the two disciplines on an identical workload
    plen = (lambda: 8) if args.round_based else (
        lambda: int(rng.integers(4, 13)))
    prompts = [rng.integers(0, tcfg.vocab_size - 2,
                            size=plen()).astype(np.int32)
               for _ in range(args.requests)]
    budgets = rng.integers(max(args.max_new // 2, 1), args.max_new + 1,
                           size=args.requests).tolist()
    arrivals = (np.cumsum(rng.exponential(args.mean_gap,
                                          size=args.requests)).tolist()
                if args.mean_gap > 0 else [0.0] * args.requests)
    if args.round_based and tcfg.family in ("vlm", "encdec"):
        raise SystemExit(
            "--round-based is a whole-batch loop without per-request "
            "extras; serve vlm/encdec through the scheduler (default)")

    def params_for(i: int):
        if args.temperature <= 0 or (args.mixed_sampling and i % 2 == 0):
            return SamplingParams.greedy(seed=args.seed + i)
        return SamplingParams(temperature=args.temperature,
                              top_k=args.top_k, top_p=args.top_p,
                              seed=args.seed + i)
    sps = [params_for(i) for i in range(args.requests)]
    n_sampled = sum(not sp.is_greedy for sp in sps)
    if n_sampled:
        print(f"sampling: {n_sampled}/{args.requests} requests at "
              f"T={args.temperature} top_k={args.top_k} top_p={args.top_p} "
              f"(seeds {args.seed}..{args.seed + args.requests - 1}; "
              "deterministic per-request streams)")

    # vlm/encdec requests need no explicit extras here: admission
    # synthesizes deterministic per-prompt stub frontend inputs (real
    # deployments attach actual vision/audio features via Request.extras)
    sched = Scheduler(eng, eos_id=args.eos_id, sync_every=args.sync_every,
                      preempt=False if args.no_preempt else None,
                      adaptive_k=args.adaptive_k)
    rep = None
    for _ in range(2):      # second run = warm, compile excluded
        rep = sched.serve([Request(p, max_new_tokens=b, arrival_time=a,
                                   sampling=sp)
                           for p, b, a, sp in zip(prompts, budgets, arrivals,
                                                  sps)])
    print(f"mode={args.mode} K={args.k} batch={args.batch} "
          f"requests={rep['n_requests']}: OTPS={rep['otps']:.1f} "
          f"AL={rep['weighted_acceptance_length']:.2f} "
          f"({rep['total_new_tokens']} tokens, {rep['iterations']} iterations,"
          f" mean latency {rep['mean_latency_s'] * 1e3:.0f} ms)")
    if args.adaptive_k:
        spec = rep["speculation"]
        print(f"adaptive-K: mean_k={spec['mean_k']:.2f} "
              f"(min {spec['min_k']} / max {spec['max_k']} of K={args.k})")
    if args.mean_gap > 0 or rep["preemptions"]:
        print(f"async: makespan={rep['makespan_vt']:.1f} vt  "
              f"latency p50/p99={rep['p50_latency_vt']:.1f}/"
              f"{rep['p99_latency_vt']:.1f} vt  "
              f"wait p50/p99={rep['p50_wait_vt']:.1f}/"
              f"{rep['p99_wait_vt']:.1f} vt  "
              f"preemptions={rep['preemptions']}")
    if args.swap == "host":
        hp = rep["host_pool"]
        print(f"swap-to-host: {rep['preempt_swap']} swapped / "
              f"{rep['preempt_recompute']} recomputed / "
              f"{rep['swap_drops']} dropped  "
              f"recomputed_prefill_tokens={rep['recomputed_prefill_tokens']}"
              f"  host pool peak {hp['peak_bytes']} B"
              + (f" of {hp['capacity_bytes']}" if hp["capacity_bytes"]
                 else " (unbounded)"))
    for r in rep["results"]:
        pre = f"  preempt={r['n_preempt']}" if r["n_preempt"] else ""
        print(f"  req {r['rid']:3d}: {r['n_new']:3d} tok in {r['iters']:3d} "
              f"iters  AL={r['acceptance_length']:.2f}  "
              f"latency={r['latency_s'] * 1e3:6.1f} ms{pre}")
    if eng.paged:
        print(f"paged KV: {eng.pool_pages} pages x {args.page_size} "
              f"positions shared by {args.batch} slots, {args.kv_growth} "
              f"growth (peak {eng.allocator.peak_used} pages, "
              f"{eng.allocator.n_free} free after drain)")

    if args.round_based:
        rb_eng = eng
        if eng.paged:
            # the round-based baseline is a whole-batch loop (one contiguous
            # state per round) — paged states are scheduler-only
            rb_eng = Engine(tcfg, dcfg, tparams, dparams,
                            EngineConfig(K=args.k,
                                         max_new_tokens=args.max_new,
                                         drafter_mode=args.mode, max_len=256),
                            args.batch)
        rb = None
        for _ in range(2):      # same per-request budgets as the scheduler
            rb = serve_round_based(rb_eng, prompts, budgets)
        print(f"round-based baseline: OTPS={rb['otps']:.1f} "
              f"({rb['rounds']} rounds) → continuous is "
              f"{rep['otps'] / max(rb['otps'], 1e-9):.2f}x")


if __name__ == "__main__":
    main()
