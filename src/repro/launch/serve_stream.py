"""Streaming serving front-end: newline-delimited JSON over a TCP socket.

Runs the wall-clock :class:`~repro.serving.streaming.AsyncEngine` behind an
asyncio socket server, so request-shaping / tokenization / client I/O live
in OTHER processes and the dispatch loop's process does nothing but step
the engine and shuttle small JSON lines (the aphrodite/vLLM
multiprocessing-front-end split).

    PYTHONPATH=src python -m repro.launch.serve_stream --arch qwen2-1.5b \
        --port 8765 --batch 4 --k 5 --max-new 32

Protocol — one JSON object per line, both directions:

client → server::

    {"op": "generate", "id": "r1", "prompt": [3, 17, ...],
     "max_new_tokens": 32,            # optional
     "temperature": 0.8, "top_k": 0, "top_p": 1.0, "seed": 7}  # optional
    {"op": "abort", "id": "r1"}
    {"op": "health"}

server → client::

    {"id": "r1", "event": "tokens", "tokens": [..], "logprobs": [..]}
    {"id": "r1", "event": "done", "n_new": 12, "aborted": false}
    {"event": "health", "queue_depth": 0, ...}
    {"id": "r1", "event": "error", "message": "..."}

``tokens`` events carry everything one speculative sync committed for the
request (already stop/budget-trimmed — the stream never shows a token past
the stop). ``id`` is the client's correlation key, scoped per connection.
A dropped connection aborts its in-flight requests, freeing their slots.

Demo client (same protocol, for smoke tests and as reference code)::

    PYTHONPATH=src python -m repro.launch.serve_stream --client \
        --port 8765 --requests 4
"""
from __future__ import annotations

import argparse
import asyncio
import json
from typing import Any, Dict, Optional

import numpy as np

from repro.serving.sampling import SamplingParams
from repro.serving.streaming import AsyncEngine, StreamHandle


def _sampling_from(msg: Dict[str, Any]) -> Optional[SamplingParams]:
    """Build the request's SamplingParams from protocol fields (None when
    the message sets no policy field — engine default applies)."""
    keys = ("temperature", "top_k", "top_p", "seed", "stop_token_ids")
    if not any(k in msg for k in keys):
        return None
    return SamplingParams(temperature=float(msg.get("temperature", 0.0)),
                          top_k=int(msg.get("top_k", 0)),
                          top_p=float(msg.get("top_p", 1.0)),
                          seed=int(msg.get("seed", 0)),
                          stop_token_ids=tuple(msg.get("stop_token_ids", ())))


class _Connection:
    """One client connection: reads NDJSON ops, fans generate ops out to
    per-request pump tasks, serializes writes through a lock so concurrent
    streams never interleave mid-line."""

    def __init__(self, aeng: AsyncEngine, reader: asyncio.StreamReader,
                 writer: asyncio.StreamWriter):
        self.aeng = aeng
        self.reader = reader
        self.writer = writer
        self.wlock = asyncio.Lock()
        self.handles: Dict[str, StreamHandle] = {}
        self.tasks: Dict[str, asyncio.Task] = {}

    async def send(self, obj: Dict[str, Any]) -> None:
        line = (json.dumps(obj) + "\n").encode()
        async with self.wlock:
            self.writer.write(line)
            # drain under the lock: a slow client socket backpressures its
            # own connection task, never the engine's dispatch loop
            await self.writer.drain()

    async def _pump(self, cid: str, handle: StreamHandle) -> None:
        """Forward one request's committed tokens to the client as they
        stream out of the engine, then the done event."""
        try:
            try:
                async for tok, lp in handle:
                    toks, lps = [tok], [lp]
                    # batch whatever the same sync already delivered
                    while True:
                        try:
                            nxt = handle._queue.get_nowait()
                        except asyncio.QueueEmpty:
                            break
                        if nxt is None or isinstance(nxt, BaseException):
                            handle._queue.put_nowait(nxt)
                            break
                        toks.append(nxt[0])
                        lps.append(nxt[1])
                    await self.send({"id": cid, "event": "tokens",
                                     "tokens": toks, "logprobs": lps})
                final = {"id": cid, "event": "done",
                         "n_new": len(handle.request.out_tokens),
                         "aborted": handle.aborted}
            except Exception as e:                   # engine failure
                final = {"id": cid, "event": "error", "message": repr(e)}
            try:
                await self.send(final)
            except (ConnectionError, RuntimeError):
                pass                                 # client vanished
        finally:
            self.handles.pop(cid, None)
            self.tasks.pop(cid, None)

    async def handle_op(self, msg: Dict[str, Any]) -> None:
        op = msg.get("op")
        if op == "generate":
            cid = str(msg.get("id"))
            if cid in self.handles:
                await self.send({"id": cid, "event": "error",
                                 "message": "duplicate id"})
                return
            try:
                prompt = np.asarray(msg["prompt"], np.int32)
                handle = await self.aeng.submit(
                    prompt, sampling_params=_sampling_from(msg),
                    max_new_tokens=msg.get("max_new_tokens"))
            except (ValueError, KeyError, TypeError) as e:
                await self.send({"id": cid, "event": "error",
                                 "message": str(e)})
                return
            self.handles[cid] = handle
            self.tasks[cid] = asyncio.get_running_loop().create_task(
                self._pump(cid, handle))
        elif op == "abort":
            cid = str(msg.get("id"))
            handle = self.handles.get(cid)
            # the pump task sees the finish sentinel and sends "done"
            ok = handle.abort() if handle is not None else False
            if not ok and handle is None:
                await self.send({"id": cid, "event": "error",
                                 "message": "unknown id"})
        elif op == "health":
            await self.send({"event": "health", **self.aeng.health()})
        else:
            await self.send({"event": "error",
                             "message": f"unknown op {op!r}"})

    async def run(self) -> None:
        try:
            while True:
                line = await self.reader.readline()
                if not line:
                    break
                try:
                    msg = json.loads(line)
                except json.JSONDecodeError as e:
                    await self.send({"event": "error", "message": str(e)})
                    continue
                await self.handle_op(msg)
        except (OSError, RuntimeError):
            # a reset mid-read (ECONNRESET surfaces through readline) or a
            # send() on the closed transport: same as EOF — fall through to
            # the cleanup below instead of killing the task with an
            # unretrieved exception
            pass
        finally:
            # a vanished client must not pin slots/pages
            for handle in list(self.handles.values()):
                if not handle.done:
                    handle.abort()
            for t in list(self.tasks.values()):
                t.cancel()
            self.writer.close()


async def start_stream_server(aeng: AsyncEngine, host: str = "127.0.0.1",
                              port: int = 0) -> "asyncio.base_events.Server":
    """Start the NDJSON front-end for a (started or not) AsyncEngine;
    returns the asyncio Server (its sockets carry the bound port). Tests
    drive this in-process with port=0."""
    await aeng.start()

    async def on_client(reader, writer):
        await _Connection(aeng, reader, writer).run()

    return await asyncio.start_server(on_client, host, port)


# ---------------------------------------------------------------------------
# reference client (also the smoke test)
# ---------------------------------------------------------------------------
async def _demo_client(host: str, port: int, n_requests: int,
                       max_new: int, vocab: int, temperature: float,
                       seed: int) -> None:
    reader, writer = await asyncio.open_connection(host, port)
    rng = np.random.default_rng(seed)
    for i in range(n_requests):
        req = {"op": "generate", "id": f"r{i}",
               "prompt": rng.integers(0, vocab,
                                      size=int(rng.integers(4, 13))).tolist(),
               "max_new_tokens": max_new}
        if temperature > 0:
            req.update(temperature=temperature, seed=seed + i)
        writer.write((json.dumps(req) + "\n").encode())
    writer.write((json.dumps({"op": "health"}) + "\n").encode())
    await writer.drain()
    got: Dict[str, list] = {}
    done = 0
    while done < n_requests:
        msg = json.loads(await reader.readline())
        if msg.get("event") == "tokens":
            got.setdefault(msg["id"], []).extend(msg["tokens"])
        elif msg.get("event") == "done":
            done += 1
            print(f"{msg['id']}: {msg['n_new']} tokens"
                  + (" (aborted)" if msg["aborted"] else ""))
        elif msg.get("event") == "health":
            print("health:", {k: msg[k] for k in
                              ("queue_depth", "running", "pool_occupancy")})
        elif msg.get("event") == "error":
            print("error:", msg["message"])
            done += 1
    writer.close()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8765)
    ap.add_argument("--mode", default="parallel",
                    choices=["parallel", "ar", "none"])
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="page-pool size; 0 = batch * max_len/page_size")
    ap.add_argument("--prefix-cache", action="store_true")
    ap.add_argument("--max-pending", type=int, default=0,
                    help="backpressure bound on in-flight requests "
                         "(0 = 4 * batch)")
    ap.add_argument("--ckpt", default="results/ckpt")
    ap.add_argument("--client", action="store_true",
                    help="run the reference NDJSON client instead of the "
                         "server (connects to --host/--port)")
    ap.add_argument("--requests", type=int, default=4,
                    help="(client) number of streamed requests")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="(client) per-request sampling temperature")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.client:
        asyncio.run(_demo_client(args.host, args.port, args.requests,
                                 args.max_new, 128, args.temperature,
                                 args.seed))
        return

    # heavyweight imports only on the server path — the client stays light
    import jax
    from repro.checkpoint import load_pytree
    from repro.configs import DrafterConfig, get_config
    from repro.core import drafter as D
    from repro.models import get_model
    from repro.serving import Engine, EngineConfig

    reduced = args.reduced or jax.default_backend() != "tpu"
    tcfg = get_config(args.arch)
    if reduced:
        tcfg = tcfg.reduced()
    model = get_model(tcfg)
    key = jax.random.PRNGKey(0)
    tparams = model.init(key)
    dcfg = dparams = None
    if args.mode != "none":
        dcfg = DrafterConfig(n_layers=args.layers,
                             k_infer=args.k).resolve(tcfg)
        tmpl = D.init_params(dcfg, tcfg, key)
        try:
            dparams = load_pytree(tmpl, args.ckpt, f"drafter_{args.arch}")
            print("loaded drafter checkpoint")
        except Exception as e:
            print(f"no checkpoint ({e}); using random drafter")
            dparams = tmpl
    eng = Engine(tcfg, dcfg, tparams, dparams,
                 EngineConfig(K=args.k, max_new_tokens=args.max_new,
                              drafter_mode=args.mode, max_len=args.max_len,
                              kv_layout="paged", page_size=args.page_size,
                              pool_pages=args.pool_pages,
                              prefix_cache=args.prefix_cache),
                 args.batch)
    aeng = AsyncEngine(eng, eos_id=args.eos_id,
                       max_pending=args.max_pending or None)

    async def serve_forever():
        server = await start_stream_server(aeng, args.host, args.port)
        addr = server.sockets[0].getsockname()
        print(f"streaming NDJSON server on {addr[0]}:{addr[1]} "
              f"(batch={args.batch}, K={args.k}, mode={args.mode}, "
              f"max_pending={aeng.max_pending})")
        async with server:
            await server.serve_forever()

    try:
        asyncio.run(serve_forever())
    except KeyboardInterrupt:
        pass


if __name__ == "__main__":
    main()
