"""Step functions + input specs for the multi-pod dry-run and launchers.

One builder per workload shape kind:

  train   → ``build_train_step``   — frozen-target taps + P-EAGLE drafter
            fwd/bwd (COD-expanded MTP positions, K_train=8, r=0.8, the
            paper's §5.1 configuration) + AdamW, with microbatch gradient
            accumulation inside the jitted step (lax.scan).
  prefill → ``build_prefill_step`` — target prefill filling the KV cache,
            returning taps + last logits.
  decode  → ``build_serve_step``   — ONE speculative iteration (P-EAGLE
            parallel draft → target verify of K+1 tokens → acceptance →
            cache commit), via serving.engine.speculative_step.

Each builder returns (fn, make_inputs) where make_inputs(mesh) yields
(args_sds, in_shardings, out_shardings?) built from ShapeDtypeStructs — no
device allocation — with NamedShardings resolved from sharding/rules under
the mesh context.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, DrafterConfig, ModelConfig
from repro.core import cod
from repro.core import drafter as D
from repro.core import losses
from repro.models import extra_input_shapes, get_model
from repro.optim import adamw_init, adamw_update, apply_updates, \
    linear_warmup_schedule
from repro.serving.engine import EngineConfig, make_decode_state, \
    speculative_step
from repro.sharding.rules import cache_specs, param_specs
from repro.sharding.utils import mesh_scope, spec_for
from repro.training.trainer import TrainConfig


# canonical implementation lives in sharding/utils.py (the serving engine
# needs it too); re-exported here under its historical launcher name
mesh_context = mesh_scope


def batch_spec(mesh, *trailing):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(axes if axes else None, *trailing)


def _shard_tree(mesh, tree, specs):
    return jax.tree.map(lambda l, s: NamedSharding(mesh, s), tree, specs)


def resolve_drafter(tcfg: ModelConfig, n_layers: int = 4,
                    **kw) -> DrafterConfig:
    return DrafterConfig(n_layers=n_layers, **kw).resolve(tcfg)


def eval_shape_tree(fn, *a, **k):
    return jax.eval_shape(fn, *a, **k)


# ---------------------------------------------------------------------------
# long-context config adaptation (DESIGN.md §4 shape skips / variants)
# ---------------------------------------------------------------------------

def adapt_for_shape(tcfg: ModelConfig, shape_name: str) -> Optional[ModelConfig]:
    """Returns the (possibly variant) config for this shape, or None = skip."""
    if shape_name != "long_500k":
        return tcfg
    if tcfg.long_context == "skip":
        return None
    if tcfg.long_context == "sliding_window":
        # beyond-spec rolling-KV variant: every layer local, window=long_window
        return tcfg.replace(attn_pattern=("local",),
                            window_size=tcfg.long_window)
    return tcfg


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def expanded_len(n: int, K: int, r: float) -> int:
    m = cod.expanded_length(n, K, r)
    return int(math.ceil(m / 128) * 128)


def build_train_step(tcfg: ModelConfig, dcfg: DrafterConfig,
                     shape_name: str = "train_4k", *, n_micro: int = 8,
                     tc: Optional[TrainConfig] = None):
    shape = INPUT_SHAPES[shape_name]
    tc = tc or TrainConfig(total_steps=10_000)
    model = get_model(tcfg)
    sched = linear_warmup_schedule(tc.lr, tc.total_steps, tc.warmup_ratio)
    n = shape.seq_len
    GB = shape.global_batch
    M = expanded_len(n, dcfg.k_train, dcfg.cod_rate)
    mb = GB // n_micro
    extras_shapes = extra_input_shapes(tcfg, GB, "train")

    def train_step(tparams, dparams, opt_state, tokens, pos, depth, labels,
                   rng, extras):
        def micro(acc, xs):
            toks, labs, ex = xs
            tout = model.forward(tparams, toks, mode="train",
                                 collect_taps=True, **ex)
            taps = jax.lax.stop_gradient(tout.taps)
            if tcfg.family == "vlm" and taps.shape[1] != toks.shape[1]:
                taps = taps[:, -toks.shape[1]:]

            def loss_fn(dp):
                logits, _ = D.mtp_forward(dcfg, tcfg, dp, toks, taps,
                                          pos, depth, rng=rng)
                return losses.mtp_loss(logits, labs, depth)

            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(dparams)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32) / n_micro,
                               acc, grads)
            return acc, loss

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             dparams)
        xs = (tokens.reshape(n_micro, mb, -1),
              labels.reshape(n_micro, mb, -1),
              {k: v.reshape((n_micro, mb) + v.shape[1:])
               for k, v in extras.items()})
        grads, per_micro_loss = jax.lax.scan(micro, zeros, xs)
        updates, opt_state, om = adamw_update(
            grads, opt_state, dparams, lr=sched,
            weight_decay=tc.weight_decay, max_grad_norm=tc.max_grad_norm)
        dparams = apply_updates(dparams, updates)
        return dparams, opt_state, per_micro_loss.mean()

    def make_inputs(mesh):
        tparams_sds = eval_shape_tree(model.init, jax.random.PRNGKey(0))
        dparams_sds = eval_shape_tree(
            lambda k: D.init_params(dcfg, tcfg, k), jax.random.PRNGKey(0))
        opt_sds = eval_shape_tree(adamw_init, dparams_sds)
        tl = model.text_len(n, "train")
        args = dict(
            tparams=tparams_sds, dparams=dparams_sds, opt_state=opt_sds,
            tokens=jax.ShapeDtypeStruct((GB, tl), jnp.int32),
            pos=jax.ShapeDtypeStruct((M,), jnp.int32),
            depth=jax.ShapeDtypeStruct((M,), jnp.int32),
            labels=jax.ShapeDtypeStruct((GB, M), jnp.int32),
            rng=jax.ShapeDtypeStruct((2,), jnp.uint32),
        )
        extras = {k: jax.ShapeDtypeStruct(s, d)
                  for k, (s, d) in extras_shapes.items()}
        with mesh_context(mesh):
            shardings = dict(
                tparams=_shard_tree(mesh, tparams_sds, param_specs(tparams_sds)),
                dparams=_shard_tree(mesh, dparams_sds, param_specs(dparams_sds)),
                opt_state=_shard_tree(mesh, opt_sds, param_specs(opt_sds)),
                tokens=NamedSharding(mesh, batch_spec(mesh, None)),
                pos=NamedSharding(mesh, P()),
                depth=NamedSharding(mesh, P()),
                labels=NamedSharding(mesh, batch_spec(mesh, None)),
                rng=NamedSharding(mesh, P()),
            )
            ex_sh = {k: NamedSharding(mesh, batch_spec(mesh, None, None))
                     for k in extras}
        return args, extras, shardings, ex_sh

    return train_step, make_inputs


# ---------------------------------------------------------------------------
# prefill step
# ---------------------------------------------------------------------------

def build_prefill_step(tcfg: ModelConfig, shape_name: str = "prefill_32k",
                       cache_dtype=jnp.bfloat16):
    shape = INPUT_SHAPES[shape_name]
    model = get_model(tcfg)
    GB, S = shape.global_batch, shape.seq_len
    extras_shapes = extra_input_shapes(tcfg, GB, "prefill")

    def prefill_step(tparams, tokens, cache, extras):
        out = model.forward(tparams, tokens, mode="prefill", cache=cache,
                            collect_taps=True, head_last_only=True, **extras)
        first = jnp.argmax(out.logits[:, -1], axis=-1).astype(jnp.int32)
        return out.cache, out.taps[:, -1], first

    def make_inputs(mesh):
        tparams_sds = eval_shape_tree(model.init, jax.random.PRNGKey(0))
        tl = model.text_len(S, "prefill")
        cache_sds = eval_shape_tree(
            functools.partial(model.make_cache, GB, S, dtype=cache_dtype))
        args = dict(
            tparams=tparams_sds,
            tokens=jax.ShapeDtypeStruct((GB, tl), jnp.int32),
            cache=cache_sds,
        )
        extras = {k: jax.ShapeDtypeStruct(s, d)
                  for k, (s, d) in extras_shapes.items()}
        with mesh_context(mesh):
            shardings = dict(
                tparams=_shard_tree(mesh, tparams_sds, param_specs(tparams_sds)),
                tokens=NamedSharding(mesh, batch_spec(mesh, None)),
                cache=_shard_tree(mesh, cache_sds, cache_specs(cache_sds)),
            )
            ex_sh = {k: NamedSharding(mesh, batch_spec(mesh, None, None))
                     for k in extras}
        return args, extras, shardings, ex_sh

    return prefill_step, make_inputs


# ---------------------------------------------------------------------------
# serve (decode) step — one speculative iteration
# ---------------------------------------------------------------------------

def build_serve_step(tcfg: ModelConfig, dcfg: DrafterConfig,
                     shape_name: str, *, K: int = 5,
                     cache_dtype=jnp.bfloat16,
                     drafter_mode: str = "parallel"):
    shape = INPUT_SHAPES[shape_name]
    model = get_model(tcfg)
    GB, S = shape.global_batch, shape.seq_len
    max_len = S + 64
    ecfg = EngineConfig(K=K, max_new_tokens=1 << 30,
                        drafter_mode=drafter_mode,
                        cache_dtype="bfloat16", max_len=max_len)

    def serve_step(tparams, dparams, state):
        return speculative_step(model, tcfg, dcfg, ecfg, tparams, dparams,
                                state)

    def make_state():
        # one skeleton definition (serving/engine.py) shared with the Engine
        return make_decode_state(model, tcfg, dcfg, ecfg, GB,
                                 cache_dtype=cache_dtype,
                                 taps_dtype=jnp.bfloat16, last_fill=S)

    def make_inputs(mesh):
        tparams_sds = eval_shape_tree(model.init, jax.random.PRNGKey(0))
        dparams_sds = eval_shape_tree(
            lambda k: D.init_params(dcfg, tcfg, k, dtype=jnp.bfloat16),
            jax.random.PRNGKey(0))
        state_sds = eval_shape_tree(make_state)
        with mesh_context(mesh):
            bsp = batch_spec(mesh)
            state_specs = {
                "tokens": spec_for((GB, max_len), bsp[0]),
                "logprobs": spec_for((GB, max_len), bsp[0]),
                "last": spec_for((GB,), bsp[0]),
                "taps_last": spec_for((GB, 3 * tcfg.d_model), bsp[0], "model"),
                "tcache": cache_specs(state_sds["tcache"]),
                "dcache": cache_specs(state_sds["dcache"]),
                "new_count": spec_for((GB,), bsp[0]),
                "slot_iters": spec_for((GB,), bsp[0]),
                "iters": P(), "row_iters": P(), "committed": P(),
                # per-slot decoding-policy rows (serving/sampling.py)
                "sampling": {"temperature": spec_for((GB,), bsp[0]),
                             "top_k": spec_for((GB,), bsp[0]),
                             "top_p": spec_for((GB,), bsp[0]),
                             "key": spec_for((GB, 2), bsp[0])},
            }
            state_sh = {}
            for k in state_sds:
                sp = state_specs[k]
                if isinstance(sp, P):
                    state_sh[k] = NamedSharding(mesh, sp)
                else:
                    state_sh[k] = jax.tree.map(
                        lambda s: NamedSharding(mesh, s), sp)
            shardings = dict(
                tparams=_shard_tree(mesh, tparams_sds, param_specs(tparams_sds)),
                dparams=_shard_tree(mesh, dparams_sds, param_specs(dparams_sds)),
                state=state_sh,
            )
        args = dict(tparams=tparams_sds, dparams=dparams_sds, state=state_sds)
        return args, {}, shardings, {}

    return serve_step, make_inputs
