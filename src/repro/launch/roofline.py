"""Roofline accounting from compiled dry-run artifacts.

Three terms (per chip, seconds) against TPU v5e constants:

    compute    = HLO_FLOPs / (chips × 197e12)
    memory     = HLO_bytes / (chips × 819e9)
    collective = collective_bytes / (chips × 50e9)

``cost_analysis()`` supplies FLOPs / bytes-accessed. Collective bytes are
NOT in cost_analysis: we parse the post-SPMD optimized HLO
(``compiled.as_text()``) and sum the output-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
The optimized module is the per-partition program, so parsed byte counts are
already per chip.
"""
from __future__ import annotations

import re
from typing import Dict

from repro.configs.base import HW

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-reduce.5 = f32[16,6144]{1,0} all-reduce(...)
#       ROOT %fusion = (bf16[8,128]{...}, f32[...]) tuple-ish
# match sync ops and the async "-start" form (the "-done" half carries the
# same shape and would double count)
_OP_RE = re.compile(
    r"=\s*((?:\()?[a-z0-9]+\[[0-9,]*\][^ ]*)\s+(" + "|".join(_COLLECTIVES)
    + r")(?:-start)?[(\s]")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, dict]:
    """Per-collective-kind {count, bytes} from optimized HLO text."""
    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        ty, kind = m.group(1), m.group(2)
        out[kind]["count"] += 1
        out[kind]["bytes"] += _shape_bytes(ty)
    return out


def roofline_terms(cost: dict, coll: Dict[str, dict], n_chips: int,
                   model_flops: float = 0.0) -> dict:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    # cost_analysis on an SPMD-partitioned module reports per-partition
    # numbers; collective bytes parsed from the per-partition program too.
    cbytes = sum(v["bytes"] for v in coll.values())
    t_compute = flops / HW["peak_flops"]
    t_memory = byts / HW["hbm_bw"]
    t_coll = cbytes / HW["ici_bw"]
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll,
             "flops_per_chip": flops, "bytes_per_chip": byts,
             "collective_bytes_per_chip": cbytes}
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    terms["bottleneck"] = dom
    if model_flops:
        total_hlo = flops * n_chips
        terms["model_flops"] = model_flops
        terms["useful_flops_ratio"] = model_flops / max(total_hlo, 1.0)
    return terms


def model_flops_estimate(tcfg, shape, dcfg=None, k_infer: int = 5) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) 'useful' FLOPs for the workload."""
    n_params = param_count(tcfg, active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        flops = 2.0 * n_params * tokens          # frozen target fwd only
        if dcfg is not None:
            d_params = drafter_param_count(dcfg, tcfg)
            from repro.core import cod
            m = cod.expanded_length(shape.seq_len, dcfg.k_train,
                                    dcfg.cod_rate)
            flops += 6.0 * d_params * shape.global_batch * m
        return flops
    if shape.kind == "prefill":
        return 2.0 * n_params * shape.global_batch * shape.seq_len
    # decode: one speculative iteration = K+1 target tokens + K drafter slots
    flops = 2.0 * n_params * shape.global_batch * (k_infer + 1)
    if dcfg is not None:
        flops += 2.0 * drafter_param_count(dcfg, tcfg) * \
            shape.global_batch * k_infer
    return flops


def param_count(cfg, active_only: bool = False) -> float:
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab_size
    emb = V * d * (1 if cfg.tie_embeddings else 2)
    if cfg.family == "ssm":
        di = cfg.ssm.expand * d
        per = d * (2 * di + 2 * cfg.ssm.d_state +
                   di // cfg.ssm.head_dim) + di * d
        return emb + L * per
    attn = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim \
        + cfg.n_heads * cfg.head_dim * d
    glu = cfg.mlp_variant in ("swiglu", "geglu")
    mlp = d * cfg.d_ff * (3 if glu else 2)
    total = emb
    for li in range(L):
        total += attn
        if cfg.is_moe_layer(li):
            e = (cfg.moe.top_k if active_only else cfg.moe.n_experts)
            total += mlp * (e + cfg.moe.n_shared_experts)
        else:
            total += mlp
    if cfg.family == "hybrid":
        W = cfg.hybrid.lru_width or d
        total += L * (2 * d * W + 2 * W * W + W * d) * 2 // 3  # rec slots
    if cfg.n_encoder_layers:
        total += cfg.n_encoder_layers * (attn + mlp)
    return float(total)


def drafter_param_count(dcfg, tcfg) -> float:
    d = dcfg.d_model
    per = d * (dcfg.n_heads + 2 * dcfg.n_kv_heads) * dcfg.head_dim \
        + dcfg.n_heads * dcfg.head_dim * d + 3 * d * dcfg.d_ff
    return float(tcfg.vocab_size * d * 2 + dcfg.num_taps * tcfg.d_model * d
                 + 2 * d * d + dcfg.n_layers * per)
