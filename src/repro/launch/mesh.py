"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — device count is locked on first jax init, and the
dry-run must set XLA_FLAGS before that happens (see dryrun.py).

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16); the batch
            shards over ("pod", "data") and parameters/caches over "model".
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, *,
                    multi_pod: bool = False):
    """Small host-device mesh for tests (requires
    --xla_force_host_platform_device_count >= product)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))
