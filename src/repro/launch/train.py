"""Production training launcher.

On a TPU pod this runs the real distributed P-EAGLE training step (the same
function the dry-run lowers) under ``make_production_mesh``; on CPU it runs
the reduced configuration end-to-end so the whole pipeline (data → COD →
segments → step → checkpoint) is exercised anywhere.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
        --reduced --epochs 10 --segments 2 --ckpt results/ckpt
"""
from __future__ import annotations

import argparse

import jax

from repro.checkpoint import save_pytree
from repro.configs import DrafterConfig, get_config
from repro.data import MTPPipeline, markov_corpus, self_generated_corpus
from repro.models import get_model, make_extras
from repro.training import Trainer, TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale config (default on non-TPU backends)")
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=48)
    ap.add_argument("--n-seqs", type=int, default=64)
    ap.add_argument("--k-train", type=int, default=8)
    ap.add_argument("--cod-rate", type=float, default=0.8)
    ap.add_argument("--segments", type=int, default=1)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--variant", default="shared")
    ap.add_argument("--lr", type=float, default=1e-4)
    ap.add_argument("--ar-baseline", action="store_true")
    ap.add_argument("--data", default="self",
                    choices=["self", "markov"])
    ap.add_argument("--ckpt", default="results/ckpt")
    args = ap.parse_args()

    reduced = args.reduced or jax.default_backend() != "tpu"
    tcfg = get_config(args.arch)
    if reduced:
        tcfg = tcfg.reduced()
    model = get_model(tcfg)
    key = jax.random.PRNGKey(0)
    print(f"init target {args.arch} (reduced={reduced}) ...")
    tparams = model.init(key)

    if args.data == "self":
        extras_fn = ((lambda b: make_extras(tcfg, b, "prefill", key))
                     if tcfg.family in ("vlm", "encdec") else None)
        corpus = self_generated_corpus(
            model, tparams, seed=1, n_seqs=args.n_seqs,
            seq_len=args.seq_len, batch=min(16, args.n_seqs),
            extras_fn=extras_fn)
    else:
        corpus = markov_corpus(0, args.n_seqs, args.seq_len,
                               tcfg.vocab_size)

    dcfg = DrafterConfig(
        n_layers=args.layers, k_train=args.k_train, cod_rate=args.cod_rate,
        hidden_state_variant=args.variant,
        parallel=not args.ar_baseline).resolve(tcfg)
    pipe = MTPPipeline(corpus, k_train=dcfg.k_train,
                       cod_rate=dcfg.cod_rate, batch=args.batch, seed=0,
                       segments=args.segments)
    extras = (make_extras(tcfg, args.batch, "train", key)
              if tcfg.family in ("vlm", "encdec") else {})
    steps = args.epochs * max(len(corpus) // args.batch, 1)
    tr = Trainer(tcfg, dcfg, tparams, TrainConfig(lr=args.lr,
                                                  total_steps=steps),
                 extras=extras)
    tr.train(pipe, epochs=args.epochs, log_every=5)
    fn = save_pytree(tr.dparams, args.ckpt,
                     f"drafter_{args.arch}", step=steps)
    print(f"saved {fn}")


if __name__ == "__main__":
    main()
