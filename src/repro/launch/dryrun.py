import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, record memory/cost/collective analysis for the
roofline (EXPERIMENTS.md §Dry-run / §Roofline).

MUST be the first jax-touching import in the process: the two lines above
create 512 host platform devices so ``jax.make_mesh((2,16,16), ...)`` works
on this CPU-only container. Do NOT set that flag globally — smoke tests and
benchmarks need the real single device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
      --shape decode_32k [--multipod] [--out results/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (adapt_for_shape, build_prefill_step,
                                build_serve_step, build_train_step,
                                mesh_context, resolve_drafter)


def flatten_shardings(args: dict, extras: dict, shardings: dict,
                      ex_sh: dict, order):
    arg_vals = [args[k] for k in order]
    shd_vals = [shardings[k] for k in order]
    if extras is not None:
        arg_vals.append(extras)
        shd_vals.append(ex_sh)
    return tuple(arg_vals), tuple(shd_vals)


def run_one(arch: str, shape_name: str, multi_pod: bool,
            *, k_infer: int = 5, n_micro: int = 8,
            variant: str = "baseline") -> dict:
    t0 = time.time()
    shape = INPUT_SHAPES[shape_name]
    tcfg = adapt_for_shape(get_config(arch), shape_name)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "variant": variant}
    if tcfg is None:
        rec["status"] = "skip"
        rec["reason"] = get_config(arch).long_context
        return rec

    # "optimized" (§Perf): drafter block remat + flash custom-VJP attention
    # + last-position prefill head + p-cast attention (the latter three are
    # code-level fixes measured against the archived baseline results).
    dcfg = resolve_drafter(tcfg, remat=(variant == "optimized"))
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size

    if shape.kind == "train":
        fn, make_inputs = build_train_step(tcfg, dcfg, shape_name,
                                           n_micro=n_micro)
        order = ["tparams", "dparams", "opt_state", "tokens", "pos",
                 "depth", "labels", "rng"]
        donate = (1, 2)
    elif shape.kind == "prefill":
        fn, make_inputs = build_prefill_step(tcfg, shape_name)
        order = ["tparams", "tokens", "cache"]
        donate = (2,)
    else:
        fn, make_inputs = build_serve_step(tcfg, dcfg, shape_name, K=k_infer)
        order = ["tparams", "dparams", "state"]
        donate = (2,)

    args, extras, shardings, ex_sh = make_inputs(mesh)
    has_extras = shape.kind in ("train", "prefill")
    arg_vals, shd_vals = flatten_shardings(
        args, extras if has_extras else None, shardings,
        ex_sh if has_extras else None, order)

    with mesh_context(mesh):
        jitted = jax.jit(fn, in_shardings=shd_vals, donate_argnums=donate)
        lowered = jitted.lower(*arg_vals)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()

    coll = RL.collective_stats(hlo)
    model_flops = RL.model_flops_estimate(tcfg, shape, dcfg, k_infer)
    terms = RL.roofline_terms(cost or {}, coll, n_chips,
                              model_flops=model_flops)
    rec.update(
        status="ok",
        compile_s=round(time.time() - t0, 1),
        n_chips=n_chips,
        memory=dict(
            argument_bytes=getattr(mem, "argument_size_in_bytes", None),
            output_bytes=getattr(mem, "output_size_in_bytes", None),
            temp_bytes=getattr(mem, "temp_size_in_bytes", None),
            code_bytes=getattr(mem, "generated_code_size_in_bytes", None),
            alias_bytes=getattr(mem, "alias_size_in_bytes", None),
        ),
        collectives=coll,
        roofline=terms,
    )
    # fits-in-HBM check: args + temp − aliased, against 16 GB v5e
    try:
        live = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                + mem.output_size_in_bytes - mem.alias_size_in_bytes)
        rec["memory"]["live_bytes"] = int(live)
        rec["memory"]["fits_16GB"] = bool(live < 16e9)
    except Exception:
        pass
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--n-micro", type=int, default=8)
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "optimized"])
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = [False, True] if (args.all or args.both_meshes) \
        else [args.multipod]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}"
                out_fn = os.path.join(args.out, tag + ".json")
                if os.path.exists(out_fn):
                    print(f"[skip existing] {tag}")
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    rec = run_one(arch, shape, mp, k_infer=args.k,
                                  n_micro=args.n_micro, variant=args.variant)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()}
                with open(out_fn, "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"  -> {rec['status']}"
                      + (f" ({rec.get('compile_s')}s, "
                         f"bottleneck={rec['roofline']['bottleneck']})"
                         if rec.get("status") == "ok" else
                         f" {rec.get('error', rec.get('reason', ''))}"),
                      flush=True)


if __name__ == "__main__":
    main()
