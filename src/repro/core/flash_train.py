"""Memory-efficient training attention: flash forward + custom-VJP flash
backward (recompute-by-block), for the drafter's MTP attention.

Why: differentiating the online-softmax ``lax.scan`` stores per-block
probability residuals — O(M²) floats per layer. At the paper's training
configuration (n=4096, K_train=8 → M≈17k expanded positions) that is tens
of GB per chip and dominates the train_4k memory roofline (§Perf pair A
baseline). The flash backward stores only (out, m, l) and recomputes
probabilities blockwise: attention training memory drops O(M²) → O(M·bk).

Masking uses the closed-form MTP predicate evaluated from (pos, depth)
int32 metadata — the same beyond-paper closed form as the Pallas kernel
(kernels/mtp_attention.py); integer metadata gets None cotangents.
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp

from repro.core.masks import mtp_mask_predicate
from repro.models.layers import NEG_INF

Array = jax.Array


def _mask_block(pos, depth, q_idx, k_idx):
    """(B,M) metadata -> bool (B,1,1,Sq,Bk) via the closed-form predicate."""
    qd = jnp.take(depth, q_idx, axis=1)
    qp = jnp.take(pos, q_idx, axis=1)
    kd = jnp.take(depth, k_idx, axis=1)
    kp = jnp.take(pos, k_idx, axis=1)
    ok = jax.vmap(lambda a, b, c, d: mtp_mask_predicate(
        a, b, c, d, np_mod=jnp))(qd, qp, kd, kp)
    return ok[:, None, None]


def _fwd_pass(q, k, v, pos, depth, scale, bk):
    B, M, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    nb = M // bk
    qr = q.reshape(B, M, KV, G, hd)
    kb = k.reshape(B, nb, bk, KV, hd).swapaxes(0, 1)
    vb = v.reshape(B, nb, bk, KV, hd).swapaxes(0, 1)

    def body(carry, inp):
        m, l, acc = carry
        j, kj, vj = inp
        s = jnp.einsum("bqkgd,bjkd->bkgqj", qr, kj,
                       preferred_element_type=jnp.float32) * scale
        ok = _mask_block(pos, depth, jnp.arange(M), j * bk + jnp.arange(bk))
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.where(ok, jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgqj,bjkd->bkgqd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, KV, G, M), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, M), jnp.float32)
    a0 = jnp.zeros((B, KV, G, M, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (jnp.arange(nb), kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.where((l > 0)[..., None], out, 0.0)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, M, H, hd).astype(q.dtype)
    return out, m, l


@lru_cache(maxsize=None)
def _make(scale: float, bk: int):
    @jax.custom_vjp
    def fn(q, k, v, pos, depth):
        out, _, _ = _fwd_pass(q, k, v, pos, depth, scale, bk)
        return out

    def fwd(q, k, v, pos, depth):
        out, m, l = _fwd_pass(q, k, v, pos, depth, scale, bk)
        return out, (q, k, v, pos, depth, out, m, l)

    def bwd(res, do):
        q, k, v, pos, depth, out, m, l = res
        B, M, H, hd = q.shape
        KV = k.shape[2]
        G = H // KV
        nb = M // bk
        qr = q.reshape(B, M, KV, G, hd)
        dor = do.reshape(B, M, KV, G, hd)
        # D_i = rowsum(dO * O)
        Drow = jnp.einsum("bqkgd,bqkgd->bkgq", dor.astype(jnp.float32),
                          out.reshape(B, M, KV, G, hd).astype(jnp.float32))
        linv = 1.0 / jnp.maximum(l, 1e-30)
        kb = k.reshape(B, nb, bk, KV, hd).swapaxes(0, 1)
        vb = v.reshape(B, nb, bk, KV, hd).swapaxes(0, 1)

        def body(dq, inp):
            j, kj, vj = inp
            s = jnp.einsum("bqkgd,bjkd->bkgqj", qr, kj,
                           preferred_element_type=jnp.float32) * scale
            ok = _mask_block(pos, depth, jnp.arange(M),
                             j * bk + jnp.arange(bk))
            s = jnp.where(ok, s, NEG_INF)
            p = jnp.where(ok, jnp.exp(s - m[..., None]), 0.0) \
                * linv[..., None]                          # normalized probs
            dv_j = jnp.einsum("bkgqj,bqkgd->bjkd", p.astype(jnp.float32),
                              dor.astype(jnp.float32))
            dp = jnp.einsum("bqkgd,bjkd->bkgqj", dor, vj,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - Drow[..., None]) * scale
            dq = dq + jnp.einsum("bkgqj,bjkd->bqkgd", ds.astype(kj.dtype),
                                 kj, preferred_element_type=jnp.float32)
            dk_j = jnp.einsum("bkgqj,bqkgd->bjkd", ds.astype(jnp.float32),
                              qr.astype(jnp.float32))
            return dq, (dk_j, dv_j)

        dq0 = jnp.zeros((B, M, KV, G, hd), jnp.float32)
        dq, (dk_b, dv_b) = jax.lax.scan(body, dq0,
                                        (jnp.arange(nb), kb, vb))
        dk = dk_b.swapaxes(0, 1).reshape(B, M, KV, hd)
        dv = dv_b.swapaxes(0, 1).reshape(B, M, KV, hd)
        dq = dq.reshape(B, M, H, hd)
        return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
                None, None)

    fn.defvjp(fwd, bwd)
    return fn


def mtp_flash_attention(q: Array, k: Array, v: Array, pos: Array,
                        depth: Array, *, scale: float,
                        block_k: int = 512) -> Array:
    """q (B,M,H,hd); k/v (B,M,KV,hd); pos/depth (B,M) int32 (-1 pad).
    M must be a multiple of block_k' = min(block_k, divisor of M)."""
    M = q.shape[1]
    bk = min(block_k, M)
    while M % bk:
        bk -= 1
    return _make(float(scale), int(bk))(q, k, v, pos, depth)
