"""MTP attention masks — the paper's §3.1 plus the TPU closed form.

Parametrization (consistent with paper Figs. 3–4): an MTP *position* is a
pair (g, p) of prediction depth g ∈ [0, K) and RoPE position p. Its *anchor*
a = p − g is the end of the real context it drafts from, and it predicts
token[p + 1] (depth g predicts the token g+1 positions ahead of its anchor).

Attention predicate (closed form):

    attend((g, p) → (g', p'))  ⇔  (g' = 0 ∧ p' ≤ p − g)            # real ctx
                               ∨  (p' − g' = p − g ∧ g' ≤ g)       # own chain

i.e. a position sees its anchor's real context plus the lower-depth positions
of its *own* chain (same anchor). Depth 0 reduces to plain causal attention.

Three implementations, used as baseline → paper → beyond-paper:

1. ``pard_style_mask``      — O(M²) per-example construction (PARD baseline,
                              Table 2's slow path).
2. ``precompute_full_mask`` + ``extract_mask`` — the paper's amortized
   construction: one max-length mask at init, per-example O(1)-ish retrieval
   by row/col gather in the interleaved (p·K + g) layout, whose
   position-invariance (Fig. 3) makes every shorter mask the top-left
   submatrix of the longer one.
3. ``mtp_mask_predicate``   — the closed form evaluated lazily from two int32
   metadata vectors; zero precompute, zero HBM mask traffic. This is what the
   blocked-jnp attention and the Pallas ``mtp_attention`` kernel use
   (DESIGN.md §3 hardware adaptation).

Padding convention: depth < 0 marks padding; it attends nothing and nothing
attends it.
"""
from __future__ import annotations

import numpy as np

try:  # jnp version of the predicate (used inside jitted attention)
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jnp = None


# ---------------------------------------------------------------------------
# 3) closed form
# ---------------------------------------------------------------------------

def mtp_mask_predicate(q_depth, q_pos, k_depth, k_pos, np_mod=np):
    """Boolean matrix (len(q), len(k)) of the closed-form predicate.

    Works for numpy and jax.numpy (pass np_mod=jnp)."""
    qg = q_depth[:, None]
    qp = q_pos[:, None]
    kg = k_depth[None, :]
    kp = k_pos[None, :]
    anchor_q = qp - qg
    anchor_k = kp - kg
    real_ctx = (kg == 0) & (kp <= anchor_q)
    own_chain = (anchor_k == anchor_q) & (kg <= qg)
    valid = (qg >= 0) & (kg >= 0)
    return (real_ctx | own_chain) & valid


# ---------------------------------------------------------------------------
# 2) paper: amortized construction + retrieval
# ---------------------------------------------------------------------------

def interleaved_index(pos, depth, K: int):
    """Layout index p*K + g — appending tokens only appends indices, so the
    mask of any sequence is the top-left submatrix of the max-length mask."""
    return pos * K + depth


def precompute_full_mask(n_max: int, K: int) -> np.ndarray:
    """One-time (n_max·K)² bool mask in interleaved layout (paper §3.1)."""
    idx = np.arange(n_max * K)
    pos, depth = idx // K, idx % K
    return mtp_mask_predicate(depth, pos, depth, pos)


def extract_mask(full: np.ndarray, pos: np.ndarray, depth: np.ndarray,
                 K: int) -> np.ndarray:
    """Per-example retrieval: row/col gather of the precomputed mask at the
    COD-sampled positions (constant-time view for contiguous non-COD slices;
    a single O(M²) gather under COD — no predicate re-evaluation)."""
    idx = interleaved_index(pos, depth, K)
    return full[np.ix_(idx, idx)]


# ---------------------------------------------------------------------------
# 1) PARD-style per-example construction (the baseline the paper beats)
# ---------------------------------------------------------------------------

def pard_style_mask(pos: np.ndarray, depth: np.ndarray) -> np.ndarray:
    """Rebuilds the mask from scratch for one example, the way a per-batch
    mask constructor does: multiple O(M²) predicate passes + allocations.
    Matches ``extract_mask`` output exactly (tested)."""
    M = len(pos)
    mask = np.zeros((M, M), dtype=bool)
    anchors = pos - depth
    # pass 1: real-context visibility, one depth at a time (as in per-group
    # mask builders: they iterate groups and OR in block masks)
    for g in sorted(set(depth.tolist())):
        qsel = depth == g
        ctx = (depth[None, :] == 0) & (pos[None, :] <= anchors[qsel][:, None])
        mask[qsel] |= ctx
    # pass 2: chain visibility
    for g in sorted(set(depth.tolist())):
        qsel = depth == g
        chain = (anchors[None, :] == anchors[qsel][:, None]) & \
                (depth[None, :] <= g)
        mask[qsel] |= chain
    pad = depth < 0
    mask[pad] = False
    mask[:, pad] = False
    return mask


# ---------------------------------------------------------------------------
# helpers for training batches
# ---------------------------------------------------------------------------

def sort_by_layout(pos: np.ndarray, depth: np.ndarray, K: int):
    """Order positions by interleaved index (p, then g) — the layout under
    which the amortized property (Fig. 3) holds. Returns permutation."""
    return np.argsort(interleaved_index(pos, depth, K), kind="stable")


def labels_for(pos: np.ndarray, tokens_row: np.ndarray,
               pad_id: int = -1) -> np.ndarray:
    """Every MTP position (g, p) predicts token[p+1]."""
    n = len(tokens_row)
    tgt = pos + 1
    ok = (tgt >= 0) & (tgt < n)
    return np.where(ok, tokens_row[np.clip(tgt, 0, n - 1)], pad_id)
