"""Speculative-decoding verification: greedy prefix matching and lossless
rejection sampling (Leviathan et al. 2023 / Chen et al. 2023), plus the
acceptance-length bookkeeping the paper reports.

All shapes static, all rows independent — jit/pjit friendly.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def greedy_verify(draft_tokens: Array,
                  target_logits: Array) -> Tuple[Array, Array]:
    """draft_tokens (B, K); target_logits (B, K+1, V) for positions
    c..c+K (position c+i predicts token c+i+1).

    Returns (accept_len (B,) in [0, K], committed (B, K+1)) where
    committed[:, :accept_len+1] are the tokens to append: the accepted drafts
    (identical to target argmax) plus the bonus/correction token.
    """
    t_star = jnp.argmax(target_logits, axis=-1).astype(jnp.int32)  # (B, K+1)
    K = draft_tokens.shape[1]
    match = draft_tokens == t_star[:, :K]
    # accept_len = length of the all-True prefix
    accept_len = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)
    return accept_len, t_star


def rejection_verify(key: Array, draft_tokens: Array, draft_probs: Array,
                     target_probs: Array) -> Tuple[Array, Array]:
    """Lossless stochastic verification.

    draft_probs (B, K, V) — drafter distributions the drafts were sampled
    from; target_probs (B, K+1, V). Token i accepted w.p.
    min(1, p_i(d_i)/q_i(d_i)); on first rejection the replacement is sampled
    from norm(max(p - q, 0)); if all accepted, bonus ~ p_{K}.

    Returns (accept_len (B,), committed (B, K+1)).
    """
    B, K, V = draft_probs.shape
    ks = jax.random.split(key, 3)
    u = jax.random.uniform(ks[0], (B, K))
    q_d = jnp.take_along_axis(draft_probs, draft_tokens[..., None],
                              axis=-1)[..., 0]
    p_d = jnp.take_along_axis(target_probs[:, :K], draft_tokens[..., None],
                              axis=-1)[..., 0]
    ok = u < jnp.minimum(1.0, p_d / jnp.maximum(q_d, 1e-20))
    accept_len = jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=1), axis=1)

    # residual distribution at the first rejected slot
    idx = jnp.minimum(accept_len, K - 1)
    p_rej = jnp.take_along_axis(target_probs, idx[:, None, None], axis=1)[:, 0]
    q_rej = jnp.take_along_axis(draft_probs, idx[:, None, None], axis=1)[:, 0]
    resid = jnp.maximum(p_rej - q_rej, 0.0)
    resid = resid / jnp.maximum(resid.sum(-1, keepdims=True), 1e-20)
    resample = jax.random.categorical(ks[1], jnp.log(resid + 1e-20), axis=-1)

    bonus = jax.random.categorical(
        ks[2], jnp.log(target_probs[:, K] + 1e-20), axis=-1)

    committed = jnp.where(
        jnp.arange(K + 1)[None, :] < accept_len[:, None],
        jnp.pad(draft_tokens, ((0, 0), (0, 1))), 0).astype(jnp.int32)
    fix = jnp.where(accept_len == K, bonus, resample).astype(jnp.int32)
    committed = committed.at[jnp.arange(B), accept_len].set(fix)
    return accept_len, committed


def update_acceptance_stats(stats: dict, accept_len: Array,
                            active: Optional[Array] = None) -> dict:
    """Running mean of tokens committed per iteration (= accept_len + 1,
    the paper's acceptance length)."""
    n = accept_len.shape[0] if active is None else jnp.sum(active)
    tok = accept_len + 1
    tok = tok if active is None else jnp.where(active, tok, 0)
    return {"iters": stats.get("iters", 0) + n,
            "tokens": stats.get("tokens", 0) + jnp.sum(tok)}


def acceptance_length(stats: dict) -> float:
    return float(stats["tokens"]) / max(float(stats["iters"]), 1.0)
