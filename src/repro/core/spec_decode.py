"""Speculative-decoding verification: greedy prefix matching, lossless
rejection sampling (Leviathan et al. 2023 / Chen et al. 2023) with
per-request deterministic key streams, logit warping (temperature / top-k /
top-p applied identically to drafter and target rows), and the
acceptance-length bookkeeping the paper reports.

Verification policy is per ROW, not per engine: :func:`mixed_verify` runs
the argmax prefix-match path for ``temperature == 0`` rows and seeded
rejection sampling against the warped distributions for the rest, inside
one jitted step — a batch may freely mix greedy and sampled requests.

All shapes static, all rows independent — jit/pjit friendly.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def greedy_verify(draft_tokens: Array,
                  target_logits: Array) -> Tuple[Array, Array]:
    """draft_tokens (B, K); target_logits (B, K+1, V) for positions
    c..c+K (position c+i predicts token c+i+1).

    Returns (accept_len (B,) in [0, K], committed (B, K+1)) where
    committed[:, :accept_len+1] are the tokens to append: the accepted drafts
    (identical to target argmax) plus the bonus/correction token.
    """
    t_star = jnp.argmax(target_logits, axis=-1).astype(jnp.int32)  # (B, K+1)
    K = draft_tokens.shape[1]
    match = draft_tokens == t_star[:, :K]
    # accept_len = length of the all-True prefix
    accept_len = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)
    return accept_len, t_star


# ---------------------------------------------------------------------------
# logit warping (per-row temperature / top-k / top-p)
# ---------------------------------------------------------------------------

def warp_probs(logits: Array, temperature: Array, top_k: Array,
               top_p: Array) -> Array:
    """Per-row warped target/drafter distributions.

    Args:
      logits: (B, T, V) raw logits.
      temperature: (B,) — rows with ``temperature <= 0`` are warped at 1.0
        (their output is never consumed: greedy rows take the argmax path).
      top_k: (B,) — keep the k highest logits per position (0 disables).
        Ties at the k-th value are all kept, so the warp is deterministic.
      top_p: (B,) — nucleus filter: keep the smallest probability-sorted
        prefix with mass >= top_p (>= 1 disables; the top-1 token is always
        kept, so degenerate values from blank slots cannot produce an empty
        support).

    Returns:
      (B, T, V) probabilities, renormalized over the kept support. The same
      warp is applied to drafter and target rows, which is what makes the
      rejection verification lossless w.r.t. each request's *warped* target
      distribution.
    """
    B, T, V = logits.shape
    t = jnp.where(temperature > 0, temperature, 1.0)[:, None, None]
    z = logits / t
    # top-k: mask everything strictly below the k-th highest logit
    k = jnp.where(top_k > 0, jnp.minimum(top_k, V), V)
    z_sorted = jnp.sort(z, axis=-1)[..., ::-1]                    # descending
    kth = jnp.take_along_axis(
        z_sorted, jnp.broadcast_to((k - 1)[:, None, None], (B, T, 1)),
        axis=-1)
    z = jnp.where(z >= kth, z, -jnp.inf)
    p = jax.nn.softmax(z, axis=-1)
    # top-p: keep the minimal descending-sorted prefix reaching the mass;
    # implemented via the smallest kept probability so ties are all kept
    p_sorted = jnp.sort(p, axis=-1)[..., ::-1]
    csum = jnp.cumsum(p_sorted, axis=-1)
    keep_sorted = (csum - p_sorted) < top_p[:, None, None]
    keep_sorted = keep_sorted.at[..., 0].set(True)                # never empty
    p_min = jnp.min(jnp.where(keep_sorted, p_sorted, jnp.inf), axis=-1,
                    keepdims=True)
    p = jnp.where(p >= p_min, p, 0.0)
    return p / p.sum(-1, keepdims=True)


def sample_token(keys: Array, logits: Array, temperature: Array,
                 top_k: Array, top_p: Array) -> Array:
    """Mixed-policy single-token selection from (B, V) logits: argmax for
    ``temperature <= 0`` rows, a categorical draw from the warped
    distribution (one per-row ``key``) for the rest."""
    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    probs = warp_probs(logits[:, None, :], temperature, top_k, top_p)[:, 0]
    samp = jax.vmap(
        lambda k, p: jax.random.categorical(k, jnp.log(p)))(keys, probs)
    return jnp.where(temperature > 0, samp.astype(jnp.int32), greedy_tok)


# ---------------------------------------------------------------------------
# lossless rejection verification (seeded, per-row)
# ---------------------------------------------------------------------------

def _rejection_verify_row(key: Array, draft_tokens: Array, draft_probs: Array,
                          target_probs: Array,
                          k_row: Array) -> Tuple[Array, Array]:
    """One row: draft_tokens (K,), draft_probs (K, V), target_probs
    (K+1, V), k_row scalar int32 in [0, K]; see :func:`rejection_verify`.

    ``k_row`` is the row's effective draft length (the adaptive-K max-K
    mask): slots >= k_row are force-rejected, and the proposal mass at a
    forced-rejection slot is zeroed so the resample there draws from the
    FULL warped target — i.e. truncating speculation degrades to plain
    sampling at that position, never to a biased residual. With
    ``k_row == K`` every branch below is bitwise identical to the unmasked
    verifier (same key splits, same uniform draws, same selects).
    """
    K, V = draft_probs.shape
    ks = jax.random.split(key, 3)
    u = jax.random.uniform(ks[0], (K,))
    ar = jnp.arange(K)
    q_d = draft_probs[ar, draft_tokens]
    p_d = target_probs[ar, draft_tokens]
    # accept token i w.p. min(1, p/q): u < min(1, p/q) <=> u*q < p (u < 1
    # always), with q == 0 handled exactly — no epsilon fudge. Slots at or
    # beyond k_row are force-rejected (max-K mask).
    ok = (u * q_d < p_d) & (ar < k_row)
    accept_len = jnp.sum(jnp.cumprod(ok.astype(jnp.int32)))

    # residual distribution at the first rejected slot: norm(max(p - q, 0)),
    # renormalized explicitly — zero entries stay exactly zero (log 0 =
    # -inf, never drawn); a fully-zero residual (p == q bitwise, so
    # rejection there has probability 0) falls back to the target row.
    # At a FORCED rejection (idx == k_row) no draft was really proposed, so
    # q is zeroed: the residual is the full target row and the "resample"
    # is an exact sample from p — the lossless bonus-token semantics.
    idx = jnp.minimum(accept_len, K - 1)
    p_rej = target_probs[idx]
    q_rej = jnp.where(idx < k_row, draft_probs[idx],
                      jnp.zeros_like(draft_probs[idx]))
    resid = jnp.maximum(p_rej - q_rej, 0.0)
    mass = resid.sum()
    resid = jnp.where(mass > 0, resid / jnp.where(mass > 0, mass, 1.0), p_rej)
    resample = jax.random.categorical(ks[1], jnp.log(resid))

    bonus = jax.random.categorical(ks[2], jnp.log(target_probs[K]))

    committed = jnp.where(ar < accept_len, draft_tokens, 0)
    committed = jnp.append(committed, 0).astype(jnp.int32)
    fix = jnp.where(accept_len == K, bonus, resample).astype(jnp.int32)
    committed = committed.at[accept_len].set(fix)
    return accept_len, committed


def rejection_verify_rows(keys: Array, draft_tokens: Array,
                          draft_probs: Array, target_probs: Array,
                          k_row: Optional[Array] = None
                          ) -> Tuple[Array, Array]:
    """Lossless stochastic verification with PER-ROW keys (B, 2) uint32 —
    the serving path: each request's key is derived from its own
    ``SamplingParams.seed`` (serving/sampling.py), so a row's outcome is
    independent of batch composition and slot index.

    draft_tokens (B, K); draft_probs (B, K, V) — drafter distributions;
    target_probs (B, K+1, V). Token i accepted w.p. min(1, p_i(d_i) /
    q_i(d_i)); on first rejection the replacement is sampled from
    norm(max(p - q, 0)); if all accepted, bonus ~ p_K.

    ``k_row`` (B,) int32 is the optional per-row effective draft length
    (adaptive K): slots >= k_row[b] are force-rejected with the proposal
    mass zeroed there (see :func:`_rejection_verify_row`). ``None`` means
    the full K for every row — bitwise identical to the pre-adaptive
    verifier.

    Returns (accept_len (B,), committed (B, K+1)).
    """
    if k_row is None:
        k_row = jnp.full(draft_tokens.shape[:1], draft_tokens.shape[1],
                         jnp.int32)
    return jax.vmap(_rejection_verify_row)(keys, draft_tokens, draft_probs,
                                           target_probs, k_row)


def rejection_verify(key: Array, draft_tokens: Array, draft_probs: Array,
                     target_probs: Array,
                     k_row: Optional[Array] = None) -> Tuple[Array, Array]:
    """Whole-batch convenience wrapper: split ``key`` into per-row keys and
    verify (see :func:`rejection_verify_rows`)."""
    B = draft_tokens.shape[0]
    return rejection_verify_rows(jax.random.split(key, B), draft_tokens,
                                 draft_probs, target_probs, k_row)


def mixed_verify(keys: Array, draft_tokens: Array, draft_probs: Array,
                 target_logits: Array, temperature: Array, top_k: Array,
                 top_p: Array,
                 k_row: Optional[Array] = None) -> Tuple[Array, Array]:
    """Per-row mixed-policy verification inside ONE jitted step.

    ``temperature == 0`` rows take the exact greedy prefix-match path on the
    RAW target logits (bit-identical to the pre-SamplingParams engine);
    sampled rows run seeded rejection verification of ``draft_tokens``
    against the row-warped target distribution.

    ``draft_probs`` (B, K, V) must be the distribution the drafts were
    ACTUALLY drawn from — that is what makes rejection sampling lossless.
    For argmax drafts (a deterministic proposal) that is a one-hot:
    acceptance then reduces to ``u < p(d)`` and the residual to
    ``norm(p masked at d)``, which keeps the committed distribution exactly
    the warped target. With ``EngineConfig.draft_sampling`` the engine
    instead draws drafts from the row-warped DRAFTER distribution and
    passes that distribution here (``warp_probs`` applies identically to
    drafter logits) — higher overlap with the warped target, longer
    acceptance.

    ``k_row`` (B,) int32 optionally caps each row's effective draft length
    (adaptive K). Greedy rows clip their matched prefix at k_row — the
    correction token ``t_star[accept_len]`` is the target argmax at that
    position, so a greedy stream's CONTENT is unchanged by any k_row
    sequence (only commit pacing moves). Sampled rows force-reject slots
    >= k_row losslessly (see :func:`_rejection_verify_row`).

    Returns (accept_len (B,), committed (B, K+1))."""
    acc_g, t_star = greedy_verify(draft_tokens, target_logits)
    if k_row is not None:
        acc_g = jnp.minimum(acc_g, k_row)
    p = warp_probs(target_logits, temperature, top_k, top_p)
    acc_s, comm_s = rejection_verify_rows(keys, draft_tokens, draft_probs, p,
                                          k_row)
    is_greedy = temperature <= 0
    return (jnp.where(is_greedy, acc_g, acc_s),
            jnp.where(is_greedy[:, None], t_star, comm_s))


# ---------------------------------------------------------------------------
# acceptance-length bookkeeping
# ---------------------------------------------------------------------------

def update_acceptance_stats(stats: dict, accept_len: Array,
                            active: Optional[Array] = None,
                            iters: Optional[Array] = None) -> dict:
    """Running mean of tokens committed per iteration (= accept_len + 1,
    the paper's acceptance length).

    ``active`` masks out frozen/blank rows: an inactive row contributes
    zero iterations and zero tokens. Callers with a partially idle batch
    MUST pass it — with ``active is None`` every row of ``accept_len`` is
    credited an iteration, which silently deflates the running mean that
    the adaptive-K controller steers on.

    ``iters`` (B,) optionally weights each row as that many iterations
    (default 1): ``accept_len`` is then the row's total ACCEPTED drafts
    over those iterations, so committed tokens are ``accept_len + iters``.
    This is how the host-side controller folds multi-iteration harvest
    deltas into the same running aggregate.

    Safe under an all-False ``active`` mask: the update contributes zero
    iterations and zero tokens, and the carried ``mean`` divides by
    ``max(iters, 1)`` — never by ``sum(active) == 0`` — so an idle batch
    cannot poison the running mean with NaN."""
    w = jnp.ones(accept_len.shape, jnp.int32) if iters is None else iters
    n = jnp.sum(w) if active is None else jnp.sum(jnp.where(active, w, 0))
    tok = accept_len + w
    tok = tok if active is None else jnp.where(active, tok, 0)
    iters_tot = stats.get("iters", 0) + n
    tokens = stats.get("tokens", 0) + jnp.sum(tok)
    return {"iters": iters_tot, "tokens": tokens,
            "mean": tokens / jnp.maximum(jnp.asarray(iters_tot), 1)}


def acceptance_length(stats: dict) -> float:
    return float(stats["tokens"]) / max(float(stats["iters"]), 1.0)
