"""Sequence partitioning for within-sequence gradient accumulation
(paper §3.2, Algorithm 1).

Splits one COD-expanded sequence into S segments such that every position's
cross-depth dependency ((g, p) → (g-1, p-1)) lands in the same segment, then
augments each segment's *key* set with the cumulative depth-0 positions up to
its boundary so causal attention over real context is preserved. Each segment
is a separate forward/backward; gradients accumulate across segments
(optim/accumulate.py), cutting peak attention memory O(L²) → O(L²/S²).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np


@dataclass
class Segment:
    """One gradient-accumulation micro-step of a single sequence.

    ``q_*``   — positions whose loss/gradient this segment owns.
    ``kv_*``  — attention key set: q positions ∪ cumulative depth-0 context
                (N_s in Algorithm 1). Sorted in interleaved layout order.
    ``q_in_kv`` — indices of the q positions inside the kv arrays.
    """
    q_pos: np.ndarray
    q_depth: np.ndarray
    kv_pos: np.ndarray
    kv_depth: np.ndarray
    q_in_kv: np.ndarray


def assign_segments(pos: np.ndarray, depth: np.ndarray, L: int,
                    S: int) -> np.ndarray:
    """Algorithm 1 Phases 1–2: segment id per expanded position.

    Phase 1: depths 0 and 1 assigned by position against uniform boundaries
    B_s = s·L/S. Phase 2: depth g ≥ 2 inherits the assignment of its
    dependency (g-1, p-1) — propagated iteratively, so a whole chain follows
    its depth-1 member and never straddles a boundary.
    """
    bounds = (np.arange(S + 1) * L) // S                    # B_0..B_S
    seg_of_pos = np.searchsorted(bounds, np.arange(L), side="right") - 1
    seg_of_pos = np.clip(seg_of_pos, 0, S - 1)

    A = np.full(len(pos), -1, np.int64)
    # index lookup: (g, p) -> row
    lut = {}
    for i, (g, p) in enumerate(zip(depth.tolist(), pos.tolist())):
        lut[(g, p)] = i

    order = np.argsort(depth, kind="stable")                # by depth g asc
    for i in order.tolist():
        g, p = int(depth[i]), int(pos[i])
        if g < 0:
            continue
        if g <= 1:
            A[i] = seg_of_pos[p]                            # Phase 1
        else:
            dep = lut.get((g - 1, p - 1))                   # Phase 2
            if dep is None:                                 # (chain-closed COD
                A[i] = seg_of_pos[p]                        #  never hits this)
            else:
                A[i] = A[dep]
    return A


def build_segments(pos: np.ndarray, depth: np.ndarray, L: int,
                   S: int) -> List[Segment]:
    """Algorithm 1 Phase 3 + segment materialization."""
    A = assign_segments(pos, depth, L, S)
    bounds = (np.arange(S + 1) * L) // S
    segs: List[Segment] = []
    d0 = depth == 0
    for s in range(S):
        qsel = A == s
        if not qsel.any():
            continue
        # N_s: cumulative depth-0 positions below the segment's upper boundary
        ctx = d0 & (pos < bounds[s + 1])
        kv_sel = qsel | ctx
        kv_idx = np.nonzero(kv_sel)[0]
        # keep interleaved layout order (input is already sorted that way)
        kv_pos, kv_depth = pos[kv_idx], depth[kv_idx]
        q_idx = np.nonzero(qsel)[0]
        lookup = {int(i): j for j, i in enumerate(kv_idx.tolist())}
        q_in_kv = np.array([lookup[int(i)] for i in q_idx.tolist()], np.int64)
        segs.append(Segment(q_pos=pos[q_idx], q_depth=depth[q_idx],
                            kv_pos=kv_pos, kv_depth=kv_depth,
                            q_in_kv=q_in_kv))
    return segs


def check_dependencies_preserved(segs: List[Segment], pos: np.ndarray,
                                 depth: np.ndarray) -> bool:
    """Every key a query may attend (per the closed-form predicate) that
    exists in the example must be present in that segment's kv set — the
    invariant Algorithm 1 guarantees. Used by property tests."""
    exists = set(zip(depth.tolist(), pos.tolist()))
    for seg in segs:
        kv = set(zip(seg.kv_depth.tolist(), seg.kv_pos.tolist()))
        for g, p in zip(seg.q_depth.tolist(), seg.q_pos.tolist()):
            a = p - g
            for gk in range(1, g + 1):          # own chain members (depth>=1)
                member = (gk, a + gk)
                if member != (g, p) and member in exists and member not in kv:
                    return False
            # real context: all sampled depth-0 positions <= anchor
            need = {(0, q) for q in range(0, a + 1) if (0, q) in exists}
            if not need.issubset(kv):
                return False
    return True
