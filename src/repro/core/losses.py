"""Training losses: MTP cross-entropy (per-depth weighted), EAGLE-3 TTT
unroll for the AR baseline, and HCA (harmonized context alignment).

Labels use -1 as ignore (padding / positions whose target falls off the
sequence end)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def cross_entropy(logits: Array, labels: Array) -> Array:
    """Per-position CE with -1 ignore; returns (B, M) with 0 at ignored."""
    valid = labels >= 0
    safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return jnp.where(valid, ce, 0.0)


def mtp_loss(logits: Array, labels: Array, depth: Array, *,
             depth_weight_decay: float = 1.0) -> Tuple[Array, dict]:
    """logits (B,M,V), labels (B,M), depth (M,) or (B,M). Mean CE over valid
    positions, optionally down-weighting deeper prediction depths.
    Metrics: overall/NTP/MTP token accuracy and per-depth accuracy sums."""
    if depth.ndim == 1:
        depth = depth[None, :]
    ce = cross_entropy(logits, labels)
    valid = (labels >= 0) & (depth >= 0)
    w = jnp.where(depth >= 0,
                  depth_weight_decay ** jnp.maximum(depth, 0), 0.0)
    w = jnp.where(valid, w, 0.0)
    loss = jnp.sum(ce * w) / jnp.maximum(jnp.sum(w), 1e-9)

    pred = jnp.argmax(logits, axis=-1)
    hit = (pred == labels) & valid
    is_ntp = depth == 0
    is_mtp = depth > 0

    def rate(num, den):
        return jnp.sum(num) / jnp.maximum(jnp.sum(den), 1)

    metrics = {
        "loss": loss,
        "acc": rate(hit, valid),
        "ntp_acc": rate(hit & is_ntp, valid & is_ntp),
        "mtp_acc": rate(hit & is_mtp, valid & is_mtp),
        "valid_tokens": jnp.sum(valid),
    }
    return loss, metrics


def hca_loss(hidden: Array, target_feat: Array, valid: Array) -> Array:
    """Harmonized context alignment (Zhang et al. 2024), adapted: align the
    drafter's pre-head hidden at p with the target-conditioned feature the
    *next* drafter position consumes (fc(taps)[p+1]) — smooth-L1."""
    d = hidden.astype(jnp.float32) - target_feat.astype(jnp.float32)
    ad = jnp.abs(d)
    sl1 = jnp.where(ad < 1.0, 0.5 * d * d, ad - 0.5).mean(-1)
    return jnp.sum(sl1 * valid) / jnp.maximum(jnp.sum(valid), 1e-9)


def ttt_forward_loss(dcfg, tcfg, params: dict, tokens: Array, taps: Array,
                     *, steps: Optional[int] = None,
                     hca_weight: float = 0.1) -> Tuple[Array, dict]:
    """EAGLE-3 training-time test for the AR baseline (paper footnote 2).

    Step 0 feeds true target features; step j >= 1 replaces the hidden input
    at position p with the drafter's own step-(j-1) hidden at p-1 — exactly
    the mismatch the drafter sees when autoregressively chaining at
    inference. Tokens stay teacher-forced. Losses sum across steps.
    """
    from repro.core import drafter as D
    steps = steps or dcfg.ttt_steps
    B, n = tokens.shape
    pos = jnp.arange(n, dtype=jnp.int32)
    depth = jnp.zeros((n,), jnp.int32)
    labels = jnp.concatenate(
        [tokens[:, 2:], jnp.full((B, 2), -1, tokens.dtype)], axis=1)

    fc_all = taps.astype(params["fc"].dtype) @ params["fc"]
    tok_in = jnp.concatenate(
        [tokens[:, 1:], jnp.full((B, 1), 0, tokens.dtype)], axis=1)
    emb = D.embed_tokens(dcfg, params, tok_in)
    positions = jnp.broadcast_to(pos[None], (B, n))

    import repro.models.layers as L
    mask_fn = L.causal_mask_fn(positions)

    total = jnp.zeros((), jnp.float32)
    metrics = {}
    hid_in = fc_all
    for j in range(steps):
        x = jnp.concatenate([emb, hid_in], axis=-1) @ params["fuse"]
        x, _ = D._run_blocks(dcfg, params, x, positions=positions,
                             mask_fn=mask_fn, cache=None, mode="train")
        logits, hidden = D._head(dcfg, params, x)
        loss, m = mtp_loss(logits, labels, depth)
        if dcfg.hca:
            valid = (labels >= 0).astype(jnp.float32)
            tgt = jnp.concatenate([fc_all[:, 1:], fc_all[:, -1:]], axis=1)
            loss = loss + hca_weight * hca_loss(hidden, tgt, valid)
        total = total + loss
        metrics[f"step{j}_acc"] = m["acc"]
        # next step consumes own hiddens, shifted right by one position
        hid_in = jnp.concatenate(
            [fc_all[:, :1], hidden[:, :-1].astype(fc_all.dtype)], axis=1)
    metrics["loss"] = total
    metrics["acc"] = metrics[f"step{steps - 1}_acc"]
    return total, metrics
