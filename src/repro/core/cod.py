"""Conditional Drop-token (COD) sampling — PARD's geometric position decay,
made *chain-closed* and *fixed-count* so that (a) Algorithm 1's dependency
propagation (core/partition.py) is always well defined, and (b) batch shapes
are static for jit/pjit.

Depth g retains round(n·r^g) positions. We sample nested anchor sets
A_0 ⊇ A_1 ⊇ … ⊇ A_{K-1} and set P_g = {a + g : a ∈ A_g, a + g + 1 < n};
nesting guarantees every (g, p) has its dependency (g-1, p-1) present —
the property the paper's partitioning relies on (§3.2). Counts depend only on
(n, K, r), so the total expanded length M is deterministic.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def depth_counts(n: int, K: int, r: float) -> np.ndarray:
    """Retained positions per depth: c_0 = n, c_g = round(n·r^g), adjusted so
    c_g is non-increasing and depth-g anchors fit (a + g + 1 <= n - 1)."""
    c = np.round(n * (r ** np.arange(K))).astype(np.int64)
    c[0] = n
    for g in range(1, K):
        c[g] = min(c[g], c[g - 1], max(n - g - 1, 0))
    return np.maximum(c, 0)


def expanded_length(n: int, K: int, r: float) -> int:
    return int(depth_counts(n, K, r).sum())


def sample_cod(rng: np.random.Generator, n: int, K: int,
               r: float) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (pos, depth) int32 arrays of length expanded_length(n, K, r),
    sorted in interleaved layout order (p, then g)."""
    c = depth_counts(n, K, r)
    anchors = np.arange(n, dtype=np.int64)
    positions, depths = [anchors.copy()], [np.zeros(n, np.int64)]
    current = anchors[: max(n - 2, 0)]  # depth>=1 anchors need a+g+1 <= n-1
    for g in range(1, K):
        limit = n - g - 1               # a + g + 1 <= n - 1  =>  a <= n-g-2
        current = current[current <= max(limit, -1)]
        take = min(int(c[g]), len(current))
        if take <= 0:
            break
        sel = rng.choice(len(current), size=take, replace=False)
        current = np.sort(current[sel])
        positions.append(current + g)
        depths.append(np.full(take, g, np.int64))
    pos = np.concatenate(positions)
    depth = np.concatenate(depths)
    order = np.argsort(pos * K + depth, kind="stable")
    return pos[order].astype(np.int32), depth[order].astype(np.int32)


def pad_to(pos: np.ndarray, depth: np.ndarray, M: int):
    """Pad with (pos=-1, depth=-1) to static length M (mask & loss ignore)."""
    m = len(pos)
    if m > M:
        raise ValueError(f"expanded length {m} exceeds static budget {M}")
    ppos = np.full(M, -1, np.int32)
    pdep = np.full(M, -1, np.int32)
    ppos[:m] = pos
    pdep[:m] = depth
    return ppos, pdep
