from repro.core import cod, drafter, losses, masks, partition, spec_decode

__all__ = ["cod", "drafter", "losses", "masks", "partition", "spec_decode"]
