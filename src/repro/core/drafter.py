"""P-EAGLE drafter (paper §2) and the AR EAGLE-3 baseline.

The drafter is a LLaMA-3-style transformer conditioned on target hidden
states: taps from target layers (2, L/2, L-1) are concatenated (3·D_t),
projected by ``fc`` to the drafter width, fused with the token embedding
through ``fuse`` ([emb; hidden] → D), then run through N blocks.

Position pairing follows EAGLE: drafter RoPE position p carries
(taps[p], emb(token[p+1])) and predicts token[p+2]. An MTP position at depth
g (RoPE p, anchor a = p − g) lacks both inputs and substitutes the learnable
``h_shared`` for the hidden and the mask-token embedding for the token; it
predicts token[p+2] = the (g+1)-th token after the committed context.

Hidden-state variants (paper §4.1 / Appendix B.2):
  shared           — h_shared                                  (the winner)
  depth_encoding   — h_shared + e_depth[g]
  ntp_hidden       — h_shared + proj(fc(taps[anchor]))
  ntp_hidden_depth — h_shared + proj(fc(taps[anchor])) + e_depth[g]
  regularized      — h_shared + α · dropout(proj(fc(taps[anchor])))

Parallel drafting at inference needs no special mask: the K draft slots form
a single chain (equal anchors), for which the closed-form MTP predicate
degenerates to plain causal attention over [cache ∪ block]; only the NTP
slot is committed to the drafter KV cache (depth-0 semantics).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DrafterConfig, ModelConfig
from repro.core import spec_decode as SD
from repro.core.masks import mtp_mask_predicate
from repro.models import layers as L
from repro.sharding.utils import shard_hint

Array = jax.Array


def mask_token_id(tcfg: ModelConfig) -> int:
    return tcfg.vocab_size - 1          # reserved unused id (paper §4.3)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_params(dcfg: DrafterConfig, tcfg: ModelConfig, key: Array,
                dtype=jnp.float32) -> dict:
    d = dcfg.d_model
    ks = jax.random.split(key, 8)

    def block_init(k):
        ka, km = jax.random.split(k)
        return {
            "ln1": jnp.ones((d,), jnp.float32),
            "ln2": jnp.ones((d,), jnp.float32),
            "attn": {
                "wq": L.dense_init(jax.random.fold_in(ka, 0), (d, dcfg.n_heads * dcfg.head_dim), dtype=dtype),
                "wk": L.dense_init(jax.random.fold_in(ka, 1), (d, dcfg.n_kv_heads * dcfg.head_dim), dtype=dtype),
                "wv": L.dense_init(jax.random.fold_in(ka, 2), (d, dcfg.n_kv_heads * dcfg.head_dim), dtype=dtype),
                "wo": L.dense_init(jax.random.fold_in(ka, 3), (dcfg.n_heads * dcfg.head_dim, d), dtype=dtype),
            },
            "mlp": L.mlp_init(km, d, dcfg.d_ff, "swiglu", dtype),
        }

    params = {
        "embed": L.embed_init(ks[0], tcfg.vocab_size, d, dtype),
        "fc": L.dense_init(ks[1], (dcfg.num_taps * tcfg.d_model, d), dtype=dtype),
        "fuse": L.dense_init(ks[2], (2 * d, d), dtype=dtype),
        "h_shared": 0.02 * jax.random.normal(ks[3], (d,), jnp.float32).astype(dtype),
        "blocks": jax.vmap(block_init)(jax.random.split(ks[4], dcfg.n_layers)),
        "final_norm": jnp.ones((d,), jnp.float32),
        "lm_head": L.dense_init(ks[5], (d, tcfg.vocab_size), dtype=dtype),
    }
    v = dcfg.hidden_state_variant
    if v in ("depth_encoding", "ntp_hidden_depth"):
        params["depth_emb"] = 0.02 * jax.random.normal(
            ks[6], (max(dcfg.k_train, dcfg.k_infer) + 1, d), jnp.float32).astype(dtype)
    if v in ("ntp_hidden", "ntp_hidden_depth", "regularized"):
        params["ntp_proj"] = L.dense_init(ks[7], (d, d), dtype=dtype)
    if v == "regularized":
        params["alpha"] = jnp.asarray(0.1, jnp.float32)   # init per App. B.2
    return params


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _block_apply(dcfg: DrafterConfig, p: dict, x: Array, *,
                 positions: Array, mask_fn, cache: Optional[dict],
                 mode: str, flash_meta=None) -> Tuple[Array, Optional[dict]]:
    B, T, D = x.shape
    H, KV, hd = dcfg.n_heads, dcfg.n_kv_heads, dcfg.head_dim
    h = L.rms_norm(x, p["ln1"], dcfg.norm_eps)
    q = (h @ p["attn"]["wq"]).reshape(B, T, H, hd)
    k = (h @ p["attn"]["wk"]).reshape(B, T, KV, hd)
    v = (h @ p["attn"]["wv"]).reshape(B, T, KV, hd)
    rp = jnp.maximum(positions, 0)
    sin, cos = L.rope_sincos(rp, hd, dcfg.rope_theta)
    q = L.apply_rope(q, sin, cos)
    k = L.apply_rope(k, sin, cos)
    q = shard_hint(q, ("pod", "data"), None, "model")

    new_cache = cache
    if mode == "train":
        if flash_meta is not None:
            # flash fwd + custom-VJP bwd: O(M·bk) training attention memory
            # instead of O(M²) scan residuals (core/flash_train.py).
            from repro.core.flash_train import mtp_flash_attention
            out = mtp_flash_attention(q, k, v, flash_meta[0], flash_meta[1],
                                      scale=hd ** -0.5)
        else:
            out = L.blocked_attention(q, k, v, scale=hd ** -0.5,
                                      mask_fn=mask_fn)
    else:
        # inference: attend [old cache] + [current block] two-phase (LSE
        # merge — no cache copy); block entries are a single chain so plain
        # causal-by-position masking applies (see module docstring).
        old_kpos = jnp.where(cache["positions"] >= positions[:, :1], -1,
                             cache["positions"])
        o1, m1, l1 = L.blocked_attention(
            q, cache["k"].astype(q.dtype), cache["v"].astype(q.dtype),
            scale=hd ** -0.5, mask_fn=L.cache_mask_fn(positions, old_kpos),
            return_stats=True)
        o2, m2, l2 = L.blocked_attention(
            q, k, v, scale=hd ** -0.5,
            mask_fn=L.cache_mask_fn(positions, positions),
            return_stats=True)
        out = L.merge_attention(o1, m1, l1, o2, m2, l2)
        if mode == "draft":
            # commit only slot 0 (the NTP position) to the cache
            new_cache = L.cache_update(cache, k[:, :1], v[:, :1],
                                       positions[:, 0])
        else:                    # extend: commit all (depth-0 tokens)
            new_cache = L.cache_update(cache, k, v, positions[:, 0])
    out = out.reshape(B, T, H * hd) @ p["attn"]["wo"]
    x = x + out
    h = L.rms_norm(x, p["ln2"], dcfg.norm_eps)
    x = x + L.mlp_apply(p["mlp"], h, "swiglu")
    return x, new_cache


def _run_blocks(dcfg, params, x, *, positions, mask_fn, cache, mode,
                flash_meta=None):
    if cache is None:
        def body(x, bp):
            x, _ = _block_apply(dcfg, bp, x, positions=positions,
                                mask_fn=mask_fn, cache=None, mode=mode,
                                flash_meta=flash_meta)
            return x, None
        if dcfg.remat and mode == "train":
            body = jax.checkpoint(body)   # block-boundary activation remat
        x, _ = jax.lax.scan(body, x, params["blocks"])
        return x, None

    def body(x, xs):
        bp, bc = xs
        x, nc = _block_apply(dcfg, bp, x, positions=positions,
                             mask_fn=mask_fn, cache=bc, mode=mode)
        return x, nc
    x, ncache = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
    return x, {"blocks": ncache}


def _head(dcfg, params, x):
    h = L.rms_norm(x, params["final_norm"], dcfg.norm_eps)
    logits = (h @ params["lm_head"]).astype(jnp.float32)
    # keep the vocab dim model-sharded — replicated f32 MTP-expanded logits
    # are ~20 GB/chip at the train_4k shape (§Perf pair A, iteration 3)
    logits = shard_hint(logits, ("pod", "data"), None, "model")
    return logits, h


def make_cache(dcfg: DrafterConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    per = L.make_kv_cache(batch, max_len, dcfg.n_kv_heads, dcfg.head_dim,
                          dtype=dtype, ring=False)
    return {"blocks": jax.tree.map(
        lambda a: jnp.broadcast_to(a, (dcfg.n_layers,) + a.shape).copy(), per)}


# ---------------------------------------------------------------------------
# input construction
# ---------------------------------------------------------------------------

def _hidden_inputs(dcfg: DrafterConfig, params: dict, fc_taps: Array,
                   depth: Array, anchor_fc: Array, *,
                   rng: Optional[Array]) -> Array:
    """Per-position drafter 'hidden' input: fc(taps) at depth 0, the variant
    formula at MTP depths. fc_taps (B,M,D) is fc(taps) gathered at each
    position p; anchor_fc (B,M,D) is fc(taps) gathered at each anchor."""
    v = dcfg.hidden_state_variant
    h = jnp.broadcast_to(params["h_shared"].astype(fc_taps.dtype),
                         fc_taps.shape)
    if v in ("depth_encoding", "ntp_hidden_depth"):
        de = params["depth_emb"][jnp.clip(depth, 0, params["depth_emb"].shape[0] - 1)]
        h = h + de.astype(h.dtype)
    if v in ("ntp_hidden", "ntp_hidden_depth", "regularized"):
        inj = anchor_fc @ params["ntp_proj"]
        if v == "regularized":
            if rng is not None:
                keep = jax.random.bernoulli(rng, 0.9, inj.shape)
                inj = inj * keep / 0.9
            inj = params["alpha"].astype(inj.dtype) * inj
        h = h + inj
    is_ntp = depth == 0                     # (M,) or (B, M)
    if is_ntp.ndim == 1:
        is_ntp = is_ntp[None, :]
    return jnp.where(is_ntp[..., None], fc_taps, h)


def embed_tokens(dcfg: DrafterConfig, params: dict, tok: Array) -> Array:
    emb = params["embed"]
    if dcfg.freeze_embeddings:
        emb = jax.lax.stop_gradient(emb)
    return emb[tok]


# ---------------------------------------------------------------------------
# training forward (MTP, full or segment)
# ---------------------------------------------------------------------------

def mtp_forward(dcfg: DrafterConfig, tcfg: ModelConfig, params: dict,
                tokens: Array, taps: Array, pos: Array, depth: Array, *,
                rng: Optional[Array] = None) -> Tuple[Array, Array]:
    """Training forward over COD-expanded positions.

    tokens (B, n) original sequence; taps (B, n, num_taps·D_t) target taps;
    pos/depth (M,) shared or (B, M) per-row expanded metadata (padding: -1).
    Returns (logits (B,M,V), hidden (B,M,D))."""
    B, n = tokens.shape
    if pos.ndim == 1:
        pos = jnp.broadcast_to(pos[None], (B, pos.shape[0]))
        depth = jnp.broadcast_to(depth[None], (B, depth.shape[0]))
    safe_pos = jnp.clip(pos, 0, n - 1)
    anchor = jnp.clip(pos - jnp.maximum(depth, 0), 0, n - 1)

    fc_all = taps.astype(params["fc"].dtype) @ params["fc"]     # (B, n, D)
    fc_at = jnp.take_along_axis(fc_all, safe_pos[..., None], axis=1)
    fc_anchor = jnp.take_along_axis(fc_all, anchor[..., None], axis=1)
    hid = _hidden_inputs(dcfg, params, fc_at, depth, fc_anchor, rng=rng)

    tok_in = jnp.take_along_axis(tokens, jnp.clip(safe_pos + 1, 0, n - 1),
                                 axis=1)
    tok_in = jnp.where(depth == 0, tok_in, mask_token_id(tcfg))
    emb = embed_tokens(dcfg, params, tok_in)

    x = jnp.concatenate([emb, hid], axis=-1) @ params["fuse"]
    x = shard_hint(x, ("pod", "data"), None, None)

    def mask_fn(q_idx, k_idx):
        qd = jnp.take(depth, q_idx, axis=1)            # (B, Sq)
        qp = jnp.take(pos, q_idx, axis=1)
        kd = jnp.take(depth, k_idx, axis=1)            # (B, Bk)
        kp = jnp.take(pos, k_idx, axis=1)
        ok = jax.vmap(lambda a, b, c, d: mtp_mask_predicate(
            a, b, c, d, np_mod=jnp))(qd, qp, kd, kp)   # (B, Sq, Bk)
        return ok[:, None, None]

    positions = jnp.maximum(pos, 0)
    # use the flash custom-VJP attention when the expanded length is large
    # enough that O(M²) scan residuals would dominate training memory
    flash_meta = (pos, depth) if (dcfg.flash_train
                                  and pos.shape[-1] >= 512) else None
    x, _ = _run_blocks(dcfg, params, x, positions=positions, mask_fn=mask_fn,
                       cache=None, mode="train", flash_meta=flash_meta)
    logits, hidden = _head(dcfg, params, x)
    return logits, hidden


# ---------------------------------------------------------------------------
# inference: extend / parallel draft / AR draft
# ---------------------------------------------------------------------------

def extend(dcfg: DrafterConfig, tcfg: ModelConfig, params: dict, cache: dict,
           tokens_next: Array, taps: Array, positions: Array) -> dict:
    """Commit T depth-0 positions: position p carries (taps[p], emb(t_{p+1})).

    tokens_next (B, T) = tokens p+1 aligned to taps (B, T, 3D_t);
    positions (B, T)."""
    fc = taps.astype(params["fc"].dtype) @ params["fc"]
    emb = embed_tokens(dcfg, params, tokens_next)
    x = jnp.concatenate([emb, fc], axis=-1) @ params["fuse"]
    _, ncache = _run_blocks(dcfg, params, x, positions=positions,
                            mask_fn=None, cache=cache, mode="extend")
    return ncache


def draft_block_inputs(dcfg, tcfg, params, token_next, taps_last, anchor_pos, K):
    """Build the K-slot parallel draft block (slot 0 = NTP, 1..K-1 = MTP)."""
    B = token_next.shape[0]
    fc = taps_last.astype(params["fc"].dtype) @ params["fc"]    # (B, D)
    fc = fc[:, None]                                            # (B, 1, D)
    depth = jnp.arange(K, dtype=jnp.int32)
    fc_b = jnp.broadcast_to(fc, (B, K, fc.shape[-1]))
    hid = _hidden_inputs(dcfg, params, fc_b, depth, fc_b, rng=None)
    tok = jnp.where((depth == 0)[None, :], token_next[:, None],
                    mask_token_id(tcfg))
    emb = embed_tokens(dcfg, params, tok)
    x = jnp.concatenate([emb, hid], axis=-1) @ params["fuse"]
    positions = anchor_pos[:, None] + depth[None, :]
    return x, positions


def draft_parallel(dcfg: DrafterConfig, tcfg: ModelConfig, params: dict,
                   cache: dict, token_next: Array, taps_last: Array,
                   anchor_pos: Array, K: int, policy=None):
    """P-EAGLE: one forward pass drafts K tokens (chain decoding).

    ``policy`` — optional ``(keys (B,K,2), temperature (B,), top_k (B,),
    top_p (B,))`` sampled-draft policy: rows with ``temperature > 0`` draw
    each draft slot from the row-warped drafter distribution
    (``warp_probs`` on the slot logits, one key per slot) instead of the
    argmax; greedy rows stay bitwise on the argmax path. The K slots are
    mask-token-conditioned in ONE forward, so the slot logits do not depend
    on which draft tokens are chosen — sampling post-forward from
    ``warp_probs(logits)`` IS sampling from the true proposal the verifier
    must be handed as ``q``.

    Returns (draft_tokens (B,K), draft_logits (B,K,V), new cache)."""
    x, positions = draft_block_inputs(dcfg, tcfg, params, token_next,
                                      taps_last, anchor_pos, K)
    x, ncache = _run_blocks(dcfg, params, x, positions=positions,
                            mask_fn=None, cache=cache, mode="draft")
    logits, _ = _head(dcfg, params, x)
    toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if policy is not None:
        keys, temperature, top_k, top_p = policy
        probs = SD.warp_probs(logits, temperature, top_k, top_p)
        drawn = jax.vmap(jax.vmap(
            lambda k, p: jax.random.categorical(k, jnp.log(p))))(keys, probs)
        toks = jnp.where((temperature > 0)[:, None],
                         drawn.astype(jnp.int32), toks)
    return toks, logits, ncache


def draft_ar(dcfg: DrafterConfig, tcfg: ModelConfig, params: dict,
             cache: dict, token_next: Array, taps_last: Array,
             anchor_pos: Array, K: int, policy=None):
    """AR EAGLE-3 baseline: K sequential single-position forwards; step i
    feeds (token d_i, drafter hidden h_i) into step i+1.

    ``policy`` as in :func:`draft_parallel`, but sampling MUST happen
    inside the scan: each drafted token is fed forward, so the slot-i
    logits are conditioned on the slots actually drawn before it — only
    in-scan draws make ``warp_probs(logits)`` the true per-slot proposal."""
    B = token_next.shape[0]
    fc = (taps_last.astype(params["fc"].dtype) @ params["fc"])  # (B, D)

    def step(carry, xs):
        i, keys_i = xs
        cache, tok, hid = carry
        emb = embed_tokens(dcfg, params, tok[:, None])          # (B,1,D)
        x = jnp.concatenate([emb, hid[:, None]], axis=-1) @ params["fuse"]
        positions = (anchor_pos + i)[:, None]
        x, ncache = _run_blocks(dcfg, params, x, positions=positions,
                                mask_fn=None, cache=cache, mode="extend")
        logits, h = _head(dcfg, params, x)
        if keys_i is None:
            nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        else:
            nxt = SD.sample_token(keys_i, logits[:, 0], temperature, top_k,
                                  top_p)
        return (ncache, nxt, h[:, 0]), (nxt, logits[:, 0])

    if policy is None:
        xs = (jnp.arange(K), None)
        temperature = top_k = top_p = None
    else:
        keys, temperature, top_k, top_p = policy
        xs = (jnp.arange(K), keys.swapaxes(0, 1))               # (K, B, 2)
    (cache, _, _), (toks, logits) = jax.lax.scan(
        step, (cache, token_next, fc), xs)
    return toks.swapaxes(0, 1), logits.swapaxes(0, 1), cache
