"""AdamW + schedules in pure JAX (no optax in this environment).

Matches the paper's training configuration: linear LR schedule with warmup
(§5.1: peak 1e-4, warmup ratio 0.0025), decoupled weight decay, global-norm
clipping. Optimizer state mirrors the parameter pytree, so the same sharding
specs apply (dryrun shards m/v alongside the drafter params).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


class AdamWState(NamedTuple):
    step: Array
    m: dict
    v: dict


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def linear_warmup_schedule(peak: float, total_steps: int,
                           warmup_ratio: float = 0.0025) -> Callable:
    warmup = max(int(total_steps * warmup_ratio), 1)

    def sched(step):
        s = step.astype(jnp.float32)
        up = peak * s / warmup
        down = peak * jnp.maximum(total_steps - s, 0.0) / max(
            total_steps - warmup, 1)
        return jnp.where(s < warmup, up, down)
    return sched


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def adamw_update(grads, state: AdamWState, params, *,
                 lr: Callable | float, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.01,
                 max_grad_norm: float = 1.0) -> Tuple[dict, AdamWState, dict]:
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else jnp.asarray(lr)
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g32
        v2 = b2 * v + (1 - b2) * jnp.square(g32)
        u = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
        u = u + weight_decay * p.astype(jnp.float32)
        return (-lr_t * u).astype(p.dtype), m2, v2

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    flat_p = tdef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    updates = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return updates, AdamWState(step=step, m=new_m, v=new_v), {
        "grad_norm": gnorm, "lr": lr_t}


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u, params, updates)
