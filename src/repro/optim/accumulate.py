"""Gradient accumulation — across micro-batches *and within a sequence*
(the paper's §3.2 novelty: a single COD-expanded sequence is split into
segments, each a separate forward/backward, summed here before one optimizer
step). The accumulator is jit-friendly: state is a grads pytree + counters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


class GradAccumulator:
    def __init__(self, params_like):
        self._zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params_like)

    def init(self):
        return {"grads": self._zeros, "weight": jnp.zeros((), jnp.float32)}

    @staticmethod
    def add(acc, grads, weight):
        """Accumulate `weight`-weighted gradient sums (weight = number of
        valid target tokens in the segment, so the final average is exact
        regardless of segment sizes)."""
        w = jnp.asarray(weight, jnp.float32)
        return {
            "grads": jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) * w, acc["grads"], grads),
            "weight": acc["weight"] + w,
        }

    @staticmethod
    def mean(acc):
        w = jnp.maximum(acc["weight"], 1e-9)
        return jax.tree.map(lambda a: a / w, acc["grads"])
