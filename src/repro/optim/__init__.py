from repro.optim.adamw import (AdamWState, adamw_init, adamw_update,
                               apply_updates, clip_by_global_norm,
                               linear_warmup_schedule)
from repro.optim.accumulate import GradAccumulator

__all__ = ["AdamWState", "adamw_init", "adamw_update", "apply_updates",
           "clip_by_global_norm", "linear_warmup_schedule", "GradAccumulator"]
