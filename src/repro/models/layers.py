"""Shared neural-net primitives for the target-model zoo and the drafter.

Everything is pure JAX on explicit parameter pytrees (no flax). Conventions:

- activations: ``(B, S, D)``; attention heads ``(B, S, H, head_dim)``.
- parameters are stored in float32 ("master") unless a caller casts them;
  forward code computes in ``compute_dtype`` with float32 softmax/accums.
- attention is *blocked*: an online-softmax ``lax.scan`` over KV blocks, so
  the lowered HLO never materializes an (Sq, Skv) score matrix. This is the
  CPU/dry-run twin of the Pallas ``flash_attention`` kernel (kernels/).
- masks are pluggable predicates over absolute positions, which is how the
  P-EAGLE closed-form MTP mask (core/masks.py) plugs into the same machinery.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

Array = jax.Array
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key: Array, shape, scale: Optional[float] = None,
               dtype=jnp.float32) -> Array:
    """Truncated-normal fan-in init (matches common LLM inits)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return std * jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32).astype(dtype)


def embed_init(key: Array, vocab: int, d: int, dtype=jnp.float32) -> Array:
    return 0.02 * jax.random.truncated_normal(key, -3.0, 3.0, (vocab, d), jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# norms / positions / activations
# ---------------------------------------------------------------------------

def rms_norm(x: Array, w: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (w.astype(jnp.float32))
    return out.astype(dt)


def rope_sincos(positions: Array, head_dim: int, theta: float):
    """positions (..., T) int -> sin/cos (..., T, head_dim//2) float32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: Array, sin: Array, cos: Array) -> Array:
    """x (B, T, H, hd); sin/cos (B, T, hd/2) or (T, hd/2)."""
    dt = x.dtype
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    if sin.ndim == 2:  # (T, half)
        s, c = sin[None, :, None, :], cos[None, :, None, :]
    else:              # (B, T, half)
        s, c = sin[:, :, None, :], cos[:, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(dt)


def sinusoidal_positions(positions: Array, d: int) -> Array:
    """Whisper-style absolute sinusoidal embeddings, (..., T) -> (..., T, d)."""
    half = d // 2
    scale = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (math.log(10_000.0) / max(half - 1, 1)))
    ang = positions.astype(jnp.float32)[..., None] * scale
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def softcap(x: Array, cap: float) -> Array:
    return cap * jnp.tanh(x / cap) if cap > 0.0 else x


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------

def mlp_init(key: Array, d: int, f: int, variant: str, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if variant in ("swiglu", "geglu"):
        return {"w_gate": dense_init(k1, (d, f), dtype=dtype),
                "w_up": dense_init(k2, (d, f), dtype=dtype),
                "w_down": dense_init(k3, (f, d), dtype=dtype)}
    return {"w_up": dense_init(k1, (d, f), dtype=dtype),
            "w_down": dense_init(k2, (f, d), dtype=dtype)}


def mlp_apply(p: dict, x: Array, variant: str) -> Array:
    if variant == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif variant == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"], approximate=True) * (x @ p["w_up"])
    elif variant == "relu2":
        h = jnp.square(jax.nn.relu(x @ p["w_up"]))
    elif variant == "gelu":
        h = jax.nn.gelu(x @ p["w_up"], approximate=True)
    else:
        raise ValueError(f"unknown mlp variant {variant!r}")
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# blocked attention (online softmax over KV blocks)
# ---------------------------------------------------------------------------

MaskFn = Callable[[Array, Array], Array]  # (q_idx (Sq,), k_idx (Bk,)) -> bool


def causal_mask_fn(q_positions: Array) -> MaskFn:
    """q_positions: (B, Sq) absolute positions; keys are indexed 0..Skv-1 and
    key slot j holds absolute position j (contiguous, non-ring layout)."""
    def fn(q_idx, k_idx):
        qp = jnp.take(q_positions, q_idx, axis=-1)        # (B, Sq)
        ok = qp[:, :, None] >= k_idx[None, None, :]
        return ok[:, None, None]                          # (B,1,1,Sq,Bk)
    return fn


def local_mask_fn(q_positions: Array, window: int) -> MaskFn:
    def fn(q_idx, k_idx):
        qp = jnp.take(q_positions, q_idx, axis=-1)
        d = qp[:, :, None] - k_idx[None, None, :]
        ok = (d >= 0) & (d < window)
        return ok[:, None, None]
    return fn


def cache_mask_fn(q_positions: Array, k_positions: Array,
                  window: int = 0) -> MaskFn:
    """Decode against a (possibly ring) cache with stored absolute positions.

    q_positions (B, Sq); k_positions (B, W) with -1 for empty slots.
    """
    def fn(q_idx, k_idx):
        qp = jnp.take(q_positions, q_idx, axis=-1)        # (B, Sq)
        kp = jnp.take(k_positions, k_idx, axis=-1)        # (B, Bk)
        ok = (kp[:, None, :] <= qp[:, :, None]) & (kp[:, None, :] >= 0)
        if window > 0:
            ok &= (qp[:, :, None] - kp[:, None, :]) < window
        return ok[:, None, None]                          # (B,1,1,Sq,Bk)
    return fn


def _pick_block(skv: int, want: int = 512) -> int:
    b = min(want, skv)
    while skv % b:
        b -= 1
    return max(b, 1)


def blocked_attention(q: Array, k: Array, v: Array, *,
                      scale: float,
                      mask_fn: Optional[MaskFn] = None,
                      logit_cap: float = 0.0,
                      block_k: int = 512,
                      return_stats: bool = False):
    """Flash-style attention in pure jnp.

    q (B, Sq, H, hd); k/v (B, Skv, KV, hd) with H % KV == 0 (GQA).
    mask_fn maps absolute (q_idx, k_idx) index vectors to a boolean array
    broadcastable to (B, KV, G, Sq, Bk). Accumulation is float32.

    With return_stats=True also returns the online-softmax (m, l) so two
    attention passes over disjoint key sets can be merged exactly
    (merge_attention) — used by the decode path to attend [old cache] and
    [current block] without copying the cache.
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    bk = _pick_block(Skv, block_k)
    n_blocks = Skv // bk

    qr = q.reshape(B, Sq, KV, G, hd)
    kb = k.reshape(B, n_blocks, bk, KV, hd)
    vb = v.reshape(B, n_blocks, bk, KV, hd)

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)

    def body(carry, inp):
        m, l, acc = carry
        jblk, kj, vj = inp
        s = jnp.einsum("bqkgd,bjkd->bkgqj", qr, kj,
                       preferred_element_type=jnp.float32) * scale
        s = softcap(s, logit_cap)
        k_idx = jblk * bk + jnp.arange(bk)
        ok = None
        if mask_fn is not None:
            ok = mask_fn(jnp.arange(Sq), k_idx)
            s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        if ok is not None:   # fully-masked rows: exp(-inf - -inf) = 1
            p = jnp.where(ok, p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        # cast p to the value dtype: a mixed f32×bf16 einsum upcasts its
        # bf16 operand, and XLA hoists that convert out of the KV loop —
        # materializing a full f32 copy of the cache (§Perf iteration 1).
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqj,bjkd->bkgqd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.arange(n_blocks), kb.swapaxes(0, 1), vb.swapaxes(0, 1)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.where((l > 0)[..., None], out, 0.0)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)
    if return_stats:
        return out, m, l
    return out


def merge_attention(o1: Array, m1: Array, l1: Array,
                    o2: Array, m2: Array, l2: Array) -> Array:
    """Exact merge of two online-softmax passes over disjoint key sets.

    o* (B, Sq, H, hd) normalized outputs; m*/l* (B, KV, G, Sq)."""
    B, Sq, H, hd = o1.shape
    KV = m1.shape[1]
    G = m1.shape[2]
    m = jnp.maximum(m1, m2)
    w1 = l1 * jnp.exp(m1 - m)
    w2 = l2 * jnp.exp(m2 - m)
    l = w1 + w2
    w1 = (w1 / jnp.maximum(l, 1e-30))
    w2 = (w2 / jnp.maximum(l, 1e-30))
    # reshape weights (B,KV,G,Sq) -> (B,Sq,H,1)
    def rs(w):
        return w.transpose(0, 3, 1, 2).reshape(B, Sq, H)[..., None]
    out = o1.astype(jnp.float32) * rs(w1) + o2.astype(jnp.float32) * rs(w2)
    out = jnp.where(rs(l > 0) > 0, out, 0.0)
    return out.astype(o1.dtype)


def full_attention(q: Array, k: Array, v: Array, *, scale: float,
                   mask: Optional[Array] = None, logit_cap: float = 0.0) -> Array:
    """Unblocked reference path (short sequences, e.g. whisper encoder)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qr = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgd,bjkd->bkgqj", qr, k,
                   preferred_element_type=jnp.float32) * scale
    s = softcap(s, logit_cap)
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqj,bjkd->bkgqd", p, v,
                     preferred_element_type=jnp.float32)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# KV caches
# ---------------------------------------------------------------------------

def make_kv_cache(batch: int, max_len: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16, ring: bool = False) -> dict:
    """A single layer's KV cache. ``positions`` stores absolute positions of
    each slot (-1 = empty) so ring (sliding-window) caches mask correctly."""
    return {
        "k": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        "positions": jnp.full((batch, max_len), -1, jnp.int32),
        "ring": jnp.array(ring),
    }


def cache_update(cache: dict, k_new: Array, v_new: Array,
                 pos: Array) -> dict:
    """Insert T new tokens at per-row absolute positions ``pos`` (B,).

    For ring caches the slot is ``position % W``. Any existing entry with
    position >= pos is *stale history being rewritten* (speculative decoding
    rolls back rejected drafts) and is invalidated first. Returns the updated
    cache.
    """
    B, T = k_new.shape[0], k_new.shape[1]
    W = cache["k"].shape[1]
    stale = cache["positions"] >= pos[:, None]
    cache = dict(cache)
    cache["positions"] = jnp.where(stale, -1, cache["positions"])
    abs_pos = pos[:, None] + jnp.arange(T)[None, :]          # (B, T)
    slot = jnp.where(cache["ring"], abs_pos % W, abs_pos)

    def upd_row(buf_k, buf_v, buf_p, kr, vr, sl, ap):
        bk = buf_k.at[sl].set(kr.astype(buf_k.dtype))
        bv = buf_v.at[sl].set(vr.astype(buf_v.dtype))
        bp = buf_p.at[sl].set(ap)
        return bk, bv, bp

    k2, v2, p2 = jax.vmap(upd_row)(cache["k"], cache["v"], cache["positions"],
                                   k_new, v_new, slot, abs_pos)
    return {"k": k2, "v": v2, "positions": p2, "ring": cache["ring"]}
