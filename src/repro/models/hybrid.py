"""RecurrentGemma (Griffin): RG-LRU recurrent blocks + local attention, 1:2.
[arXiv:2402.19427]

Layer pattern (recurrent, recurrent, attention) scanned as super-blocks of 3;
26 layers = 8 scanned blocks + a (recurrent, recurrent) tail. Train/prefill
run the RG-LRU with ``jax.lax.associative_scan`` (log-depth parallel scan —
the TPU-native mapping of the paper's linear recurrence); decode carries an
O(1) hidden state. Local attention uses a 2048-slot ring cache, so long_500k
decode memory is bounded (DESIGN.md §4).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.transformer import ModelOutput, tap_layers
from repro.sharding.utils import shard_hint

Array = jax.Array
_LRU_C = 8.0


def _lru_width(cfg: ModelConfig) -> int:
    return cfg.hybrid.lru_width or cfg.d_model


# ---------------------------------------------------------------------------
# RG-LRU recurrent mixer
# ---------------------------------------------------------------------------

def _rec_init(cfg: ModelConfig, key: Array, dtype) -> dict:
    W = _lru_width(cfg)
    cw = cfg.hybrid.conv_width
    ks = jax.random.split(key, 7)
    # Lambda init so that a = sigmoid(Lambda)^c lies in (0.9, 0.999)
    u = jax.random.uniform(ks[4], (W,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u ** (1.0 / _LRU_C) / (1 - u ** (1.0 / _LRU_C)))
    return {
        "in_x": L.dense_init(ks[0], (cfg.d_model, W), dtype=dtype),
        "in_gate": L.dense_init(ks[1], (cfg.d_model, W), dtype=dtype),
        "conv_w": L.dense_init(ks[2], (cw, W), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((W,), dtype),
        "w_rec_gate": L.dense_init(ks[3], (W, W), scale=0.02, dtype=dtype),
        "w_in_gate": L.dense_init(ks[5], (W, W), scale=0.02, dtype=dtype),
        "lam": lam,
        "out": L.dense_init(ks[6], (W, cfg.d_model), dtype=dtype),
    }


def _rg_lru(xb: Array, p: dict, h0: Optional[Array], mode: str):
    """xb (B, S, W) post-conv branch. Returns (h (B,S,W), h_last (B,W))."""
    r = jax.nn.sigmoid((xb @ p["w_rec_gate"]).astype(jnp.float32))
    i = jax.nn.sigmoid((xb @ p["w_in_gate"]).astype(jnp.float32))
    log_a = _LRU_C * r * jax.nn.log_sigmoid(p["lam"])        # (B,S,W) <= 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(-jnp.expm1(2.0 * log_a), 1e-12)) * (
        i * xb.astype(jnp.float32))

    if mode == "decode":
        def body(h, inp):
            at, bt = inp
            h = at * h + bt
            return h, h
        h_last, hs = jax.lax.scan(
            body,
            h0.astype(jnp.float32) if h0 is not None
            else jnp.zeros(gated.shape[::2], jnp.float32),
            (a.swapaxes(0, 1), gated.swapaxes(0, 1)))
        return hs.swapaxes(0, 1).astype(xb.dtype), h_last

    if h0 is not None:  # fold the carried state into the first step
        gated = gated.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, hs = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return hs.astype(xb.dtype), hs[:, -1]


def _rec_apply(cfg: ModelConfig, p: dict, x: Array, *,
               cache: Optional[dict], mode: str):
    xb = jax.nn.gelu(x @ p["in_gate"], approximate=True)      # gate branch
    xr = x @ p["in_x"]
    conv_state = cache["conv"] if cache is not None else None
    xr, new_conv, conv_full = _conv(xr, p["conv_w"], p["conv_b"], conv_state)
    xr = shard_hint(xr, ("pod", "data"), None, "model")
    h, h_last = _rg_lru(xr, p, cache["h"] if cache is not None else None, mode)
    out = (h * xb) @ p["out"]
    new_cache, snaps = None, None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "h": h_last.astype(cache["h"].dtype)}
        if mode == "decode":
            # per-token snapshots for speculative rollback
            cw = p["conv_w"].shape[0]
            T = xr.shape[1]
            conv_snaps = jnp.stack(
                [conv_full[:, t + 1:t + cw] for t in range(T)], axis=1)
            snaps = {"conv": conv_snaps.astype(new_cache["conv"].dtype),
                     "h": h.astype(jnp.float32)}   # h (B,T,W) per-step states
    return out, new_cache, snaps


def _conv(xr: Array, w: Array, b: Array, conv_state: Optional[Array]):
    cw = w.shape[0]
    hist = conv_state if conv_state is not None else jnp.zeros(
        (xr.shape[0], cw - 1, xr.shape[-1]), xr.dtype)
    full = jnp.concatenate([hist.astype(xr.dtype), xr], axis=1)
    out = sum(full[:, i:i + xr.shape[1]] * w[i] for i in range(cw)) + b
    return out, full[:, -(cw - 1):], full


# ---------------------------------------------------------------------------
# block: (pre-norm mixer residual) + (pre-norm MLP residual)
# ---------------------------------------------------------------------------

def _slot_init(cfg: ModelConfig, key: Array, slot_kind: str, dtype) -> dict:
    ka, km = jax.random.split(key)
    p = {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
         "ln2": jnp.ones((cfg.d_model,), jnp.float32),
         "mlp": L.mlp_init(km, cfg.d_model, cfg.d_ff, cfg.mlp_variant, dtype)}
    if slot_kind == "recurrent":
        p["rec"] = _rec_init(cfg, ka, dtype)
    else:
        p["attn"] = T.attn_init(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.head_dim, cfg.qkv_bias, dtype)
    return p


def _slot_apply(cfg: ModelConfig, p: dict, x: Array, *, slot_kind: str,
                positions: Array, cache: Optional[dict], mode: str):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    snaps = None
    if slot_kind == "recurrent":
        mix, new_cache, snaps = _rec_apply(cfg, p["rec"], h, cache=cache,
                                           mode=mode)
    else:
        mix, new_cache = T.attn_apply(p["attn"], h, cfg=cfg, kind="local",
                                      positions=positions, cache=cache,
                                      mode=mode)
    x = x + mix
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + L.mlp_apply(p["mlp"], h, cfg.mlp_variant)
    return x, new_cache, snaps


def _pattern(cfg: ModelConfig):
    return cfg.hybrid.block_pattern


def init_params(cfg: ModelConfig, key: Array) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    pat = _pattern(cfg)
    period = len(pat)
    n_sb, tail = divmod(cfg.n_layers, period)
    k0, k1, k2 = jax.random.split(key, 3)

    def block_init(bkey):
        sk = jax.random.split(bkey, period)
        return {f"slot{i}": _slot_init(cfg, sk[i], pat[i], dtype)
                for i in range(period)}

    blocks = jax.vmap(block_init)(jax.random.split(k0, n_sb))
    params = {
        "embed": L.embed_init(k1, cfg.vocab_size, cfg.d_model, dtype),
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if tail:
        tk = jax.random.split(k2, tail)
        params["tail"] = {f"slot{i}": _slot_init(cfg, tk[i], pat[i], dtype)
                          for i in range(tail)}
    return params


def make_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    pat = _pattern(cfg)
    period = len(pat)
    n_sb, tail = divmod(cfg.n_layers, period)
    W = _lru_width(cfg)
    cw = cfg.hybrid.conv_width

    def slot_cache(kind, stack: Optional[int]):
        if kind == "recurrent":
            c = {"conv": jnp.zeros((batch, cw - 1, W), dtype),
                 "h": jnp.zeros((batch, W), jnp.float32)}
        else:
            ring = cfg.window_size < max_len
            ln = min(cfg.window_size, max_len)
            c = L.make_kv_cache(batch, ln, cfg.n_kv_heads, cfg.head_dim,
                                dtype=dtype, ring=ring)
        if stack is not None:
            c = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (stack,) + a.shape).copy(), c)
        return c

    cache = {"blocks": {f"slot{i}": slot_cache(pat[i], n_sb)
                        for i in range(period)}}
    if tail:
        cache["tail"] = {f"slot{i}": slot_cache(pat[i], None)
                         for i in range(tail)}
    return cache


def forward(cfg: ModelConfig, params: dict, tokens: Array, *,
            positions: Optional[Array] = None,
            cache: Optional[dict] = None,
            mode: str = "train",
            vision_embeds: Optional[Array] = None,
            collect_taps: bool = True,
            head_last_only: bool = False,
            head_positions: Optional[Array] = None) -> ModelOutput:
    B, S = tokens.shape
    pat = _pattern(cfg)
    period = len(pat)
    n_sb = cfg.n_layers // period
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    taps_idx = tap_layers(cfg.n_layers)
    taps0 = jnp.zeros((len(taps_idx), B, S, cfg.d_model), x.dtype)

    def run_block(x, taps, bparams, bcache, base):
        new_cache = {} if bcache is not None else None
        snaps = {} if bcache is not None else None
        for i in range(period):
            sl = f"slot{i}"
            x, sc, sn = _slot_apply(cfg, bparams[sl], x, slot_kind=pat[i],
                                    positions=positions,
                                    cache=None if bcache is None else bcache[sl],
                                    mode=mode)
            if new_cache is not None:
                new_cache[sl] = sc
                snaps[sl] = sn
            if collect_taps:
                li = base + i
                sel = jnp.stack([jnp.asarray(li == t) for t in taps_idx])
                taps = jnp.where(sel[:, None, None, None], x[None], taps)
        return x, taps, new_cache, snaps

    def scan_body(carry, xs):
        x, taps, base = carry
        bp, bc = xs
        x, taps, nc, sn = run_block(x, taps, bp, bc, base)
        return (x, taps, base + period), (nc, sn)

    snapshots = None
    if cache is None:
        (x, taps, base), _ = jax.lax.scan(
            lambda c, bp: (scan_body(c, (bp, None))[0], None),
            (x, taps0, jnp.zeros((), jnp.int32)), params["blocks"])
        new_cache = None
    else:
        (x, taps, base), (nb, snapshots) = jax.lax.scan(
            scan_body, (x, taps0, jnp.zeros((), jnp.int32)),
            (params["blocks"], cache["blocks"]))
        new_cache = {"blocks": nb}
        snapshots = {"blocks": snapshots}

    if "tail" in params:
        tcache = cache.get("tail") if cache is not None else None
        ntail, stail = {}, {}
        for i in range(len(params["tail"])):
            sl = f"slot{i}"
            li = n_sb * period + i
            x, sc, sn = _slot_apply(cfg, params["tail"][sl], x,
                                    slot_kind=pat[i], positions=positions,
                                    cache=None if tcache is None else tcache[sl],
                                    mode=mode)
            ntail[sl] = sc
            stail[sl] = sn
            if collect_taps:
                sel = jnp.stack([jnp.asarray(li == t) for t in taps_idx])
                taps = jnp.where(sel[:, None, None, None], x[None], taps)
        if new_cache is not None:
            new_cache["tail"] = ntail
            if snapshots is not None:
                snapshots["tail"] = stail

    if head_positions is not None:
        x = jnp.take_along_axis(x, head_positions[:, None, None], axis=1)
    elif head_last_only:
        # prefill only consumes the last position's logits; computing the
        # full (B, S, vocab) tensor wastes memory+collectives (§Perf iter 2)
        x = x[:, -1:]
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["embed"].T.astype(x.dtype)).astype(jnp.float32)
    taps_out = jnp.moveaxis(taps, 0, -2).reshape(B, S, -1) if collect_taps else None
    return ModelOutput(logits=logits, taps=taps_out, cache=new_cache,
                       aux={"lb_loss": jnp.zeros(()), "z_loss": jnp.zeros(()),
                            "snapshots": snapshots if mode == "decode" else None})
