"""Mixture-of-Experts FFN (DBRX: 16e top-4 every layer; Llama-4: 128e top-1
interleaved + shared expert).

Sort-based capacity dispatch (GShard-style drops, Switch-style capacity
factor) expressed so XLA SPMD shards experts over the ``model`` mesh axis —
the (E, C, D) grouped activations carry an expert-parallel sharding hint, so
the gather/scatter between token-sharded and expert-sharded layouts lowers to
all-to-all style collectives on the mesh.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding.utils import shard_hint

Array = jax.Array


def moe_init(key: Array, d: int, f: int, n_experts: int, n_shared: int,
             variant: str, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 6)
    p = {
        "router": L.dense_init(ks[0], (d, n_experts), scale=0.02, dtype=jnp.float32),
        "w_gate": L.dense_init(ks[1], (n_experts, d, f), dtype=dtype),
        "w_up": L.dense_init(ks[2], (n_experts, d, f), dtype=dtype),
        "w_down": L.dense_init(ks[3], (n_experts, f, d), dtype=dtype),
    }
    if n_shared:
        p["shared"] = L.mlp_init(ks[4], d, f * n_shared, variant, dtype)
    return p


def _expert_ffn(xg: Array, p: dict, variant: str) -> Array:
    """xg (E, C, D) -> (E, C, D), expert-parallel einsums."""
    if variant in ("swiglu", "geglu"):
        act = jax.nn.silu if variant == "swiglu" else (
            lambda a: jax.nn.gelu(a, approximate=True))
        g = jnp.einsum("ecd,edf->ecf", xg, p["w_gate"])
        u = jnp.einsum("ecd,edf->ecf", xg, p["w_up"])
        h = act(g) * u
    elif variant == "relu2":
        h = jnp.square(jax.nn.relu(jnp.einsum("ecd,edf->ecf", xg, p["w_up"])))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xg, p["w_up"]),
                        approximate=True)
    h = shard_hint(h, "data", None, "model")   # (E, C, F): 2D expert shard
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def moe_apply(p: dict, x: Array, *, n_experts: int, top_k: int,
              capacity_factor: float, variant: str,
              n_shared: int = 0) -> Tuple[Array, dict]:
    """x (B, S, D) -> (out (B, S, D), aux dict with load-balance metrics)."""
    B, S, D = x.shape
    T = B * S
    E, K = n_experts, top_k
    xf = x.reshape(T, D)

    logits = (xf.astype(jnp.float32) @ p["router"])            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)            # (T, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- capacity-based sort dispatch ------------------------------------
    C = int((T * K) / E * capacity_factor) + 1
    flat_e = expert_idx.reshape(-1)                            # (T*K,)
    flat_t = jnp.arange(T * K) // K                            # token of slot
    flat_g = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.zeros(E, jnp.int32).at[flat_e].add(1)
    offsets = jnp.cumsum(counts) - counts                      # exclusive
    pos_in_e = jnp.arange(T * K) - offsets[se]
    valid = pos_in_e < C
    dest = jnp.where(valid, se * C + pos_in_e, E * C)          # E*C = trash

    table = jnp.full(E * C + 1, T, jnp.int32).at[dest].set(st)[:E * C]
    gtab = jnp.zeros(E * C + 1, jnp.float32).at[dest].set(sg)[:E * C]

    x_pad = jnp.concatenate([xf, jnp.zeros((1, D), xf.dtype)], axis=0)
    xg = x_pad[table].reshape(E, C, D)
    xg = shard_hint(xg, "data", None, None)    # experts over data (2D shard)
    yg = _expert_ffn(xg, p, variant)
    yg = shard_hint(yg, "data", None, None)

    y = jnp.zeros((T + 1, D), jnp.float32).at[table].add(
        gtab[:, None] * yg.reshape(E * C, D).astype(jnp.float32))[:T]
    out = y.astype(x.dtype)

    if n_shared:
        out = out + L.mlp_apply(p["shared"], xf, variant)
    out = out.reshape(B, S, D)

    # --- aux losses / metrics (Switch/GShard load balance + z-loss) ------
    frac_tokens = counts.astype(jnp.float32) / jnp.maximum(T * K, 1)
    mean_prob = probs.mean(axis=0)
    aux = {
        "lb_loss": E * jnp.sum(frac_tokens * mean_prob),
        "z_loss": jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))),
        "drop_frac": 1.0 - valid.mean(),
    }
    return out, aux
