"""Decoder-only transformer covering the dense / moe / vlm families.

Layers are stacked into *super-blocks* and iterated with ``lax.scan`` so the
lowered HLO is depth-independent (required to compile 40-48 layer targets for
512 host devices). A super-block spans ``period`` physical layers, where
``period = lcm(len(attn_pattern), moe interleave)`` — e.g. gemma2's
(local, global) alternation scans 23 blocks of 2, llama4's
(local,local,local,global+NoPE) × interleaved-MoE scans 12 blocks of 4.

EAGLE hidden-state taps (layers 2, L/2, L-1 per the paper) are collected in
the scan carry with predicated selects, so no (L, B, S, D) stack is ever
materialized.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.moe import moe_apply, moe_init
from repro.sharding.utils import shard_hint

Array = jax.Array


@dataclass
class ModelOutput:
    logits: Array
    taps: Optional[Array]          # (B, S, num_taps * D)
    cache: Any
    aux: dict


def tap_layers(n_layers: int, num_taps: int = 3):
    """EAGLE-3 tap layer indices (output-of-layer), paper Fig. 2: 2, L/2, L-1."""
    if num_taps == 1 or n_layers < 3:
        return (n_layers - 1,) * num_taps
    return (min(2, n_layers - 1), n_layers // 2, n_layers - 1)


def block_period(cfg: ModelConfig) -> int:
    p = len(cfg.attn_pattern)
    if cfg.moe.n_experts and cfg.moe.pattern == "interleaved":
        p = math.lcm(p, 2)
    return p


# ---------------------------------------------------------------------------
# attention layer
# ---------------------------------------------------------------------------

def attn_init(key: Array, d: int, n_heads: int, n_kv: int, hd: int,
              qkv_bias: bool, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(ks[0], (d, n_heads * hd), dtype=dtype),
        "wk": L.dense_init(ks[1], (d, n_kv * hd), dtype=dtype),
        "wv": L.dense_init(ks[2], (d, n_kv * hd), dtype=dtype),
        "wo": L.dense_init(ks[3], (n_heads * hd, d), dtype=dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((n_kv * hd,), dtype)
        p["bv"] = jnp.zeros((n_kv * hd,), dtype)
    return p


def attn_apply(p: dict, x: Array, *, cfg: ModelConfig, kind: str,
               positions: Array, cache: Optional[dict],
               mode: str) -> tuple:
    """kind: global | local | full. mode: train | prefill | decode.

    Returns (out, new_cache)."""
    B, T, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, T, H, hd)
    k = k.reshape(B, T, KV, hd)
    v = v.reshape(B, T, KV, hd)
    q = shard_hint(q, ("pod", "data"), None, "model")
    k = shard_hint(k, ("pod", "data"), None, "model")

    use_rope = cfg.positional == "rope" and not (
        kind == "global" and cfg.nope_on_global)
    if use_rope:
        sin, cos = L.rope_sincos(positions, hd, cfg.rope_theta)
        q = L.apply_rope(q, sin, cos)
        k = L.apply_rope(k, sin, cos)

    window = cfg.window_size if kind == "local" else 0
    scale = cfg.q_scale()

    if mode == "decode":
        assert cache is not None
        pos0 = positions[:, 0]
        # two-phase: attend [old cache] + [current block], merge by LSE,
        # THEN insert. Avoids copying the cache and — critically for ring
        # (sliding-window) caches — avoids evicting in-window entries the
        # current queries still need to read.
        old_kpos = jnp.where(cache["positions"] >= pos0[:, None], -1,
                             cache["positions"])   # mask stale history
        mask1 = L.cache_mask_fn(positions, old_kpos, window=window)
        o1, m1, l1 = L.blocked_attention(
            q, cache["k"].astype(q.dtype), cache["v"].astype(q.dtype),
            scale=scale, mask_fn=mask1, logit_cap=cfg.logit_softcap,
            return_stats=True)
        mask2 = L.cache_mask_fn(positions, positions, window=window)
        o2, m2, l2 = L.blocked_attention(
            q, k, v, scale=scale, mask_fn=mask2,
            logit_cap=cfg.logit_softcap, return_stats=True)
        out = L.merge_attention(o1, m1, l1, o2, m2, l2)
        cache = L.cache_update(cache, k, v, pos0)
    else:
        if cache is not None:  # prefill: also populate the cache
            ins = min(T, cache["k"].shape[1])
            cache = L.cache_update(cache, k[:, -ins:], v[:, -ins:],
                                   positions[:, T - ins])
        if kind == "full":
            mask = None
        elif window:
            mask = L.local_mask_fn(positions, window)
        else:
            mask = L.causal_mask_fn(positions)
        out = L.blocked_attention(q, k, v, scale=scale, mask_fn=mask,
                                  logit_cap=cfg.logit_softcap)
    out = out.reshape(B, T, H * hd) @ p["wo"]
    return out, cache


# ---------------------------------------------------------------------------
# block = [norm, attn, (post-norm), norm, mlp/moe, (post-norm)]
# ---------------------------------------------------------------------------

def _slot_init(cfg: ModelConfig, key: Array, layer_idx: int, dtype) -> dict:
    ka, km = jax.random.split(key)
    p = {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": attn_init(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                          cfg.head_dim, cfg.qkv_bias, dtype),
    }
    if cfg.post_norms:
        p["pn1"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["pn2"] = jnp.ones((cfg.d_model,), jnp.float32)
    if cfg.is_moe_layer(layer_idx):
        p["moe"] = moe_init(km, cfg.d_model, cfg.d_ff, cfg.moe.n_experts,
                            cfg.moe.n_shared_experts, cfg.mlp_variant, dtype)
    else:
        p["mlp"] = L.mlp_init(km, cfg.d_model, cfg.d_ff, cfg.mlp_variant, dtype)
    return p


def _slot_apply(cfg: ModelConfig, p: dict, x: Array, *, layer_idx: int,
                positions: Array, cache: Optional[dict], mode: str):
    kind = cfg.attn_kind(layer_idx)
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    a, cache = attn_apply(p["attn"], h, cfg=cfg, kind=kind,
                          positions=positions, cache=cache, mode=mode)
    if cfg.post_norms:
        a = L.rms_norm(a, p["pn1"], cfg.norm_eps)
    x = x + a
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    aux = None
    if "moe" in p:
        f, aux = moe_apply(p["moe"], h, n_experts=cfg.moe.n_experts,
                           top_k=cfg.moe.top_k,
                           capacity_factor=cfg.moe.capacity_factor,
                           variant=cfg.mlp_variant,
                           n_shared=cfg.moe.n_shared_experts)
    else:
        f = L.mlp_apply(p["mlp"], h, cfg.mlp_variant)
    if cfg.post_norms:
        f = L.rms_norm(f, p["pn2"], cfg.norm_eps)
    x = x + f
    x = shard_hint(x, ("pod", "data"), None, None)
    return x, cache, aux


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key: Array) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    period = block_period(cfg)
    n_sb, tail = divmod(cfg.n_layers, period)
    keys = jax.random.split(key, 4)

    def block_init(bkey, base_idx):
        sk = jax.random.split(bkey, period)
        return {f"slot{i}": _slot_init(cfg, sk[i], base_idx + i, dtype)
                for i in range(period)}

    bkeys = jax.random.split(keys[0], n_sb)
    blocks = jax.vmap(lambda k: block_init(k, 0))(bkeys)
    # NOTE: is_moe_layer / attn_kind depend on layer_idx % period only, so
    # base_idx=0 gives every block the right per-slot structure.

    params = {
        "embed": L.embed_init(keys[1], cfg.vocab_size, cfg.d_model, dtype),
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if tail:
        tkeys = jax.random.split(keys[2], tail)
        params["tail"] = {f"slot{i}": _slot_init(cfg, tkeys[i],
                                                 n_sb * period + i, dtype)
                          for i in range(tail)}
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(keys[3], (cfg.d_model, cfg.vocab_size),
                                         dtype=dtype)
    if cfg.family == "vlm":
        kv1, kv2 = jax.random.split(keys[3] if cfg.tie_embeddings else keys[2])
        params["vis_proj"] = {
            "w1": L.dense_init(kv1, (cfg.vision_dim, cfg.d_model), dtype=dtype),
            "w2": L.dense_init(kv2, (cfg.d_model, cfg.d_model), dtype=dtype),
        }
    return params


def make_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    """Per-slot stacked KV caches; local-attention slots get ring buffers of
    window length (this is what makes long_500k decode memory bounded)."""
    period = block_period(cfg)
    n_sb, tail = divmod(cfg.n_layers, period)

    def slot_cache(kind, stack: Optional[int]):
        ring = kind == "local" and cfg.window_size < max_len
        ln = min(cfg.window_size, max_len) if ring else max_len
        c = L.make_kv_cache(batch, ln, cfg.n_kv_heads, cfg.head_dim,
                            dtype=dtype, ring=ring)
        if stack is not None:
            c = jax.tree.map(lambda a: jnp.broadcast_to(
                a, (stack,) + a.shape).copy(), c)
        return c

    cache = {"blocks": {f"slot{i}": slot_cache(cfg.attn_kind(i), n_sb)
                        for i in range(period)}}
    if tail:
        cache["tail"] = {f"slot{i}": slot_cache(
            cfg.attn_kind(n_sb * period + i), None) for i in range(tail)}
    return cache


def forward(cfg: ModelConfig, params: dict, tokens: Array, *,
            positions: Optional[Array] = None,
            cache: Optional[dict] = None,
            mode: str = "train",
            vision_embeds: Optional[Array] = None,
            collect_taps: bool = True,
            head_last_only: bool = False,
            head_positions: Optional[Array] = None) -> ModelOutput:
    """tokens (B, S). For vlm train/prefill, vision_embeds (B, Tv, vision_dim)
    are projected and prepended (early fusion); logits cover the full fused
    sequence. ``head_positions`` (B,) restricts the LM head to one gathered
    sequence index per row (bucketed prefill: the true last prompt position
    inside a padded bucket), like ``head_last_only`` does for index -1."""
    B = tokens.shape[0]
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if cfg.family == "vlm" and vision_embeds is not None:
        vp = params["vis_proj"]
        vis = jax.nn.gelu(vision_embeds.astype(x.dtype) @ vp["w1"]) @ vp["w2"]
        x = jnp.concatenate([vis, x], axis=1)
    S = x.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if cfg.positional == "sinusoidal":
        x = x + L.sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)
    x = shard_hint(x, ("pod", "data"), None, None)

    period = block_period(cfg)
    n_sb = cfg.n_layers // period
    taps_idx = tap_layers(cfg.n_layers)
    taps0 = jnp.zeros((len(taps_idx), B, S, cfg.d_model), x.dtype)

    def run_block(x, taps, bparams, bcache, base_idx):
        new_cache = {} if bcache is not None else None
        aux_lb = jnp.zeros((), jnp.float32)
        aux_z = jnp.zeros((), jnp.float32)
        for i in range(period):
            sl = f"slot{i}"
            x, sc, aux = _slot_apply(
                cfg, bparams[sl], x, layer_idx=i, positions=positions,
                cache=None if bcache is None else bcache[sl], mode=mode)
            if new_cache is not None:
                new_cache[sl] = sc
            if aux is not None:
                aux_lb += aux["lb_loss"]
                aux_z += aux["z_loss"]
            if collect_taps:
                li = base_idx + i
                sel = jnp.stack([jnp.asarray(li == t) for t in taps_idx])
                taps = jnp.where(sel[:, None, None, None], x[None], taps)
        return x, taps, new_cache, aux_lb, aux_z

    def scan_body(carry, xs):
        x, taps, lb, z, base = carry
        bparams, bcache = xs
        x, taps, ncache, alb, az = run_block(x, taps, bparams, bcache, base)
        return (x, taps, lb + alb, z + az, base + period), ncache

    bcaches = cache["blocks"] if cache is not None else None
    if bcaches is None:
        dummy = jnp.zeros((n_sb,), jnp.int32)
        (x, taps, lb, z, base), _ = jax.lax.scan(
            lambda c, xs_: (scan_body(c, (xs_[0], None))[0], None),
            (x, taps0, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
             jnp.zeros((), jnp.int32)),
            (params["blocks"], dummy))
        new_cache = None
    else:
        (x, taps, lb, z, base), new_bcache = jax.lax.scan(
            scan_body,
            (x, taps0, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
             jnp.zeros((), jnp.int32)),
            (params["blocks"], bcaches))
        new_cache = {"blocks": new_bcache}

    # tail layers (when n_layers % period != 0)
    if "tail" in params:
        tcache = cache.get("tail") if cache is not None else None
        ntail = {}
        for i in range(len(params["tail"])):
            sl = f"slot{i}"
            li = n_sb * period + i
            x, sc, aux = _slot_apply(
                cfg, params["tail"][sl], x, layer_idx=li, positions=positions,
                cache=None if tcache is None else tcache[sl], mode=mode)
            ntail[sl] = sc
            if aux is not None:
                lb, z = lb + aux["lb_loss"], z + aux["z_loss"]
            if collect_taps:
                sel = jnp.stack([jnp.asarray(li == t) for t in taps_idx])
                taps = jnp.where(sel[:, None, None, None], x[None], taps)
        if new_cache is not None:
            new_cache["tail"] = ntail

    if head_positions is not None:
        x = jnp.take_along_axis(x, head_positions[:, None, None], axis=1)
    elif head_last_only:
        # prefill only consumes the last position's logits; computing the
        # full (B, S, vocab) tensor wastes memory+collectives (§Perf iter 2)
        x = x[:, -1:]
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head", None)
    logits = x @ head if head is not None else x @ params["embed"].T.astype(x.dtype)
    logits = L.softcap(logits.astype(jnp.float32), cfg.final_softcap)
    logits = shard_hint(logits, ("pod", "data"), None, "model")

    taps_out = None
    if collect_taps:
        taps_out = jnp.moveaxis(taps, 0, -2).reshape(B, S, -1)
    return ModelOutput(logits=logits, taps=taps_out, cache=new_cache,
                       aux={"lb_loss": lb, "z_loss": z})
