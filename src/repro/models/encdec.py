"""Whisper-style encoder-decoder backbone. [arXiv:2212.04356]

The mel-spectrogram + conv feature extractor is a STUB per the assignment:
callers provide precomputed frame embeddings (B, encoder_seq, d_model) — see
``registry.input_specs``. We implement the transformer: a bidirectional
encoder over frames and a causal decoder with cross-attention. Positions are
absolute sinusoidal (Whisper), added at the embedding level.

EAGLE taps come from *decoder* layers; encoder information reaches the
drafter through them (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.transformer import ModelOutput, tap_layers
from repro.sharding.utils import shard_hint

Array = jax.Array


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def _enc_layer_init(cfg: ModelConfig, key: Array, dtype) -> dict:
    ka, km = jax.random.split(key)
    return {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": T.attn_init(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.head_dim, False, dtype),
            "mlp": L.mlp_init(km, cfg.d_model, cfg.d_ff, cfg.mlp_variant, dtype)}


def encode(cfg: ModelConfig, params: dict, frames: Array) -> Array:
    """frames (B, Senc, D) stub embeddings -> encoder output (B, Senc, D)."""
    B, S, D = frames.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = frames + L.sinusoidal_positions(pos, D).astype(frames.dtype)

    def body(x, p):
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        a, _ = T.attn_apply(p["attn"], h, cfg=cfg, kind="full",
                            positions=pos, cache=None, mode="train")
        x = x + a
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + L.mlp_apply(p["mlp"], h, cfg.mlp_variant)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------

def _dec_layer_init(cfg: ModelConfig, key: Array, dtype) -> dict:
    ka, kx, km = jax.random.split(key, 3)
    return {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "lnx": jnp.ones((cfg.d_model,), jnp.float32),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        "self_attn": T.attn_init(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.head_dim, False, dtype),
        "cross_attn": T.attn_init(kx, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                  cfg.head_dim, False, dtype),
        "mlp": L.mlp_init(km, cfg.d_model, cfg.d_ff, cfg.mlp_variant, dtype),
    }


def _cross_apply(cfg: ModelConfig, p: dict, x: Array, enc_kv: dict) -> Array:
    """Cross-attention against precomputed encoder K/V (no mask)."""
    B, Tq, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, Tq, H, hd)
    out = L.full_attention(q, enc_kv["k"].astype(q.dtype),
                           enc_kv["v"].astype(q.dtype), scale=cfg.q_scale())
    return out.reshape(B, Tq, H * hd) @ p["wo"]


def _enc_kv(cfg: ModelConfig, p: dict, enc_out: Array) -> dict:
    B, S, D = enc_out.shape
    return {"k": (enc_out @ p["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim),
            "v": (enc_out @ p["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)}


def _dec_slot_apply(cfg, p, x, *, positions, cache, mode, enc_kv):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    new_self = None
    a, new_self = T.attn_apply(p["self_attn"], h, cfg=cfg, kind="global",
                               positions=positions,
                               cache=None if cache is None else cache["self"],
                               mode=mode)
    x = x + a
    h = L.rms_norm(x, p["lnx"], cfg.norm_eps)
    x = x + _cross_apply(cfg, p["cross_attn"], h, enc_kv)
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + L.mlp_apply(p["mlp"], h, cfg.mlp_variant)
    new_cache = None if cache is None else {"self": new_self,
                                            "cross": cache["cross"]}
    return x, new_cache


# ---------------------------------------------------------------------------
# model API
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key: Array) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    k0, k1, k2 = jax.random.split(key, 3)
    enc = jax.vmap(lambda k: _enc_layer_init(cfg, k, dtype))(
        jax.random.split(k0, cfg.n_encoder_layers))
    dec = jax.vmap(lambda k: _dec_layer_init(cfg, k, dtype))(
        jax.random.split(k1, cfg.n_layers))
    return {
        "embed": L.embed_init(k2, cfg.vocab_size, cfg.d_model, dtype),
        "enc_blocks": enc,
        "enc_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "dec_blocks": dec,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }


def make_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    """Self-attn cache per decoder layer + cross K/V (filled at prefill)."""
    self_c = L.make_kv_cache(batch, max_len, cfg.n_kv_heads, cfg.head_dim,
                             dtype=dtype, ring=False)
    cross = {"k": jnp.zeros((batch, cfg.encoder_seq, cfg.n_kv_heads,
                             cfg.head_dim), dtype),
             "v": jnp.zeros((batch, cfg.encoder_seq, cfg.n_kv_heads,
                             cfg.head_dim), dtype)}
    per = {"self": self_c, "cross": cross}
    return {"blocks": jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape).copy(), per)}


def forward(cfg: ModelConfig, params: dict, tokens: Array, *,
            positions: Optional[Array] = None,
            cache: Optional[dict] = None,
            mode: str = "train",
            encoder_embeds: Optional[Array] = None,
            vision_embeds: Optional[Array] = None,
            collect_taps: bool = True,
            head_last_only: bool = False,
            head_positions: Optional[Array] = None) -> ModelOutput:
    """Train/prefill require encoder_embeds (stub frontend output); prefill
    fills both the self cache and the per-layer cross K/V. Decode reads the
    cross K/V from the cache."""
    B, S = tokens.shape
    x = params["embed"][tokens]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = x + L.sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)

    enc_out = None
    if encoder_embeds is not None:
        enc_out = encode(cfg, params, encoder_embeds)
        enc_out = shard_hint(enc_out, ("pod", "data"), None, None)

    taps_idx = tap_layers(cfg.n_layers)
    taps0 = jnp.zeros((len(taps_idx), B, S, cfg.d_model), x.dtype)

    def scan_body(carry, xs):
        x, taps, li = carry
        bp, bc = xs
        if enc_out is not None:
            ekv = _enc_kv(cfg, bp["cross_attn"], enc_out)
            if bc is not None:
                bc = {"self": bc["self"], "cross": jax.tree.map(
                    lambda dst, src: src.astype(dst.dtype), bc["cross"], ekv)}
        else:
            ekv = jax.tree.map(lambda a: a, bc["cross"])
        x, nc = _dec_slot_apply(cfg, bp, x, positions=positions, cache=bc,
                                mode=mode, enc_kv=ekv)
        if collect_taps:
            sel = jnp.stack([jnp.asarray(li == t) for t in taps_idx])
            taps = jnp.where(sel[:, None, None, None], x[None], taps)
        return (x, taps, li + 1), nc

    if cache is None:
        (x, taps, _), _ = jax.lax.scan(
            lambda c, bp: (scan_body(c, (bp, None))[0], None),
            (x, taps0, jnp.zeros((), jnp.int32)), params["dec_blocks"])
        new_cache = None
    else:
        (x, taps, _), nb = jax.lax.scan(
            scan_body, (x, taps0, jnp.zeros((), jnp.int32)),
            (params["dec_blocks"], cache["blocks"]))
        new_cache = {"blocks": nb}

    if head_positions is not None:
        x = jnp.take_along_axis(x, head_positions[:, None, None], axis=1)
    elif head_last_only:
        # prefill only consumes the last position's logits; computing the
        # full (B, S, vocab) tensor wastes memory+collectives (§Perf iter 2)
        x = x[:, -1:]
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["embed"].T.astype(x.dtype)).astype(jnp.float32)
    taps_out = jnp.moveaxis(taps, 0, -2).reshape(B, S, -1) if collect_taps else None
    return ModelOutput(logits=logits, taps=taps_out, cache=new_cache,
                       aux={"lb_loss": jnp.zeros(()), "z_loss": jnp.zeros(())})
