from repro.models.registry import (Model, extra_input_shapes, get_model,
                                   make_extras)
from repro.models.transformer import ModelOutput, tap_layers

__all__ = ["Model", "ModelOutput", "extra_input_shapes", "get_model",
           "make_extras", "tap_layers"]
