"""Family dispatch: one `Model` facade over the zoo modules.

`Model.forward` has a single signature across all six families; modality
frontends (audio frames, vision patches) enter via keyword extras whose
shapes come from `extra_input_shapes` (stub frontends per the assignment).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, hybrid, ssm, transformer

Array = jax.Array

_FAMILY_MODULE = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": ssm,
    "hybrid": hybrid,
    "encdec": encdec,
}


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    @property
    def module(self):
        return _FAMILY_MODULE[self.cfg.family]

    def init(self, key: Array) -> dict:
        return self.module.init_params(self.cfg, key)

    def make_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
        return self.module.make_cache(self.cfg, batch, max_len, dtype=dtype)

    def forward(self, params: dict, tokens: Array, *, positions=None,
                cache=None, mode: str = "train", collect_taps: bool = True,
                head_last_only: bool = False, head_positions=None,
                **extras) -> transformer.ModelOutput:
        kw: Dict[str, Any] = dict(positions=positions, cache=cache, mode=mode,
                                  collect_taps=collect_taps,
                                  head_last_only=head_last_only,
                                  head_positions=head_positions)
        if self.cfg.family == "encdec":
            kw["encoder_embeds"] = extras.get("encoder_embeds")
        else:
            kw["vision_embeds"] = extras.get("vision_embeds")
        return self.module.forward(self.cfg, params, tokens, **kw)

    def text_len(self, total_seq: int, mode: str) -> int:
        """How many *token* inputs produce a length-`total_seq` sequence
        (VLM prepends vision_tokens at train/prefill)."""
        if self.cfg.family == "vlm" and mode in ("train", "prefill"):
            return max(total_seq - self.cfg.vision_tokens, 1)
        return total_seq


def get_model(cfg: ModelConfig) -> Model:
    if cfg.family not in _FAMILY_MODULE:
        raise KeyError(f"unknown family {cfg.family!r}")
    return Model(cfg)


def extra_input_shapes(cfg: ModelConfig, batch: int,
                       mode: str) -> Dict[str, Tuple[tuple, Any]]:
    """Stub-frontend inputs: name -> (shape, dtype). Empty for pure-text."""
    dt = jnp.dtype(cfg.dtype)
    out: Dict[str, Tuple[tuple, Any]] = {}
    if cfg.family == "encdec":
        out["encoder_embeds"] = ((batch, cfg.encoder_seq, cfg.d_model), dt)
    elif cfg.family == "vlm" and mode in ("train", "prefill"):
        out["vision_embeds"] = ((batch, cfg.vision_tokens, cfg.vision_dim), dt)
    return out


def make_extras(cfg: ModelConfig, batch: int, mode: str, key: Array) -> dict:
    """Concrete random stub-frontend inputs (smoke tests, examples)."""
    out = {}
    for name, (shape, dt) in extra_input_shapes(cfg, batch, mode).items():
        # init-time stub-input derivation: draw order is pinned by the
        # (deterministic) shape-dict iteration, not a serving stream
        key, sub = jax.random.split(key)  # repro-lint: disable=PRNG01
        out[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32).astype(dt)
    return out
