"""Mamba-2 (SSD — state-space duality) target model. [arXiv:2405.21060]

Attention-free: each block is an SSD mixer (in_proj → depthwise conv over
(x, B, C) → chunked selective-state-space scan → gated RMSNorm → out_proj).

Train/prefill use the *chunked* SSD algorithm: quadratic attention-like
computation within chunks of ``chunk_size`` plus a sequential ``lax.scan``
over chunk states — O(S·Q) memory instead of O(S²). Decode carries an O(1)
recurrent state, which is why ``long_500k`` is native for this family.

The EAGLE tap mechanism is unchanged: taps are block outputs (the drafter is
attention-based regardless of the target family — DESIGN.md §4).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.transformer import ModelOutput, tap_layers
from repro.sharding.utils import shard_hint

Array = jax.Array


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm.expand * cfg.d_model
    H = d_inner // cfg.ssm.head_dim
    return d_inner, H, cfg.ssm.head_dim, cfg.ssm.d_state


# ---------------------------------------------------------------------------
# per-layer params
# ---------------------------------------------------------------------------

def _mixer_init(cfg: ModelConfig, key: Array, dtype) -> dict:
    d_inner, H, P, N = _dims(cfg)
    cw = cfg.ssm.conv_width
    conv_ch = d_inner + 2 * N
    ks = jax.random.split(key, 5)
    dt = jnp.exp(jax.random.uniform(ks[3], (H,), jnp.float32,
                                    math.log(1e-3), math.log(1e-1)))
    return {
        "ln": jnp.ones((cfg.d_model,), jnp.float32),
        "in_proj": L.dense_init(ks[0], (cfg.d_model, 2 * d_inner + 2 * N + H),
                                dtype=dtype),
        "conv_w": L.dense_init(ks[1], (cw, conv_ch), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jax.random.uniform(ks[2], (H,), jnp.float32, 1.0, 16.0)),
        "dt_bias": dt + jnp.log(-jnp.expm1(-dt)),   # softplus^-1(dt)
        "D": jnp.ones((H,), jnp.float32),
        "gnorm": jnp.ones((d_inner,), jnp.float32),
        "out_proj": L.dense_init(ks[4], (d_inner, cfg.d_model), dtype=dtype),
    }


def _split_proj(cfg, proj):
    d_inner, H, P, N = _dims(cfg)
    z, xBC, dt = jnp.split(proj, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC: Array, w: Array, b: Array,
                 conv_state: Optional[Array]):
    """Depthwise causal conv, width cw. conv_state (B, cw-1, C) holds the
    previous raw inputs for streaming decode. Returns (out, new_state)."""
    cw = w.shape[0]
    hist = conv_state if conv_state is not None else jnp.zeros(
        (xBC.shape[0], cw - 1, xBC.shape[-1]), xBC.dtype)
    full = jnp.concatenate([hist.astype(xBC.dtype), xBC], axis=1)
    out = sum(full[:, i:i + xBC.shape[1]] * w[i] for i in range(cw)) + b
    new_state = full[:, -(cw - 1):]
    return jax.nn.silu(out), new_state, full


def _ssd_chunked(x, dt, A, Bm, Cm, D, chunk: int, init_state=None):
    """Chunked SSD scan.

    x (B,S,H,P), dt (B,S,H) [post-softplus], A (H,) negative, Bm/Cm (B,S,N).
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    nc = S // chunk
    xq = x.reshape(Bsz, nc, chunk, H, P)
    dq = dt.reshape(Bsz, nc, chunk, H)
    Bq = Bm.reshape(Bsz, nc, chunk, N)
    Cq = Cm.reshape(Bsz, nc, chunk, N)
    a = dq * A  # (B,nc,Q,H) negative log-decay increments
    csum = jnp.cumsum(a, axis=2)

    # intra-chunk (quadratic within chunk)
    cb = jnp.einsum("bcin,bcjn->bcij", Cq, Bq,
                    preferred_element_type=jnp.float32)
    decay = jnp.exp(csum[:, :, :, None, :] - csum[:, :, None, :, :])  # (B,nc,Q,Q,H)
    idx = jnp.arange(chunk)
    causal = (idx[:, None] >= idx[None, :])[None, None, :, :, None]
    w = jnp.where(causal, cb[..., None] * decay, 0.0)
    y = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", w, dq, xq,
                   preferred_element_type=jnp.float32)

    # chunk states: contribution of each chunk to the running state
    last = csum[:, :, -1:, :]
    st = jnp.einsum("bcjh,bcjn,bcjhp->bchpn",
                    jnp.exp(last - csum) * dq, Bq, xq,
                    preferred_element_type=jnp.float32)

    def body(state, inp):
        st_c, decay_c = inp        # (B,H,P,N), (B,H)
        new = state * decay_c[:, :, None, None] + st_c
        return new, state          # emit state *before* this chunk

    s0 = (init_state.astype(jnp.float32) if init_state is not None
          else jnp.zeros((Bsz, H, P, N), jnp.float32))
    chunk_decay = jnp.exp(last[:, :, 0]).transpose(1, 0, 2)      # (nc,B,H)
    final, prev_states = jax.lax.scan(
        body, s0, (st.transpose(1, 0, 2, 3, 4), chunk_decay))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)           # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp", Cq, prev_states,
                         jnp.exp(csum), preferred_element_type=jnp.float32)
    out = (y + y_inter).reshape(Bsz, S, H, P) + D[None, None, :, None] * x
    return out.astype(x.dtype), final


def _ssd_step(x, dt, A, Bm, Cm, D, state):
    """Sequential decode over T tokens. Emits a per-token state snapshot so
    speculative decoding can roll back to the last *accepted* token
    (serving/cache_ops.commit). Shapes as above with S=T small."""
    def body(s, inp):
        xt, dtt, bt, ct = inp      # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dtt * A)   # (B,H)
        s = s * decay[:, :, None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dtt, bt, xt, preferred_element_type=jnp.float32)
        yt = jnp.einsum("bn,bhpn->bhp", ct, s,
                        preferred_element_type=jnp.float32)
        return s, (yt, s)

    xs = (x.swapaxes(0, 1), dt.swapaxes(0, 1),
          Bm.swapaxes(0, 1), Cm.swapaxes(0, 1))
    state, (ys, snaps) = jax.lax.scan(body, state.astype(jnp.float32), xs)
    y = ys.swapaxes(0, 1) + D[None, None, :, None] * x
    return y.astype(x.dtype), state, snaps.swapaxes(0, 1)   # snaps (B,T,H,P,N)


def _mixer_apply(cfg: ModelConfig, p: dict, x: Array, *,
                 cache: Optional[dict], mode: str):
    d_inner, H, P, N = _dims(cfg)
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    z, xBC, dt_raw = _split_proj(cfg, h @ p["in_proj"])
    xBC, conv_state, conv_full = _causal_conv(
        xBC, p["conv_w"], p["conv_b"],
        cache["conv"] if cache is not None else None)
    xs, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)
    Bsz, S = xs.shape[:2]
    xh = xs.reshape(Bsz, S, H, P)
    xh = shard_hint(xh, ("pod", "data"), None, "model")
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    snaps = None
    if mode == "decode":
        y, state, st_snaps = _ssd_step(xh, dt, A, Bm.astype(jnp.float32),
                                       Cm.astype(jnp.float32), p["D"],
                                       cache["state"])
        # conv-state snapshot after token t = raw-input window ending at t
        cw = p["conv_w"].shape[0]
        conv_snaps = jnp.stack(
            [conv_full[:, t + 1:t + cw] for t in range(S)], axis=1)
        snaps = {"state": st_snaps, "conv": conv_snaps}
    else:
        chunk = min(cfg.ssm.chunk_size, S)
        while S % chunk:
            chunk -= 1
        y, state = _ssd_chunked(xh, dt, A, Bm.astype(jnp.float32),
                                Cm.astype(jnp.float32), p["D"], chunk)

    y = y.reshape(Bsz, S, d_inner)
    y = L.rms_norm(y * jax.nn.silu(z), p["gnorm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    new_cache = None
    if cache is not None:
        new_cache = {"conv": conv_state.astype(cache["conv"].dtype),
                     "state": state.astype(cache["state"].dtype)}
    return x + out, new_cache, snaps


# ---------------------------------------------------------------------------
# model API (mirrors transformer.py)
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key: Array) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    k0, k1 = jax.random.split(key)
    bkeys = jax.random.split(k0, cfg.n_layers)
    blocks = jax.vmap(lambda k: _mixer_init(cfg, k, dtype))(bkeys)
    return {
        "embed": L.embed_init(k1, cfg.vocab_size, cfg.d_model, dtype),
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }


def make_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    d_inner, H, P, N = _dims(cfg)
    cw = cfg.ssm.conv_width
    per = {
        "conv": jnp.zeros((batch, cw - 1, d_inner + 2 * N), dtype),
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
    }
    return {"blocks": jax.tree.map(
        lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), per)}


def forward(cfg: ModelConfig, params: dict, tokens: Array, *,
            positions: Optional[Array] = None,
            cache: Optional[dict] = None,
            mode: str = "train",
            vision_embeds: Optional[Array] = None,
            collect_taps: bool = True,
            head_last_only: bool = False,
            head_positions: Optional[Array] = None) -> ModelOutput:
    B, S = tokens.shape
    x = params["embed"][tokens]
    taps_idx = tap_layers(cfg.n_layers)
    taps0 = jnp.zeros((len(taps_idx), B, S, cfg.d_model), x.dtype)

    def scan_body(carry, xs):
        x, taps, li = carry
        bparams, bcache = xs
        x, ncache, snaps = _mixer_apply(cfg, bparams, x, cache=bcache,
                                        mode=mode)
        if collect_taps:
            sel = jnp.stack([jnp.asarray(li == t) for t in taps_idx])
            taps = jnp.where(sel[:, None, None, None], x[None], taps)
        return (x, taps, li + 1), (ncache, snaps)

    snapshots = None
    if cache is None:
        (x, taps, _), _ = jax.lax.scan(
            lambda c, bp: (scan_body(c, (bp, None))[0], None),
            (x, taps0, jnp.zeros((), jnp.int32)), params["blocks"])
        new_cache = None
    else:
        (x, taps, _), (nblocks, snapshots) = jax.lax.scan(
            scan_body, (x, taps0, jnp.zeros((), jnp.int32)),
            (params["blocks"], cache["blocks"]))
        new_cache = {"blocks": nblocks}

    if head_positions is not None:
        x = jnp.take_along_axis(x, head_positions[:, None, None], axis=1)
    elif head_last_only:
        # prefill only consumes the last position's logits; computing the
        # full (B, S, vocab) tensor wastes memory+collectives (§Perf iter 2)
        x = x[:, -1:]
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["embed"].T.astype(x.dtype)).astype(jnp.float32)
    taps_out = jnp.moveaxis(taps, 0, -2).reshape(B, S, -1) if collect_taps else None
    return ModelOutput(logits=logits, taps=taps_out, cache=new_cache,
                       aux={"lb_loss": jnp.zeros(()), "z_loss": jnp.zeros(()),
                            "snapshots": ({"blocks": snapshots}
                                          if snapshots is not None else None)})
