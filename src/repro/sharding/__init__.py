from repro.sharding.utils import shard_hint, axis_size, batch_axes
from repro.sharding.rules import param_specs, cache_specs, DRAFTER_RULES

__all__ = ["shard_hint", "axis_size", "batch_axes", "param_specs",
           "cache_specs", "DRAFTER_RULES"]
