"""Parameter / cache PartitionSpec rules, path-regex based (MaxText-style).

Rules map flattened pytree path strings (``blocks/slot0/attn/wq``) to spec
entry tuples; entries are axis names filtered by divisibility at apply time
(sharding/utils.spec_for), so one rule set serves every architecture — e.g.
a 10-head attention simply falls back to replicated heads while its MLP still
shards over ``model``.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

from repro.sharding.utils import _current_mesh, _filter_spec, mesh_scope

# (regex over path, spec entries applied to the *trailing* dims).
# Stacked-layer leading dims (scan) are padded with None automatically.
PARAM_RULES = [
    # 2D ("FSDP-style") weight sharding: output/expert dim over model +
    # the other matrix dim over data (§Perf iteration 4 — model-only
    # sharding replicates every weight across the 16-way data axis; for a
    # 27B bf16 target that is 3.4 GB/chip of avoidable replication, and for
    # the drafter it ZeRO-shards AdamW state as well). XLA all-gathers
    # weights per scanned layer on use — classic FSDP dataflow.
    (r"(^|/)embed$", ("model", "data")),
    (r"lm_head$", ("data", "model")),
    # attention projections
    (r"attn/wq$", ("data", "model")),
    (r"attn/wk$", ("data", "model")),
    (r"attn/wv$", ("data", "model")),
    (r"attn/wo$", ("model", "data")),
    (r"attn/b[qkv]$", ("model",)),
    # MLP
    (r"mlp/w_gate$", ("data", "model")),
    (r"mlp/w_up$", ("data", "model")),
    (r"mlp/w_down$", ("model", "data")),
    (r"shared/w_gate$", ("data", "model")),
    (r"shared/w_up$", ("data", "model")),
    (r"shared/w_down$", ("model", "data")),
    # MoE experts: 2D sharding — experts over data, FFN dim over model
    # (§Perf pair B: expert-parallel over model alone replicates the expert
    # stack across the data axis: 50 GB/chip for llama4-maverick. 2D
    # sharding brings per-chip expert weights down 16x; the token dispatch
    # becomes an all-to-all on the data axis.)
    (r"moe/w_gate$", ("data", None, "model")),
    (r"moe/w_up$", ("data", None, "model")),
    (r"moe/w_down$", ("data", "model", None)),
    (r"moe/router$", (None, None)),
    # Mamba-2 mixer: inner channels over model
    (r"in_proj$", (None, "model")),
    (r"out_proj$", ("model", None)),
    (r"conv_w$", (None, "model")),
    (r"conv_b$", ("model",)),
    # RG-LRU
    (r"rec/in_x$", (None, "model")),
    (r"rec/in_gate$", (None, "model")),
    (r"rec/w_rec_gate$", (None, "model")),
    (r"rec/w_in_gate$", (None, "model")),
    (r"rec/out$", ("model", None)),
    (r"rec/lam$", ("model",)),
    # vision projector
    (r"vis_proj/w1$", (None, "model")),
    (r"vis_proj/w2$", ("model", None)),
]

DRAFTER_RULES = PARAM_RULES  # the drafter is a llama-style transformer

# KV cache sharding is shape-aware (see cache_specs below): the batch dim
# shards over ("pod","data") when divisible; otherwise (long_500k, batch=1)
# the *sequence* dim shards over those axes (context parallelism). The KV
# head dim shards over "model", falling back to head_dim when the head count
# does not divide the axis (narrow-GQA archs).


def _path_str(path) -> str:
    parts = []
    for pe in path:
        if hasattr(pe, "key"):
            parts.append(str(pe.key))
        elif hasattr(pe, "idx"):
            parts.append(str(pe.idx))
        else:
            parts.append(str(pe))
    return "/".join(parts)


def _spec_for_leaf(path_s: str, leaf, rules, stacked_prefix: bool) -> P:
    mesh = _current_mesh()
    if mesh is None:
        return P()
    for rx, entries in rules:
        if re.search(rx, path_s):
            ent = list(entries)
            # pad leading (scan-stacked) dims with None
            pad = leaf.ndim - len(ent)
            if pad < 0:
                ent = ent[-leaf.ndim:] if leaf.ndim else []
                pad = 0
            full = [None] * pad + ent
            spec = _filter_spec(leaf.shape, full, mesh)
            # embed fallback: if vocab not divisible, shard d_model instead
            if rx == r"(^|/)embed$" and spec == P(None, None) and leaf.ndim == 2:
                spec = _filter_spec(leaf.shape, [None, "model"], mesh)
            return spec
    return P()


def param_specs(params, rules=PARAM_RULES):
    """Pytree of PartitionSpec matching ``params``."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: _spec_for_leaf(_path_str(p), l, rules, True), params)


def _cache_leaf_spec(path_s: str, leaf) -> P:
    mesh = _current_mesh()
    if mesh is None:
        return P()
    name = path_s.rsplit("/", 1)[-1]
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bsize = 1
    for a in baxes:
        bsize *= mesh.shape[a]
    if name == "ring" or leaf.ndim == 0:
        return P()

    def build(dims):
        """dims: list of (size, candidate-entry). Applies batch-vs-seq and
        divisibility logic."""
        return _filter_spec([d for d, _ in dims], [e for _, e in dims], mesh)

    # locate batch dim: caches are (stack?, B, ...) — stack dims are the
    # leading dims beyond the known per-layer rank.
    ranks = {"k": 4, "v": 4, "positions": 2, "conv": 3, "state": 4, "h": 2}
    rank = ranks.get(name)
    if rank is None or leaf.ndim < rank:
        return P()
    pad = leaf.ndim - rank
    shape = leaf.shape[pad:]
    B = shape[0]
    batch_ok = B % bsize == 0 and bsize > 1
    ent = [None] * pad
    if name in ("k", "v"):
        _, S, KV, hd = shape
        ent += [baxes if batch_ok else None,
                None if batch_ok else baxes,     # context parallelism
                "model", None]
        spec = _filter_spec(leaf.shape, ent, mesh)
        if spec[pad + 2] is None:                # KV not divisible → shard hd
            ent[pad + 2], ent[pad + 3] = None, "model"
            spec = _filter_spec(leaf.shape, ent, mesh)
        return spec
    if name == "positions":
        ent += [baxes if batch_ok else None, None if batch_ok else baxes]
    elif name == "conv":
        ent += [baxes if batch_ok else None, None, "model"]
    elif name == "state":
        ent += [baxes if batch_ok else None, "model", None, None]
    elif name == "h":
        ent += [baxes if batch_ok else None, "model"]
    return _filter_spec(leaf.shape, ent, mesh)


def cache_specs(cache):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: _cache_leaf_spec(_path_str(p), l), cache)


# ---------------------------------------------------------------------------
# Serving (lossless) profile — storage sharding for the model-sharded engine
# ---------------------------------------------------------------------------
#
# The serving engine (serving/engine.py, EngineConfig(shard_model=True))
# shards *storage*, not compute: weights and full-length KV — contiguous
# per-slot rows and the paged page pools alike — live sharded over the 1-D
# ("model",) serving mesh and are gathered at an explicit replication
# boundary inside each jitted step (sharding/utils.replicate_tree). Compute
# then runs with single-device tensor shapes, which is what makes the
# sharded engine token-for-token (bitwise) lossless: reduction order and
# backend matmul tiling are shape-dependent, so any scheme that *computes*
# on sharded operands (Megatron-style row-parallel matmuls, per-head
# attention on a KV shard) drifts by ulps and eventually flips a greedy
# argmax. See docs/sharding.md for the measured evidence and the layout
# table.
#
# What shards at rest, and on which axis:
#   k/v leaves (rank >= 4)   — the KV-head axis (dim -2) over "model";
#       narrow-GQA shapes that don't divide fall back to head_dim (dim -1).
#       One rule covers every K/V shape because all of them keep the
#       trailing (KV, hd) dims: contiguous full-length (..., B, S, KV, hd),
#       page pools (..., NP, page, KV, hd), and per-slot ring
#       (sliding-window) windows (..., B, W, KV, hd).
#   everything else          — replicated. positions/block tables are tiny
#       and index math; recurrent state (SSM "state", conv windows, RG-LRU
#       "h") is O(B·d) bounded per slot and not worth a gather boundary;
#       host bookkeeping (tokens, counters, per-slot sampling-policy rows
#       incl. the per-request PRNG base keys) must stay cheap to read
#       back every scheduler sync.
#   BlockAllocator free lists — host-side Python, never on device at all.

def serve_param_specs(params, mesh, rules=PARAM_RULES):
    """Storage-sharding PartitionSpecs for serving weights under ``mesh``.

    Reuses the training PARAM_RULES: under the 1-D ``("model",)`` serving
    mesh the "data" entries drop out automatically (utils._filter_spec), so
    each weight keeps roughly a 1/n_model resident footprint and is
    all-gathered on use — FSDP/ZeRO-3-style inference dataflow, which keeps
    the matmuls full-shape (the losslessness requirement above)."""
    with mesh_scope(mesh):
        return param_specs(params, rules)


def _serve_state_leaf(path_s: str, leaf, mesh) -> P:
    name = path_s.rsplit("/", 1)[-1]
    if name in ("k", "v") and leaf.ndim >= 4:
        nd = leaf.ndim
        ent = [None] * nd
        ent[nd - 2] = "model"                    # KV-head axis
        spec = _filter_spec(leaf.shape, ent, mesh)
        if spec[nd - 2] is None:                 # narrow GQA → shard head_dim
            ent[nd - 2], ent[nd - 1] = None, "model"
            spec = _filter_spec(leaf.shape, ent, mesh)
        return spec
    return P()


def serve_state_specs(state, mesh):
    """Storage-sharding PartitionSpecs for a serving decode state (either
    layout: contiguous per-slot caches or the paged state whose full-length
    KV leaves are page pools + a ``block_table``).

    Attention K/V — the k/v leaves of target, drafter, and encdec cross
    caches, whether contiguous full-length rows, page pools, or per-slot
    ring windows — shards (KV-head axis, head_dim fallback); every other
    leaf replicates. Leaves are matched by name and rank, so the one rule
    covers the (..., B, max_len, KV, hd), (..., NP, page, KV, hd), and
    (..., B, W, KV, hd) shapes alike; block tables and position pools stay
    replicated so page growth and preemption (``Engine.ensure_capacity`` /
    ``cache_ops.blank_pages``) are pure host-or-replicated updates that
    never relayout the sharded pools."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: _serve_state_leaf(_path_str(p), l, mesh), state)
