"""Mesh-aware sharding helpers.

Models sprinkle ``shard_hint(x, "data", None, "model")`` constraints; on a
single-device CPU run (tests, benchmarks) there is no mesh and the hint is a
no-op, while under ``jax.set_mesh``/``with mesh`` in the dry-run and launchers
it becomes ``with_sharding_constraint``. Axes that do not exist in the mesh or
do not divide the corresponding dimension are dropped from the spec rather
than erroring, which lets one model definition serve every (arch × mesh).
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

AxisEntry = Union[None, str, Sequence[str]]


# Legacy-jax fallback (no set_mesh/use_mesh/get_abstract_mesh, e.g. 0.4.x):
# launch.steps.mesh_context pushes the concrete Mesh here; a concrete Mesh
# exposes the same .empty/.axis_names/.shape surface the abstract mesh does.
_FALLBACK_MESH: list = []


def _current_mesh():
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        mesh = _FALLBACK_MESH[-1] if _FALLBACK_MESH else None
    if mesh is None or mesh.empty or not mesh.axis_names:
        return None
    return mesh


def axis_size(name: str, default: int = 1) -> int:
    mesh = _current_mesh()
    if mesh is None or name not in mesh.axis_names:
        return default
    return mesh.shape[name]


def batch_axes() -> AxisEntry:
    """Axes the global batch shards over: ("pod","data") when both exist."""
    mesh = _current_mesh()
    if mesh is None:
        return None
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    return tuple(axes) if axes else None


def _filter_spec(shape, spec_entries, mesh) -> Optional[P]:
    out = []
    for dim, entry in zip(shape, spec_entries):
        if entry is None:
            out.append(None)
            continue
        names = (entry,) if isinstance(entry, str) else tuple(entry)
        names = [n for n in names if n in mesh.axis_names]
        total = 1
        for n in names:
            total *= mesh.shape[n]
        if not names or total == 0 or dim % total != 0:
            out.append(None)
        else:
            out.append(names[0] if len(names) == 1 else tuple(names))
    return P(*out)


def shard_hint(x: jax.Array, *spec_entries: AxisEntry) -> jax.Array:
    """Best-effort with_sharding_constraint; no-op without a mesh context."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    entries = list(spec_entries) + [None] * (x.ndim - len(spec_entries))
    spec = _filter_spec(x.shape, entries[: x.ndim], mesh)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def spec_for(shape, *spec_entries: AxisEntry) -> P:
    """Resolve a divisibility-filtered PartitionSpec for a concrete shape."""
    mesh = _current_mesh()
    if mesh is None:
        return P()
    entries = list(spec_entries) + [None] * (len(shape) - len(spec_entries))
    return _filter_spec(shape, entries[: len(shape)], mesh)
