"""Mesh-aware sharding helpers.

Models sprinkle ``shard_hint(x, "data", None, "model")`` constraints; on a
single-device CPU run (tests, benchmarks) there is no mesh and the hint is a
no-op, while under ``mesh_scope`` (``jax.set_mesh``/``with mesh``) in the
dry-run, launchers, and the model-sharded serving engine it becomes
``with_sharding_constraint``. Axes that do not exist in the mesh or do not
divide the corresponding dimension are dropped from the spec rather than
erroring, which lets one model definition serve every (arch × mesh).
"""
from __future__ import annotations

import contextlib
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

AxisEntry = Union[None, str, Sequence[str]]


# Legacy-jax fallback (no set_mesh/use_mesh/get_abstract_mesh, e.g. 0.4.x):
# mesh_scope pushes the concrete Mesh here; a concrete Mesh exposes the same
# .empty/.axis_names/.shape surface the abstract mesh does.
_FALLBACK_MESH: list = []


def mesh_scope(mesh):
    """Enter ``mesh`` so ``shard_hint`` / ``spec_for`` / the rules in
    sharding/rules.py see it during tracing or eager spec resolution.

    Uses ``jax.set_mesh`` / ``jax.sharding.use_mesh`` when the installed jax
    has them; on legacy jax (0.4.x) falls back to pushing the concrete Mesh
    onto ``_FALLBACK_MESH`` and entering ``with mesh:`` (the physical
    resource env bare-``PartitionSpec`` constraints need there)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)       # context manager in jax >= 0.7
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return _legacy_mesh_scope(mesh)


@contextlib.contextmanager
def _legacy_mesh_scope(mesh):
    _FALLBACK_MESH.append(mesh)
    try:
        with mesh:                      # resource env for bare-P constraints
            yield mesh
    finally:
        _FALLBACK_MESH.pop()


def serving_mesh(n_devices: Optional[int] = None):
    """1-D ``("model",)`` mesh over the first ``n_devices`` local devices
    (all of them when None) — the serving engine's tensor-sharding mesh.

    Serving shards *storage* over a single model axis (weights and KV page
    pools; see docs/sharding.md): there is no data axis because the
    scheduler's continuous batch is one replica — request rows are slots of
    one decode state, not a data-parallel shard."""
    devs = jax.devices()
    n = len(devs) if n_devices is None else n_devices
    if n > len(devs):
        raise ValueError(f"serving_mesh({n}): only {len(devs)} devices")
    return jax.sharding.Mesh(np.asarray(devs[:n]), ("model",))


def replicate_tree(tree, mesh):
    """Constrain every leaf of ``tree`` to be fully replicated over ``mesh``
    (inside jit: an all-gather at this point for sharded-at-rest leaves).

    This is the serving engine's exactness boundary: storage-sharded
    weights/pools are gathered here and every op downstream computes with
    the exact tensor shapes of a single-device run, so results are
    bit-identical to the unsharded engine (reduction order and backend
    matmul tiling are shape-dependent — sharded *compute* is not lossless;
    sharded *storage* with gather-on-use is)."""
    repl = NamedSharding(mesh, P())
    return jax.tree.map(
        lambda x: jax.lax.with_sharding_constraint(x, repl), tree)


def _current_mesh():
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        mesh = _FALLBACK_MESH[-1] if _FALLBACK_MESH else None
    if mesh is None or mesh.empty or not mesh.axis_names:
        return None
    return mesh


def axis_size(name: str, default: int = 1) -> int:
    mesh = _current_mesh()
    if mesh is None or name not in mesh.axis_names:
        return default
    return mesh.shape[name]


def batch_axes() -> AxisEntry:
    """Axes the global batch shards over: ("pod","data") when both exist."""
    mesh = _current_mesh()
    if mesh is None:
        return None
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    return tuple(axes) if axes else None


def _filter_spec(shape, spec_entries, mesh) -> Optional[P]:
    out = []
    for dim, entry in zip(shape, spec_entries):
        if entry is None:
            out.append(None)
            continue
        names = (entry,) if isinstance(entry, str) else tuple(entry)
        names = [n for n in names if n in mesh.axis_names]
        total = 1
        for n in names:
            total *= mesh.shape[n]
        if not names or total == 0 or dim % total != 0:
            out.append(None)
        else:
            out.append(names[0] if len(names) == 1 else tuple(names))
    return P(*out)


def shard_hint(x: jax.Array, *spec_entries: AxisEntry) -> jax.Array:
    """Best-effort with_sharding_constraint; no-op without a mesh context."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    entries = list(spec_entries) + [None] * (x.ndim - len(spec_entries))
    spec = _filter_spec(x.shape, entries[: x.ndim], mesh)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def spec_for(shape, *spec_entries: AxisEntry) -> P:
    """Resolve a divisibility-filtered PartitionSpec for a concrete shape."""
    mesh = _current_mesh()
    if mesh is None:
        return P()
    entries = list(spec_entries) + [None] * (len(shape) - len(spec_entries))
    return _filter_spec(shape, entries[: len(shape)], mesh)
