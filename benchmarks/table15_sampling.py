"""Beyond-paper Table 15 — per-request sampling: acceptance length vs
temperature, and mixed greedy/sampled-batch throughput.

The SamplingParams redesign makes verification a per-request policy:
``temperature == 0`` rows take the greedy argmax path and sampled rows run
seeded rejection verification against the row-warped target distribution,
inside ONE jitted step. Two questions this table answers:

  AL vs temperature — drafts are deterministic argmax tokens, so lossless
      rejection accepts a draft w.p. p(d) under the warped target;
      acceptance length degrades as the warped target flattens (higher
      temperature spreads p away from the drafter's argmax). temperature 0
      reproduces the greedy AL.
      NOTE the CPU-reduced target here is random-init and therefore
      near-flat (its argmax token carries p ~ 1e-2) while the trained
      drafter is confident (q ~ 1), so sampled AL collapses close to 1.0 —
      the monotone degradation from the greedy ceiling is the claim, not
      the absolute values; a trained target gives a gentler curve.

  mixed-batch OTPS — a batch alternating greedy and T=0.8 requests serves
      through the same engine/trace with no mode switch; its OTPS should
      land between the all-greedy and all-sampled rows (the redesign's
      acceptance criterion: one compiled step for any policy mix).

Every sampled request runs on its own deterministic PRNG stream
(seed = request index), so rows are bitwise reproducible run to run. Rows
are persisted to results/table15_sampling.csv.
"""
import numpy as np

from benchmarks.common import (get_corpus, get_target, longtail_budgets, row,
                               train_drafter, write_results_csv)
from repro.serving import (Engine, EngineConfig, Request, SamplingParams,
                           Scheduler)

TEMPS = [0.0, 0.5, 0.8, 1.0]
MAX_LEN = 128
B_SLOTS = 4


def run(epochs=15, n_requests=16, max_new=24):
    arch = "qwen2-1.5b"
    tcfg, m, tparams = get_target(arch)
    dcfg, dp, _ = train_drafter("table9_peagle_" + arch, arch=arch,
                                epochs=epochs, n_layers=4, k_train=8)

    corpus = get_corpus(arch)
    rng = np.random.default_rng(15)
    rows_ = rng.choice(len(corpus), size=n_requests, replace=False)
    prompts = [np.asarray(corpus[i, :6]) for i in rows_]
    budgets = longtail_budgets(n_requests, max_new, rng)

    eng = Engine(tcfg, dcfg, tparams, dp,
                 EngineConfig(K=5, max_new_tokens=max_new,
                              drafter_mode="parallel", max_len=MAX_LEN),
                 B_SLOTS)
    sched = Scheduler(eng)

    def serve(sps):
        rep = None
        for _ in range(2):                       # warm second run
            rep = sched.serve([Request(p, max_new_tokens=b, sampling=sp)
                               for p, b, sp in zip(prompts, budgets, sps)])
        return rep

    def params(t, i):
        if t == 0.0:
            return SamplingParams.greedy(seed=i)
        return SamplingParams(temperature=t, seed=i)

    csv_rows, results = [], {}
    for t in TEMPS:
        rep = serve([params(t, i) for i in range(n_requests)])
        results[t] = rep
        csv_rows.append({"discipline": f"T={t}", "temperature": t,
                         "acceptance_length": rep["weighted_acceptance_length"],
                         "otps": rep["otps"],
                         "total_new_tokens": rep["total_new_tokens"],
                         "iterations": rep["iterations"]})
        row(f"table15/T{t}", 1e6 / max(rep["otps"], 1e-9),
            f"AL={rep['weighted_acceptance_length']:.2f} "
            f"OTPS={rep['otps']:.1f} "
            f"({rep['total_new_tokens']} tokens, "
            f"{rep['iterations']} iterations)")

    # mixed batch: even requests greedy, odd at T=0.8 — one engine, one
    # compiled step, no mode switch
    mixed = serve([params(0.0 if i % 2 == 0 else 0.8, i)
                   for i in range(n_requests)])
    csv_rows.append({"discipline": "mixed greedy/T=0.8", "temperature": "",
                     "acceptance_length": mixed["weighted_acceptance_length"],
                     "otps": mixed["otps"],
                     "total_new_tokens": mixed["total_new_tokens"],
                     "iterations": mixed["iterations"]})
    lo = min(results[0.8]["otps"], results[0.0]["otps"])
    hi = max(results[0.8]["otps"], results[0.0]["otps"])
    row("table15/mixed", 1e6 / max(mixed["otps"], 1e-9),
        f"AL={mixed['weighted_acceptance_length']:.2f} "
        f"OTPS={mixed['otps']:.1f} vs all-greedy {results[0.0]['otps']:.1f} "
        f"/ all-T0.8 {results[0.8]['otps']:.1f} "
        f"({'PASS' if mixed['otps'] > 0.5 * lo else 'FAIL'}: mixed batch "
        "must serve through the same step without collapsing)")
    al_greedy = results[0.0]["weighted_acceptance_length"]
    al_hot = results[1.0]["weighted_acceptance_length"]
    row("table15/al_trend", al_greedy / max(al_hot, 1e-9),
        f"AL greedy/T=1.0 = {al_greedy:.2f}/{al_hot:.2f} — rejection "
        "sampling accepts fewer drafts as the warped target flattens")
    path = write_results_csv("table15_sampling.csv", csv_rows)
    print(f"# wrote {path}")
    return {"per_temp": results, "mixed": mixed}


if __name__ == "__main__":
    run()
