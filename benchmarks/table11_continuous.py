"""Beyond-paper Table 11 — continuous (per-slot refill) vs round-based
batching under a long-tail request mix.

The paper's deployed numbers (§5.4, vLLM integration) assume a scheduler
that refills a finished slot immediately. Our previous driver faked this by
refilling the queue only *between* full generation rounds, so every round
ran at the pace of its slowest request. This table quantifies the gap on a
long-tail workload (a few long requests, many short ones — the realistic
serving distribution): round-based OTPS pays the straggler on every round,
continuous does not. Also sweeps the scheduler's ``sync_every`` knob
(iterations dispatched between host syncs).

Output losslessness between the two disciplines is a test invariant
(tests/test_scheduler.py); this table is about throughput only.
"""
import numpy as np

from benchmarks.common import (get_corpus, get_target, longtail_budgets, row,
                               train_drafter)
from repro.serving import (Engine, EngineConfig, Request, Scheduler,
                           serve_round_based)


def longtail_requests(arch, n_requests, max_new, seed=5, prompt_len=6):
    """~1/4 long (full budget) requests, the rest short — per-request budgets
    for the continuous scheduler; round-based can only run every request to
    the full budget (its engine has one shared max_new_tokens)."""
    corpus = get_corpus(arch)
    rng = np.random.default_rng(seed)
    rows_ = rng.choice(len(corpus), size=n_requests, replace=False)
    prompts = [np.asarray(corpus[i, :prompt_len]) for i in rows_]
    return prompts, longtail_budgets(n_requests, max_new, rng)


def run(epochs=15, batch=4, n_requests=12, max_new=24):
    arch = "qwen2-1.5b"
    tcfg, m, tparams = get_target(arch)
    dcfg_p, dp_p, _ = train_drafter(
        "table9_peagle_" + arch, arch=arch, epochs=epochs, n_layers=4,
        k_train=8)
    prompts, budgets = longtail_requests(arch, n_requests, max_new)

    results = {}
    for mode, dcfg, dp, K in [("none", None, None, 0),
                              ("parallel", dcfg_p, dp_p, 5)]:
        eng = Engine(tcfg, dcfg, tparams, dp,
                     EngineConfig(K=K, max_new_tokens=max_new,
                                  drafter_mode=mode, max_len=128), batch)
        # same per-request budgets both ways; round-based rows freeze early
        # on device but their slots idle until the round's straggler drains
        rb = None
        for _ in range(2):                       # warm second run
            rb = serve_round_based(eng, prompts, budgets)
        row(f"table11/round_{mode}", 1e6 / max(rb["otps"], 1e-9),
            f"OTPS={rb['otps']:.1f} rounds={rb['rounds']}")
        for sync_every in (1, 4):
            sched = Scheduler(eng, sync_every=sync_every)
            co = None
            for _ in range(2):
                co = sched.serve([Request(p, max_new_tokens=b)
                                  for p, b in zip(prompts, budgets)])
            sp = co["otps"] / max(rb["otps"], 1e-9)
            row(f"table11/cont_{mode}_s{sync_every}",
                1e6 / max(co["otps"], 1e-9),
                f"OTPS={co['otps']:.1f} AL={co['weighted_acceptance_length']:.2f} "
                f"vs_round={sp:.2f}x "
                f"mean_latency_ms={co['mean_latency_s'] * 1e3:.0f}")
            results[(mode, sync_every)] = (rb["otps"], co["otps"], sp)
    return results


if __name__ == "__main__":
    run()
