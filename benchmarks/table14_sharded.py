"""Beyond-paper Table 14 — model-sharded serving of the scheduler loop:
OTPS and per-step dispatch overhead at serving-mesh sizes 1/2/4/8.

The model-sharded engine (``EngineConfig(shard_model=True)``, see
docs/sharding.md) storage-shards weights and the paged KV pools over a 1-D
``("model",)`` mesh and gathers them at an explicit replication boundary
inside each jitted step — token-for-token lossless by construction (the
tier-1 parametrized tests pin it; this table re-asserts it per row).

On this CPU container every "device" is a forced host-platform device
carved from the same CPU, so there is no memory-capacity or FLOP win to
measure — what the table isolates is the *cost* side of the design: the
per-step dispatch + gather/scatter overhead the replication boundary adds
as the mesh grows, over an identical async workload. Reported per mesh
size: OTPS (wall), virtual-time makespan, mean per-step wall time, and the
per-step overhead vs the unsharded engine. Rows persist to
``results/table14_sharded.csv``.

Needs >= 8 jax devices; when the current process was initialised without
them (e.g. via ``benchmarks/run.py``), it re-execs itself in a subprocess
with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — the same
forced-host-device setup as CI's tier1-multidevice lane.
"""
import os
import subprocess
import sys

import numpy as np

MESH_SIZES = (1, 2, 4, 8)
PAGE = 8
MAX_LEN = 128


def _serve_workload(eng, prompts, budgets, arrivals):
    from repro.serving import Request, Scheduler
    sched = Scheduler(eng)
    rep = None
    for _ in range(2):                 # second run = warm, compile excluded
        rep = sched.serve([Request(p, max_new_tokens=b, arrival_time=a)
                           for p, b, a in zip(prompts, budgets, arrivals)])
    return rep


def run(epochs=15, n_requests=16, max_new=20, mean_gap=0.5):
    import jax
    if jax.device_count() < max(MESH_SIZES):
        if os.environ.get("_TABLE14_CHILD"):
            raise RuntimeError(
                f"forced host devices did not take effect (jax sees "
                f"{jax.device_count()}); not re-execing again")
        # jax is already initialised single-device: re-exec with forced
        # host devices (the flag only takes effect before first jax use).
        # Any pre-existing force-count flag is REPLACED, not shadowed —
        # XLA lets the last duplicate win, which would loop forever.
        env = dict(os.environ)
        flags = [f for f in env.get("XLA_FLAGS", "").split()
                 if not f.startswith(
                     "--xla_force_host_platform_device_count")]
        flags.append(
            f"--xla_force_host_platform_device_count={max(MESH_SIZES)}")
        env["XLA_FLAGS"] = " ".join(flags)
        env["_TABLE14_CHILD"] = "1"
        env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..",
                                          "src") + os.pathsep
                             + env.get("PYTHONPATH", ""))
        ret = subprocess.run(
            [sys.executable, "-m", "benchmarks.table14_sharded",
             f"--epochs={epochs}", f"--n-requests={n_requests}",
             f"--max-new={max_new}", f"--mean-gap={mean_gap}"],
            cwd=os.path.join(os.path.dirname(__file__), ".."), env=env)
        if ret.returncode:
            raise RuntimeError("table14 subprocess failed")
        return

    from benchmarks.common import (get_corpus, longtail_budgets, get_target,
                                   row, train_drafter, write_results_csv)
    from repro.serving import Engine, EngineConfig
    from repro.sharding.utils import serving_mesh

    arch = "qwen2-1.5b"
    tcfg, m, tparams = get_target(arch)
    dcfg, dp, _ = train_drafter("table9_peagle_" + arch, arch=arch,
                                epochs=epochs, n_layers=4, k_train=8)

    corpus = get_corpus(arch)
    rng = np.random.default_rng(29)
    rows_ = rng.choice(len(corpus), size=n_requests, replace=False)
    prompts = [np.asarray(corpus[i, :6]) for i in rows_]
    budgets = longtail_budgets(n_requests, max_new, rng)
    arrivals = np.cumsum(rng.exponential(mean_gap, size=n_requests)).tolist()

    def make(n_shard):
        return Engine(tcfg, dcfg, tparams, dp,
                      EngineConfig(K=5, max_new_tokens=max_new,
                                   drafter_mode="parallel", max_len=MAX_LEN,
                                   kv_layout="paged", page_size=PAGE,
                                   shard_model=n_shard > 0,
                                   mesh=(serving_mesh(n_shard)
                                         if n_shard else None)),
                      batch=4)

    ref = _serve_workload(make(0), prompts, budgets, arrivals)
    ref_step_us = ref["wall_s"] / max(ref["iterations"], 1) * 1e6
    ref_tokens = [r["tokens"] for r in ref["results"]]
    out = [{"mesh": 0, "otps": round(ref["otps"], 1),
            "makespan_vt": round(ref["makespan_vt"], 1),
            "step_us": round(ref_step_us, 1), "overhead_us": 0.0,
            "lossless": True}]
    row("table14/unsharded", ref_step_us, f"otps={ref['otps']:.1f}")

    for n in MESH_SIZES:
        rep = _serve_workload(make(n), prompts, budgets, arrivals)
        step_us = rep["wall_s"] / max(rep["iterations"], 1) * 1e6
        lossless = all(np.array_equal(a, b["tokens"])
                       for a, b in zip(ref_tokens, rep["results"]))
        out.append({"mesh": n, "otps": round(rep["otps"], 1),
                    "makespan_vt": round(rep["makespan_vt"], 1),
                    "step_us": round(step_us, 1),
                    "overhead_us": round(step_us - ref_step_us, 1),
                    "lossless": lossless})
        row(f"table14/mesh{n}", step_us,
            f"otps={rep['otps']:.1f} overhead_us="
            f"{step_us - ref_step_us:.0f} lossless={lossless}")
        if not lossless:
            raise AssertionError(
                f"mesh={n} diverged from the single-device stream — the "
                "sharded engine must be token-for-token lossless")

    path = write_results_csv("table14_sharded.csv", out)
    print(f"# wrote {path}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=15)
    ap.add_argument("--n-requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=20)
    ap.add_argument("--mean-gap", type=float, default=0.5)
    args = ap.parse_args()
    run(epochs=args.epochs, n_requests=args.n_requests,
        max_new=args.max_new, mean_gap=args.mean_gap)
