"""Paper Table 8 — longer training sequences help (§4.6), modestly for
non-reasoning targets."""
from benchmarks.common import eval_engine, get_corpus, row, train_drafter


def run(epochs=15, lens=(24, 48)):
    als = {}
    for n in lens:
        corpus = get_corpus("qwen2-1.5b", n_seqs=64, seq_len=n)
        tag = "table3_shared" if n == 48 else f"table8_n{n}"
        dcfg, dparams, _ = train_drafter(
            tag, epochs=epochs, corpus=corpus, n_layers=2, k_train=5)
        r = eval_engine("qwen2-1.5b", dcfg, dparams, K=5)
        als[n] = r["acceptance_length"]
    base = als[lens[0]]
    for n, al in als.items():
        row(f"table8/seqlen_{n}", al * 1e6,
            f"AL={al:.3f} delta={(al - base) / base * 100:+.1f}%")
    return als


if __name__ == "__main__":
    run()
