"""Paper Table 9 — acceptance length: AR EAGLE-3 (TTT+HCA, 1 layer, the
paper's strong baseline) vs P-EAGLE (4 layers) across three target models.
The paper's claim to validate: P-EAGLE *matches or slightly exceeds* the AR
baseline (+0-5% average) — parallel drafting does not sacrifice quality."""
from benchmarks.common import eval_engine, row, train_drafter

ARCHS = ("qwen2-1.5b", "mamba2-780m", "recurrentgemma-2b")


def run(epochs=22):
    out = {}
    for arch in ARCHS:
        dcfg_ar, dp_ar, _ = train_drafter(
            "table9_ar_" + arch, arch=arch, epochs=epochs, n_layers=1,
            parallel=False, ttt_steps=2, hca=True, k_train=1, cod_rate=0.99)
        r_ar = eval_engine(arch, dcfg_ar, dp_ar, K=5, mode="ar")
        dcfg_p, dp_p, _ = train_drafter(
            "table9_peagle_" + arch, arch=arch, epochs=epochs, n_layers=4, k_train=8)
        r_p = eval_engine(arch, dcfg_p, dp_p, K=5, mode="parallel")
        al_ar, al_p = r_ar["acceptance_length"], r_p["acceptance_length"]
        d = (al_p - al_ar) / al_ar * 100
        row(f"table9/{arch}_ar_eagle3", al_ar * 1e6, f"AL={al_ar:.3f}")
        row(f"table9/{arch}_peagle4L", al_p * 1e6,
            f"AL={al_p:.3f} delta={d:+.1f}%")
        out[arch] = (al_ar, al_p)
    return out


if __name__ == "__main__":
    run()
