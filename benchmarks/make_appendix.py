"""Regenerate EXPERIMENTS.md §Appendix roofline tables from results/."""
import os
import re

from benchmarks.roofline import load_records, markdown_table

ROOT = os.path.join(os.path.dirname(__file__), "..")


def main():
    base = load_records("baseline")
    opt = load_records("optimized")
    parts = ["## §Appendix — roofline tables\n"]
    for name, recs, mesh in [("Baseline, single-pod 16×16", base, "16x16"),
                             ("Baseline, two-pod 2×16×16", base, "2x16x16"),
                             ("Optimized, single-pod 16×16", opt, "16x16"),
                             ("Optimized, two-pod 2×16×16", opt, "2x16x16")]:
        if not recs:
            continue
        parts.append(f"### {name}\n")
        parts.append(markdown_table(recs, mesh=mesh))
        parts.append("")
    appendix = "\n".join(parts)

    fn = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(fn) as f:
        txt = f.read()
    txt = re.sub(r"## §Appendix.*\Z", "", txt, flags=re.S).rstrip() + "\n\n"
    with open(fn, "w") as f:
        f.write(txt + appendix + "\n")
    print(f"appendix written: {len(base)} baseline + {len(opt)} optimized "
          f"records")


if __name__ == "__main__":
    main()
