"""Paper Table 1 — scalability across training context lengths.

Scaled to CPU: context lengths {32, 64, 96} stand in for {1K, 4K, 8K, 20K}.
For each method we report (a) the attention-cell count a training step must
materialize (the quantity that OOMs ParallelSpec/PARD in the paper) and
(b) measured wall time of one training step, and (c) acceptance length of
the trained drafter (ours only at the largest context — the others are
reported at the contexts they can train).

  ParallelSpec-style: all n·K positions, no COD, no partitioning.
  PARD-style:         COD positions, per-example mask rebuild, no partition.
  P-EAGLE (ours):     COD + amortized mask + S=2 sequence partitioning.
"""
import time

import numpy as np

from benchmarks.common import (get_corpus, get_target, row, train_drafter,
                               eval_engine)
from repro.core import cod, partition


def attention_cells(n, K, r, method):
    if method == "parallelspec":
        m = n * K
        return m * m
    m = cod.expanded_length(n, K, r)
    if method == "pard":
        return m * m
    # ours: partitioned into S=2 segments
    rng = np.random.default_rng(0)
    pos, depth = cod.sample_cod(rng, n, K, r)
    segs = partition.build_segments(pos, depth, n, 2)
    return max(len(s.kv_pos) ** 2 for s in segs)


def run(contexts=(32, 64, 96), K=5, r=0.8):
    for n in contexts:
        for method in ("parallelspec", "pard", "ours"):
            cells = attention_cells(n, K, r, method)
            row(f"table1/attn_cells_n{n}_{method}", cells,
                "peak attention matrix entries")

    # measured: train at the largest context with ours (full + segmented)
    n = contexts[-1]
    corpus = get_corpus("qwen2-1.5b", n_seqs=32, seq_len=n)
    t0 = time.perf_counter()
    dcfg, dparams, log = train_drafter(
        f"table1_ours_n{n}", epochs=12, corpus=corpus,
        n_layers=2, k_train=K, cod_rate=r, segments=2)
    t_train = time.perf_counter() - t0
    r_eval = eval_engine("qwen2-1.5b", dcfg, dparams, K=K)
    row(f"table1/ours_n{n}_train_s", t_train * 1e6,
        f"AL={r_eval['acceptance_length']:.2f}")
    return r_eval["acceptance_length"]


if __name__ == "__main__":
    run()
