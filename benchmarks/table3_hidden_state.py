"""Paper Table 3 — hidden-state design ablation (§4.1).

Five strategies for MTP positions; the paper finds the simple learnable
shared state wins by 7-15%. We train each variant identically and report
acceptance length + Δ% vs the shared baseline, plus the learned α of the
regularized variant (paper: decays 0.1 → ~0.03)."""
import numpy as np

from benchmarks.common import eval_engine, row, train_drafter

VARIANTS = ("shared", "depth_encoding", "ntp_hidden", "ntp_hidden_depth",
            "regularized")


def run(epochs=15):
    als = {}
    alphas = {}
    for v in VARIANTS:
        dcfg, dparams, log = train_drafter(
            f"table3_{v}", epochs=epochs, n_layers=2, k_train=5,
            hidden_state_variant=v)
        r = eval_engine("qwen2-1.5b", dcfg, dparams, K=5)
        als[v] = r["acceptance_length"]
        if v == "regularized":
            alphas[v] = float(np.asarray(dparams["alpha"]))
    base = als["shared"]
    for v in VARIANTS:
        d = (als[v] - base) / base * 100
        extra = f"AL={als[v]:.3f} delta={d:+.1f}%"
        if v in alphas:
            extra += f" alpha={alphas[v]:.3f}"
        row(f"table3/{v}", als[v] * 1e6, extra)
    return als


if __name__ == "__main__":
    run()
