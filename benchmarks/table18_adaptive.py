"""Beyond-paper Table 18 — adaptive speculation: warped-proposal sampled
drafting vs one-hot, and per-request dynamic K vs fixed K.

Two claims of the adaptive-speculation PR, measured on the deterministic
virtual clock (bitwise-replayable runs):

  warped proposals — a sampled row's drafts are themselves drawn from the
      row-warped drafter distribution, so rejection verification runs with
      the TRUE proposal q instead of a one-hot spike at the drafter's
      argmax. Acceptance per slot becomes sum_d min(q(d), p(d)) >=
      p(argmax q): as temperature flattens both warps, the overlap of two
      spread distributions beats the single argmax probe — on this
      CPU-reduced rig (near-flat random-init target, confident trained
      drafter) the gap widens with temperature, which is exactly the
      regime the one-hot proposal collapses in (table 15's AL ~ 1).

  adaptive K — hard rows (sampled, hot) accept ~0 drafts but still pay K
      verify positions and, under the paged layout, ``K + 1`` reserved
      positions per growth quantum. The controller drops them to
      ``k_row ~ 1`` while easy greedy rows keep full depth, so a
      mixed-difficulty workload over a TIGHT page pool preempts less and
      finishes sooner (otps_vt >= fixed-K). Greedy rows stay bitwise
      identical — the gate below diffs their token streams across every
      variant.

Rows are persisted to results/table18_adaptive.csv with the
iteration-weighted acceptance length (the honest aggregate — see
Scheduler._report).
"""
import numpy as np

from benchmarks.common import (get_corpus, get_target, longtail_budgets, row,
                               train_drafter, write_results_csv)
from repro.serving import (Engine, EngineConfig, Request, SamplingParams,
                           Scheduler)

TEMPS = [0.8, 1.0, 1.3]
MAX_LEN = 128
B_SLOTS = 4
K = 5
POOL_PAGES = 14          # tight: fits admissions, not every full-grown slot
SYNC_EVERY = 2           # growth quantum sync_every*(k+1) — the stride the
                         # adaptive controller shrinks on hard rows


def _engine(tcfg, tparams, dcfg, dparams, *, warped, pool_pages=0):
    return Engine(tcfg, dcfg, tparams, dparams,
                  EngineConfig(K=K, max_new_tokens=24,
                               drafter_mode="parallel", max_len=MAX_LEN,
                               kv_layout="paged", page_size=8,
                               pool_pages=pool_pages,
                               draft_sampling=warped),
                  B_SLOTS)


def run(epochs=15, n_requests=16, max_new=24):
    arch = "qwen2-1.5b"
    tcfg, m, tparams = get_target(arch)
    dcfg, dp, _ = train_drafter("table9_peagle_" + arch, arch=arch,
                                epochs=epochs, n_layers=4, k_train=8)

    corpus = get_corpus(arch)
    rng = np.random.default_rng(18)
    rows_ = rng.choice(len(corpus), size=n_requests, replace=False)
    prompts = [np.asarray(corpus[i, :6]) for i in rows_]
    budgets = longtail_budgets(n_requests, max_new, rng)

    engines = {w: _engine(tcfg, tparams, dcfg, dp, warped=w)
               for w in (False, True)}

    def serve(eng, sps, adaptive=False, budgets_=None, sync_every=1):
        return Scheduler(eng, adaptive_k=adaptive,
                         sync_every=sync_every).serve(
            [Request(p, max_new_tokens=b, sampling=sp)
             for p, b, sp in zip(prompts, budgets_ or budgets, sps)])

    csv_rows = []

    # ---- claim 1: warped-proposal AL beats one-hot, per temperature ----
    al = {}
    for t in TEMPS:
        sps = [SamplingParams(temperature=t, seed=i)
               for i in range(n_requests)]
        for warped in (False, True):
            rep = serve(engines[warped], sps)
            al[(t, warped)] = rep["weighted_acceptance_length"]
            csv_rows.append({
                "discipline": f"{'warped' if warped else 'one_hot'} T={t}",
                "proposal": "warped" if warped else "one_hot",
                "adaptive_k": 0, "temperature": t,
                "weighted_acceptance_length":
                    rep["weighted_acceptance_length"],
                "otps_vt": rep["otps_vt"], "preemptions": rep["preemptions"],
                "total_new_tokens": rep["total_new_tokens"],
                "iterations": rep["iterations"], "mean_k": K})
        ok = al[(t, True)] > al[(t, False)]
        row(f"table18/proposal_T{t}", 1e6 / max(al[(t, True)], 1e-9),
            f"AL warped={al[(t, True)]:.3f} vs one-hot="
            f"{al[(t, False)]:.3f} "
            f"({'PASS' if ok else 'FAIL'}: sampled drafts must verify "
            "against their true proposal and accept more)")

    # ---- claim 2: adaptive K >= fixed K on a mixed workload, tight pool --
    # even requests greedy and short (easy: high AL, few pages); odd
    # sampled hot AND long (hard: AL ~ 1, page-hungry) — the rows whose
    # ``K + 1`` growth reservation a tight pool cannot afford but whose
    # ``k_row + 1`` it can
    mixed_sps = [SamplingParams.greedy(seed=i) if i % 2 == 0
                 else SamplingParams(temperature=1.0, seed=i)
                 for i in range(n_requests)]
    mixed_budgets = [6 if i % 2 == 0 else max_new
                     for i in range(n_requests)]
    tight = {w: _engine(tcfg, tparams, dcfg, dp, warped=w,
                        pool_pages=POOL_PAGES) for w in (False, True)}
    reps = {}
    for warped in (False, True):
        for adaptive in (False, True):
            rep = serve(tight[warped], mixed_sps, adaptive=adaptive,
                        budgets_=mixed_budgets, sync_every=SYNC_EVERY)
            reps[(warped, adaptive)] = rep
            mk = rep.get("speculation", {}).get("mean_k", K)
            csv_rows.append({
                "discipline":
                    f"mixed {'warped' if warped else 'one_hot'} "
                    f"{'adaptive' if adaptive else 'fixed'}-K",
                "proposal": "warped" if warped else "one_hot",
                "adaptive_k": int(adaptive), "temperature": "mixed",
                "weighted_acceptance_length":
                    rep["weighted_acceptance_length"],
                "otps_vt": rep["otps_vt"], "preemptions": rep["preemptions"],
                "total_new_tokens": rep["total_new_tokens"],
                "iterations": rep["iterations"], "mean_k": mk})

    for warped in (False, True):
        fx, ad = reps[(warped, False)], reps[(warped, True)]
        ok = ad["otps_vt"] >= fx["otps_vt"]
        tag = "warped" if warped else "one_hot"
        row(f"table18/adaptive_{tag}", 1e6 / max(ad["otps_vt"], 1e-9),
            f"otps_vt adaptive={ad['otps_vt']:.2f} (preempt "
            f"{ad['preemptions']}, mean_k "
            f"{ad.get('speculation', {}).get('mean_k', K):.2f}) vs "
            f"fixed={fx['otps_vt']:.2f} (preempt {fx['preemptions']}) "
            f"({'PASS' if ok else 'FAIL'}: shallow drafts on hard rows "
            "must not slow the mixed workload)")

    # ---- gate: greedy rows bitwise identical across every variant -------
    ref = reps[(False, False)]["results"]
    drift = 0
    for key, rep in reps.items():
        for i in range(0, n_requests, 2):
            if not np.array_equal(rep["results"][i]["tokens"],
                                  ref[i]["tokens"]):
                drift += 1
    row("table18/greedy_bitwise", float(drift),
        f"{drift} greedy streams diverged across proposal/adaptive "
        f"variants ({'PASS' if drift == 0 else 'FAIL'}: the controller "
        "and sampled neighbors must never perturb greedy content)")

    path = write_results_csv("table18_adaptive.csv", csv_rows)
    print(f"# wrote {path}")
    return {"al": al, "mixed": reps, "greedy_drift": drift}


if __name__ == "__main__":
    run()
