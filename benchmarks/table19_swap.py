"""Beyond-paper Table 19 — swap-to-host preemption vs recompute-prefill
preemption at IDENTICAL device pool bytes.

Workload: long-prompt Poisson mix (P-EAGLE's reasoning-workload premise —
32-token prompts over the long-tail budget mix), more decode slots than the
page pool can back, so the scheduler must preempt. The two disciplines:

  recompute (PR 6/7) — the victim's pages are freed; resume re-pays the
      whole prefix as a recompute-prefill. Lossless, but every preemption
      burns prefill FLOPs proportional to prompt+progress.

  swap-to-host       — ``EngineConfig(swap="host")``: the victim's pages
      (KV + recurrent stream state + sampling rows) move to a HostPagePool
      and resume is a device scatter. Same token streams (test invariant:
      tests/test_swap.py), zero recomputed prefill tokens while the host
      pool has room.

Both run under the SAME calibrated virtual-clock cost model, so otps_vt is
an honest apples-to-apples: a recompute resume advances the clock by
``prefill_cost + prefill_cost_per_token * prefix`` while a swap leg costs
``swap_cost_per_byte * bytes_moved`` (PCIe-ish: transfers are cheap
relative to recomputing a long prefix, which is exactly when swap wins —
the policy gate in scheduler._swap_beats_recompute prices this per victim).

Reported per discipline: otps_vt, recomputed prefill tokens, preemption
split (swap/recompute/drops), device-pool and host-pool peaks. PASS gates
(acceptance criteria): swap must show FEWER recomputed prefill tokens AND
otps_vt >= recompute at equal device pool bytes. Rows are persisted to
results/table19_swap.csv.
"""
import numpy as np

from benchmarks.common import (get_corpus, get_target, longtail_budgets, row,
                               train_drafter, write_results_csv)
from benchmarks.table12_paged import kv_bytes, peak_resident
from repro.serving import Engine, EngineConfig, Request, Scheduler

PAGE = 16
MAX_LEN = 128
B_SLOTS = 12         # decode slots — more than the pool can back
POOL_ROWS = 3        # device pool = 3 max_len rows' worth of pages (24)
PROMPT_LEN = 32      # long prompts: 2 pages claimed at admission

# virtual-clock calibration (both disciplines use the SAME numbers):
# recomputing one prefix token costs 0.05 iterations; moving one byte
# host<->device costs 1e-7 — a ~50 KB slot swap ≈ 0.005 vt vs 1.0 + 32 *
# 0.05 = 2.6 vt to recompute its prefill. Uncalibrated (both 0.0) the two
# disciplines tie on the clock by construction.
PREFILL_COST_PER_TOKEN = 0.05
SWAP_COST_PER_BYTE = 1e-7


def poisson_arrivals(n: int, mean_gap: float, rng) -> list:
    return np.cumsum(rng.exponential(mean_gap, size=n)).tolist()


def run(epochs=15, n_requests=24, max_new=24, mean_gap=0.5):
    arch = "qwen2-1.5b"
    tcfg, m, tparams = get_target(arch)
    dcfg, dp, _ = train_drafter("table9_peagle_" + arch, arch=arch,
                                epochs=epochs, n_layers=4, k_train=8)

    corpus = get_corpus(arch)
    rng = np.random.default_rng(19)
    rows_ = rng.choice(len(corpus), size=n_requests, replace=False)
    prompts = [np.asarray(corpus[i, :PROMPT_LEN]) for i in rows_]
    budgets = longtail_budgets(n_requests, max_new, rng)
    arrivals = poisson_arrivals(n_requests, mean_gap, rng)

    def make(swap):
        return Engine(tcfg, dcfg, tparams, dp,
                      EngineConfig(K=5, max_new_tokens=max_new,
                                   drafter_mode="parallel", max_len=MAX_LEN,
                                   kv_layout="paged", page_size=PAGE,
                                   pool_pages=POOL_ROWS * MAX_LEN // PAGE,
                                   kv_growth="incremental", swap=swap),
                      B_SLOTS)

    def reqs():
        return [Request(p, max_new_tokens=b, arrival_time=a)
                for p, b, a in zip(prompts, budgets, arrivals)]

    results, csv_rows = {}, []
    token_ref = None
    for name, swap in [("recompute", "none"), ("swap", "host")]:
        eng = make(swap)
        rep = None
        for it in range(2):                      # warm first, measure second
            rep = Scheduler(
                eng, prefill_cost_per_token=PREFILL_COST_PER_TOKEN,
                swap_cost_per_byte=SWAP_COST_PER_BYTE).serve(reqs())
            if it == 0:
                # peaks must reflect the measured pass only (device AND
                # host pool high-water marks — Engine.reset_stats)
                eng.reset_stats()
        toks = [tuple(r["tokens"]) for r in
                sorted(rep["results"], key=lambda r: r["rid"])]
        if token_ref is None:
            token_ref = toks
        else:
            assert toks == token_ref, \
                "swap discipline changed token streams (losslessness broken)"
        byt = kv_bytes(eng)
        peak = peak_resident(rep["events"])
        hp = rep["host_pool"]
        results[name] = dict(
            otps_vt=rep["otps_vt"], otps=rep["otps"],
            recomputed_prefill_tokens=rep["recomputed_prefill_tokens"],
            preemptions=rep["preemptions"],
            preempt_swap=rep["preempt_swap"],
            preempt_recompute=rep["preempt_recompute"],
            swap_drops=rep["swap_drops"],
            peak_resident=peak, kv_bytes=byt,
            peak_pages=rep["peak_pages"],
            host_peak_bytes=hp["peak_bytes"],
            p99_latency_vt=rep["p99_latency_vt"])
        csv_rows.append({"discipline": name, **results[name]})
        row(f"table19/{name}", 1e6 / max(rep["otps"], 1e-9),
            f"otps_vt={rep['otps_vt']:.2f} "
            f"recomputed_prefill_tokens={rep['recomputed_prefill_tokens']} "
            f"preempt={rep['preemptions']} "
            f"(swap={rep['preempt_swap']} recompute="
            f"{rep['preempt_recompute']} drops={rep['swap_drops']}) "
            f"peak_pages={rep['peak_pages']}/{eng.pool_pages} "
            f"host_peak={hp['peak_bytes']}B "
            f"p99_lat_vt={rep['p99_latency_vt']:.1f}")

    r_rec, r_swp = results["recompute"], results["swap"]
    fewer = (r_swp["recomputed_prefill_tokens"]
             < r_rec["recomputed_prefill_tokens"])
    faster = r_swp["otps_vt"] >= r_rec["otps_vt"]
    gain = r_swp["otps_vt"] / max(r_rec["otps_vt"], 1e-9)
    row("table19/swap_gain", gain,
        f"swap vs recompute otps_vt = {gain:.2f}x, recomputed prefill "
        f"tokens {r_swp['recomputed_prefill_tokens']} vs "
        f"{r_rec['recomputed_prefill_tokens']} at equal device pool bytes "
        f"({'PASS' if fewer and faster else 'FAIL'}: swap must recompute "
        "fewer prefill tokens AND hold otps_vt >= recompute)")
    csv_rows.append({"discipline": "swap_gain", "otps_vt": gain})
    path = write_results_csv("table19_swap.csv", csv_rows)
    print(f"# wrote {path}")
    return results


if __name__ == "__main__":
    run()
