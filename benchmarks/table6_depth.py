"""Paper Table 6 — training vs inference speculation depth (§4.4):
K_train=8 > K_infer=5 beats matched K_train=5 by ~+4%."""
from benchmarks.common import eval_engine, row, train_drafter


def run(epochs=15):
    als = {}
    for k_tr in (5, 8):
        tag = "table3_shared" if k_tr == 5 else f"table6_ktr{k_tr}"
        dcfg, dparams, _ = train_drafter(
            tag, epochs=epochs, n_layers=2, k_train=k_tr)
        r = eval_engine("qwen2-1.5b", dcfg, dparams, K=5)
        als[k_tr] = r["acceptance_length"]
    d = (als[8] - als[5]) / als[5] * 100
    row("table6/ktr5_kinf5", als[5] * 1e6, f"AL={als[5]:.3f}")
    row("table6/ktr8_kinf5", als[8] * 1e6,
        f"AL={als[8]:.3f} delta={d:+.1f}%")
    return als


if __name__ == "__main__":
    run()
