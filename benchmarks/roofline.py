"""§Roofline aggregation: reads results/dryrun/*.json (produced by
repro.launch.dryrun) and emits the per-(arch × shape × mesh) roofline table
with the three terms, dominant bottleneck, MODEL_FLOPS ratio, and memory
fit. Also prints CSV rows for benchmarks/run.py."""
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_records(variant: str = "baseline"):
    d = RESULTS if variant == "baseline" else RESULTS + "_opt"
    recs = []
    for fn in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def markdown_table(recs, mesh="16x16"):
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "bottleneck | useful FLOPs ratio | live GB/chip | fits |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r.get("status") == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skip ({r.get('reason')}) | — | — | — |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"ERROR | — | — | — |")
            continue
        t = r["roofline"]
        live = r["memory"].get("live_bytes")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.2e} | "
            f"{t['memory_s']:.2e} | {t['collective_s']:.2e} | "
            f"{t['bottleneck'].replace('_s','')} | "
            f"{t.get('useful_flops_ratio', 0):.3f} | "
            f"{(live or 0) / 1e9:.2f} | "
            f"{'y' if r['memory'].get('fits_16GB') else 'N'} |")
    return "\n".join(lines)


def run():
    out = {}
    for variant in ("baseline", "optimized"):
        recs = load_records(variant)
        if not recs:
            continue
        ok = [r for r in recs if r.get("status") == "ok"]
        skip = [r for r in recs if r.get("status") == "skip"]
        err = [r for r in recs if r.get("status") == "error"]
        print(f"roofline/{variant}/records,{len(recs)},ok={len(ok)} "
              f"skip={len(skip)} err={len(err)}")
        for r in ok:
            t = r["roofline"]
            print(f"roofline/{variant}/{r['arch']}__{r['shape']}__{r['mesh']},"
                  f"{max(t['compute_s'], t['memory_s'], t['collective_s']) * 1e6:.1f},"
                  f"bottleneck={t['bottleneck']} "
                  f"c={t['compute_s']:.2e} m={t['memory_s']:.2e} "
                  f"x={t['collective_s']:.2e}")
        out[variant] = recs
    return out


if __name__ == "__main__":
    print(markdown_table(load_records()))
