"""Paper Table 7 — extended training duration (§4.5): parallel drafting
keeps improving with epochs (harder attention-based learning problem)."""
from benchmarks.common import eval_engine, row, train_drafter


def run(stages=(6, 14, 22)):
    als = {}
    for ep in stages:
        tag = "table3_shared" if ep == 22 else f"table7_ep{ep}"
        dcfg, dparams, _ = train_drafter(
            tag, epochs=ep, n_layers=2, k_train=5)
        r = eval_engine("qwen2-1.5b", dcfg, dparams, K=5)
        als[ep] = r["acceptance_length"]
    base = als[stages[0]]
    for ep, al in als.items():
        row(f"table7/epochs_{ep}", al * 1e6,
            f"AL={al:.3f} delta={(al - base) / base * 100:+.1f}%")
    return als


if __name__ == "__main__":
    run()
