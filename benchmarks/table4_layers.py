"""Paper Table 4 — drafter depth ablation (§4.2): 1 vs 2 vs 4 layers.
The paper reports +33% (1→2) and +46% (1→4) acceptance length."""
from benchmarks.common import eval_engine, row, train_drafter


def run(epochs=15):
    als = {}
    for n_layers in (1, 2, 4):
        tag = "table3_shared" if n_layers == 2 else f"table4_L{n_layers}"
        dcfg, dparams, _ = train_drafter(
            tag, epochs=epochs, n_layers=n_layers, k_train=5)
        r = eval_engine("qwen2-1.5b", dcfg, dparams, K=5)
        als[n_layers] = r["acceptance_length"]
    base = als[1]
    for L, al in als.items():
        row(f"table4/layers_{L}", al * 1e6,
            f"AL={al:.3f} delta={(al - base) / base * 100:+.1f}%")
    return als


if __name__ == "__main__":
    run()
