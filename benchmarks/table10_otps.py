"""Paper Table 10 — end-to-end output tokens/s (OTPS) across speculation
depths K ∈ {3,5,7} and concurrency C ∈ {2,4}, AR EAGLE-3 vs P-EAGLE, plus
the vanilla (no-spec) floor.

The paper's headline mechanism must reproduce on CPU: AR drafting costs K
sequential drafter forwards per iteration, P-EAGLE one; so P-EAGLE's OTPS
advantage *grows with K* while AR peaks at small K. Absolute OTPS is
CPU-scale; the K-shape and the AR/P-EAGLE ordering are the claims."""
from benchmarks.common import eval_engine, row, train_drafter


def run(epochs=15, Ks=(3, 5, 7), Cs=(2, 4)):
    arch = "qwen2-1.5b"
    dcfg_ar, dp_ar, _ = train_drafter(
        "table9_ar_" + arch, arch=arch, epochs=epochs, n_layers=1, parallel=False,
        ttt_steps=2, hca=True, k_train=1, cod_rate=0.99)
    dcfg_p, dp_p, _ = train_drafter(
        "table9_peagle_" + arch, arch=arch, epochs=epochs, n_layers=4, k_train=8)

    results = {}
    for C in Cs:
        r0 = eval_engine(arch, None, None, K=0, mode="none", batch=C,
                         max_new=24)
        row(f"table10/vanilla_C{C}", 1e6 / max(r0["otps"], 1e-9),
            f"OTPS={r0['otps']:.1f}")
        for K in Ks:
            r_ar = eval_engine(arch, dcfg_ar, dp_ar, K=K, mode="ar",
                               batch=C, max_new=24)
            r_p = eval_engine(arch, dcfg_p, dp_p, K=K, mode="parallel",
                              batch=C, max_new=24)
            sp = r_p["otps"] / max(r_ar["otps"], 1e-9)
            row(f"table10/ar_K{K}_C{C}", 1e6 / max(r_ar["otps"], 1e-9),
                f"OTPS={r_ar['otps']:.1f} AL={r_ar['acceptance_length']:.2f}")
            row(f"table10/peagle_K{K}_C{C}", 1e6 / max(r_p["otps"], 1e-9),
                f"OTPS={r_p['otps']:.1f} AL={r_p['acceptance_length']:.2f} "
                f"speedup={sp:.2f}x")
            results[(K, C)] = (r_ar["otps"], r_p["otps"], sp)
    return results


if __name__ == "__main__":
    run()
