"""Paper Table 2 — training overhead of mask construction.

Directly reproducible at the paper's REAL size (n=2048, K=8) on CPU:
  - PARD-style per-example mask construction (multiple O(M²) passes),
  - ours/paper: one-time precompute + per-example gather,
  - ours/TPU: closed-form predicate (zero per-example mask work; the cost
    moves into the attention kernel where the mask is computed from O(M)
    metadata — measured here as predicate evaluation on one block).

Paper reports 718.5s vs 17.5s to load 128 examples (41x). We report the
same 128-example data-loading time for each method.
"""
import time

import numpy as np

from benchmarks.common import row
from repro.core import cod, masks


def run(n=2048, K=8, r=0.8, examples=16, full_examples=128):
    rng = np.random.default_rng(0)
    samples = [cod.sample_cod(rng, n, K, r) for _ in range(examples)]
    M = len(samples[0][0])

    # --- PARD-style: rebuild per example --------------------------------
    t0 = time.perf_counter()
    for pos, depth in samples:
        masks.pard_style_mask(pos, depth)
    t_pard = (time.perf_counter() - t0) / examples * full_examples

    # --- paper: precompute once + gather per example --------------------
    t0 = time.perf_counter()
    full = masks.precompute_full_mask(n, K)
    t_pre = time.perf_counter() - t0
    t0 = time.perf_counter()
    for pos, depth in samples:
        masks.extract_mask(full, pos, depth, K)
    t_ours = (time.perf_counter() - t0) / examples * full_examples

    # --- paper, non-COD regime: pure top-left VIEW (Fig. 3) -------------
    t0 = time.perf_counter()
    for i in range(examples):
        m = (n - i) * K
        _ = full[:m, :m]                       # O(1) numpy view
    t_view = (time.perf_counter() - t0) / examples * full_examples

    # --- beyond-paper: closed form, no mask materialization -------------
    # per-example cost is just metadata packaging (O(M)); the predicate is
    # evaluated blockwise inside the kernel. Measure metadata prep.
    t0 = time.perf_counter()
    for pos, depth in samples:
        cod.pad_to(pos, depth, ((M + 127) // 128) * 128)
    t_closed = (time.perf_counter() - t0) / examples * full_examples

    row("table2/pard_load_128ex_s", t_pard * 1e6, f"M={M}")
    row("table2/ours_precompute_once_s", t_pre * 1e6, "amortized")
    row("table2/ours_cod_gather_128ex_s", t_ours * 1e6,
        f"speedup={t_pard / max(t_ours, 1e-9):.1f}x")
    row("table2/ours_view_slice_128ex_s", t_view * 1e6,
        f"speedup={t_pard / max(t_view, 1e-9):.0f}x (non-COD, Fig.3 view)")
    row("table2/closedform_load_128ex_s", t_closed * 1e6,
        f"speedup={t_pard / max(t_closed, 1e-9):.0f}x")
    return {"pard": t_pard, "ours": t_ours, "view": t_view,
            "closed": t_closed, "precompute": t_pre}


if __name__ == "__main__":
    run()
