"""Beyond-paper Table 13 — async arrival-time serving: incremental paged-KV
growth + lossless preemption vs PR-2's static (up-front) admission sizing.

Workload: Poisson arrivals (exponential inter-arrival gaps on the
scheduler's deterministic virtual clock) over the long-tail budget mix
(~1/4 long requests), more engine slots than the page pool could ever back
at worst case. The two disciplines, at IDENTICAL pool bytes:

  up-front (PR-2)   — admission reserves ceil((prompt+budget+overshoot)/page)
      pages for the request's whole lifetime; residency is bounded by budget
      honesty (a short answer holds a long reservation until it finishes).

  incremental+preemptive — admission claims only the prompt + one
      speculative block; ``ensure_capacity`` grows the slot page-by-page as
      it actually lengthens, and when the pool runs dry the lowest-priority
      slot is evicted (pages freed, tokens kept host-side) and later resumed
      by recompute-prefill, token-for-token losslessly (test invariant:
      tests/test_async_serving.py).

Reported per discipline: OTPS (wall), virtual-time p50/p99 end-to-end
latency and queue wait, preemption count, peak concurrently-resident
requests, and resident requests per MiB of pool — the honest residency
claim. Incremental must sustain strictly more residents per pool byte on
this mix; the summary row prints the ratio. Rows are also persisted to
results/table13_async.csv.
"""
import numpy as np

from benchmarks.common import (get_corpus, get_target, longtail_budgets, row,
                               train_drafter, write_results_csv)
from benchmarks.table12_paged import kv_bytes, peak_resident
from repro.serving import Engine, EngineConfig, Request, Scheduler

PAGE = 16
MAX_LEN = 128
B_SLOTS = 12         # decode slots — more than the pool could back at worst
POOL_ROWS = 2        # pool holds only 2 max_len rows' worth of pages (16)


def poisson_arrivals(n: int, mean_gap: float, rng) -> list:
    return np.cumsum(rng.exponential(mean_gap, size=n)).tolist()


def run(epochs=15, n_requests=24, max_new=24, mean_gap=0.5):
    arch = "qwen2-1.5b"
    tcfg, m, tparams = get_target(arch)
    dcfg, dp, _ = train_drafter("table9_peagle_" + arch, arch=arch,
                                epochs=epochs, n_layers=4, k_train=8)

    corpus = get_corpus(arch)
    rng = np.random.default_rng(13)
    rows_ = rng.choice(len(corpus), size=n_requests, replace=False)
    prompts = [np.asarray(corpus[i, :6]) for i in rows_]
    budgets = longtail_budgets(n_requests, max_new, rng)
    arrivals = poisson_arrivals(n_requests, mean_gap, rng)

    def make(kv_growth):
        return Engine(tcfg, dcfg, tparams, dp,
                      EngineConfig(K=5, max_new_tokens=max_new,
                                   drafter_mode="parallel", max_len=MAX_LEN,
                                   kv_layout="paged", page_size=PAGE,
                                   pool_pages=POOL_ROWS * MAX_LEN // PAGE,
                                   kv_growth=kv_growth), B_SLOTS)

    def reqs():
        return [Request(p, max_new_tokens=b, arrival_time=a)
                for p, b, a in zip(prompts, budgets, arrivals)]

    results, csv_rows = {}, []
    for name, growth, preempt in [("upfront", "upfront", False),
                                  ("incremental", "incremental", True)]:
        eng = make(growth)
        rep = None
        for it in range(2):                      # warm first, measure second
            rep = Scheduler(eng, preempt=preempt).serve(reqs())
            if it == 0:
                # peak_pages must reflect the measured pass only, not the
                # max across both phases (device + host pools both)
                eng.reset_stats()
        byt = kv_bytes(eng)
        peak = peak_resident(rep["events"])
        per_mib = peak / (byt / 2**20)
        results[name] = dict(
            otps=rep["otps"], peak_resident=peak, kv_bytes=byt,
            resident_per_mib=per_mib, preemptions=rep["preemptions"],
            peak_pages=eng.allocator.peak_used,
            p50_latency_vt=rep["p50_latency_vt"],
            p99_latency_vt=rep["p99_latency_vt"],
            p50_wait_vt=rep["p50_wait_vt"], p99_wait_vt=rep["p99_wait_vt"])
        csv_rows.append({"discipline": name, **results[name]})
        row(f"table13/{name}", 1e6 / max(rep["otps"], 1e-9),
            f"OTPS={rep['otps']:.1f} peak_resident={peak} "
            f"resident_per_MiB={per_mib:.2f} "
            f"peak_pages={eng.allocator.peak_used}/{eng.pool_pages} "
            f"preempt={rep['preemptions']} "
            f"p50_lat_vt={rep['p50_latency_vt']:.1f} "
            f"p99_lat_vt={rep['p99_latency_vt']:.1f} "
            f"p99_wait_vt={rep['p99_wait_vt']:.1f}")

    gain = (results["incremental"]["resident_per_mib"]
            / max(results["upfront"]["resident_per_mib"], 1e-9))
    row("table13/residency_gain", gain,
        f"incremental+preemptive vs up-front resident-requests-per-byte = "
        f"{gain:.2f}x at equal pool bytes "
        f"({'PASS' if gain > 1.0 else 'FAIL'}: must be strictly > 1 on the "
        "long-tail mix)")
    csv_rows.append({"discipline": "residency_gain",
                     "resident_per_mib": gain})
    path = write_results_csv("table13_async.csv", csv_rows)
    print(f"# wrote {path}")
    return results


if __name__ == "__main__":
    run()
