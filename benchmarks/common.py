"""Shared benchmark infrastructure.

Scaled-down regime (CPU container): reduced target configs, short
self-generated corpora (the paper's target-trace training regime), tiny
drafters. Absolute numbers differ from the paper's H200 measurements; the
*relationships* the paper claims (which variant wins, how AL moves with
layers/epochs/K_train, AR-vs-parallel OTPS crossover) are what each table
reproduces. Trained drafters are checkpoint-cached under results/bench_cache.
"""
from __future__ import annotations

import dataclasses
import os
import zlib
import time
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.checkpoint import load_pytree, save_pytree  # noqa: E402
from repro.configs import DrafterConfig, get_config  # noqa: E402
from repro.core import drafter as D  # noqa: E402
from repro.data import MTPPipeline, self_generated_corpus  # noqa: E402
from repro.models import get_model, make_extras  # noqa: E402
from repro.serving import Engine, EngineConfig  # noqa: E402
from repro.training import Trainer, TrainConfig  # noqa: E402

CACHE = os.path.join(os.path.dirname(__file__), "..", "results",
                     "bench_cache")
os.makedirs(CACHE, exist_ok=True)

SEQ_LEN = 48
N_SEQS = 128
KEY = jax.random.PRNGKey(0)


@lru_cache(maxsize=None)
def get_target(arch: str = "qwen2-1.5b"):
    tcfg = get_config(arch).reduced()
    m = get_model(tcfg)
    tparams = m.init(jax.random.fold_in(KEY, zlib.crc32(arch.encode()) % 2**31))
    return tcfg, m, tparams


@lru_cache(maxsize=None)
def get_corpus(arch: str = "qwen2-1.5b", n_seqs: int = N_SEQS,
               seq_len: int = SEQ_LEN):
    fn = os.path.join(CACHE, f"corpus_{arch}_{n_seqs}x{seq_len}.npz")
    if os.path.exists(fn):
        return np.load(fn)["corpus"]
    tcfg, m, tparams = get_target(arch)
    extras_fn = (lambda b: make_extras(tcfg, b, "prefill", KEY)) \
        if tcfg.family in ("vlm", "encdec") else None
    corpus = self_generated_corpus(m, tparams, seed=1, n_seqs=n_seqs,
                                   seq_len=seq_len, prompt_len=4, batch=16,
                                   extras_fn=extras_fn)
    np.savez(fn, corpus=corpus)
    return corpus


def train_drafter(tag: str, *, arch: str = "qwen2-1.5b", epochs: int = 30,
                  lr: float = 2e-3, batch: int = 16, segments: int = 1,
                  corpus=None, **dcfg_kw):
    """Train (or load cached) a drafter; returns (dcfg, dparams, history)."""
    tcfg, m, tparams = get_target(arch)
    dcfg = DrafterConfig(**dcfg_kw).resolve(tcfg)
    if corpus is None:
        corpus = get_corpus(arch)
    ckdir = os.path.join(CACHE, f"drafter_{arch}_{tag}")
    tmpl = D.init_params(dcfg, tcfg, KEY)
    try:
        dparams = load_pytree(tmpl, ckdir, "drafter")
        return dcfg, dparams, None
    except (FileNotFoundError, KeyError, ValueError):
        pass
    extras = (make_extras(tcfg, batch, "train", KEY)
              if tcfg.family in ("vlm", "encdec") else {})
    pipe = MTPPipeline(corpus, k_train=dcfg.k_train, cod_rate=dcfg.cod_rate,
                       batch=batch, seed=0, segments=segments)
    tr = Trainer(tcfg, dcfg, tparams,
                 TrainConfig(lr=lr, total_steps=epochs * max(
                     len(corpus) // batch, 1)), extras=extras)
    log = tr.train(pipe, epochs=epochs)
    save_pytree(tr.dparams, ckdir, "drafter", step=epochs)
    return dcfg, tr.dparams, log


def eval_engine(arch, dcfg, dparams, *, K=5, mode="parallel", batch=12,
                max_new=32, prompt_len=6, seed=5):
    """Acceptance length + OTPS on held-out in-distribution prompts
    (prefixes of fresh target-generated traces)."""
    tcfg, m, tparams = get_target(arch)
    corpus = get_corpus(arch)
    rng = np.random.default_rng(seed)
    rows = rng.choice(len(corpus), size=batch, replace=False)
    prompts = jnp.asarray(corpus[rows, :prompt_len])
    extras = (make_extras(tcfg, batch, "prefill", KEY)
              if tcfg.family in ("vlm", "encdec") else {})
    eng = Engine(tcfg, dcfg, tparams, dparams,
                 EngineConfig(K=K, max_new_tokens=max_new,
                              drafter_mode=mode, max_len=128), batch)
    r = eng.run(prompts, extras)
    # steady-state OTPS: rerun once compiled
    r = eng.run(prompts, extras)
    return r


def longtail_budgets(n_requests: int, max_new: int, rng) -> list:
    """Per-request max_new_tokens for a long-tail serving mix: ~1/4 long
    (full budget) requests, the rest short. Shared by table11 and
    examples/serve_batched.py so the example demonstrates the exact
    distribution the benchmark measures."""
    return [max_new if i % 4 == 0
            else int(rng.integers(3, max(max_new // 3, 4)))
            for i in range(n_requests)]


def timed(fn, *a, repeats=3, **k):
    fn(*a, **k)  # warmup/compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*a, **k)
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") \
            or isinstance(out, jax.Array) else None
        ts.append(time.perf_counter() - t0)
    return min(ts)


def row(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")


def write_results_csv(name: str, rows: list) -> str:
    """Persist a benchmark table under results/ (list of dicts, union of
    keys as header) so reruns have the honest numbers on record, not just
    scrollback."""
    import csv
    path = os.path.join(os.path.dirname(__file__), "..", "results", name)
    keys: list = []
    for r in rows:
        keys.extend(k for k in r if k not in keys)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys)
        w.writeheader()
        w.writerows(rows)
    return path
