"""Beyond-paper Table 12 — paged (block-table) KV cache + bucketed admission
prefill vs the contiguous per-slot layout.

Two claims, both at FIXED KV-cache memory (the paged pool holds exactly the
same number of positions as the contiguous engine's B × max_len rows):

  residency — a request claims pages for what it actually uses, not a
      max_len row, so the same bytes hold ≥2x the concurrently-resident
      requests on a long-tail mix (more slots than the contiguous engine
      could ever back). Reported as peak resident requests per MiB of KV
      cache, in BOTH growth modes: "upfront" (PR-2: reserve
      ceil((prompt+budget+overshoot)/page) for the lifetime) and the
      default "incremental" (claim the prompt + one speculative block,
      grow page-by-page as the slot lengthens — the honest numbers, since
      peak pages now track real lengths; benchmarks/table13_async.py adds
      the arrival-driven comparison with preemption).

  admission latency — per-slot admission prefills retrace per *prompt
      length* in the contiguous baseline; power-of-two bucketing compiles
      O(log2 max_len) traces, so a stream of distinct lengths admits orders
      of magnitude faster cold, and no slower once buckets are warm.

Output losslessness across layouts is a test invariant
(tests/test_serving.py::test_cross_layout_losslessness); this table is about
memory and latency only.
"""
import time

import numpy as np

from benchmarks.common import get_corpus, get_target, longtail_budgets, row, \
    train_drafter, write_results_csv
from repro.serving import Engine, EngineConfig, Request, Scheduler

PAGE = 16
MAX_LEN = 128
B_CONT = 3          # contiguous slots == pool capacity in max_len rows
B_PAGED = 9         # paged slots; the *pool* still only holds B_CONT rows


def kv_bytes(eng) -> int:
    """Bytes of KV state a blank engine holds resident: caches (pool or
    per-slot rows) + block table."""
    import jax
    state = eng.blank_state()
    leaves = jax.tree.leaves({k: v for k, v in state.items()
                              if k in ("tcache", "dcache", "block_table")})
    return sum(x.size * x.dtype.itemsize for x in leaves)


def peak_resident(events) -> int:
    """Max requests concurrently holding KV (admit → preempt/finish), from
    the scheduler's chronological virtual-time event trace — a preempted
    request holds zero pages while evicted, so it must not count. A
    swapped-out request likewise releases its exclusive pages to the host
    pool ("swap_out") and re-acquires device residency at "swap_in";
    "swap_drop" only frees host bytes, so residency is unchanged."""
    live, peak = set(), 0
    for _, kind, rid in events:
        if kind in ("admit", "swap_in"):
            live.add(rid)
        elif kind in ("preempt", "swap_out", "finish"):
            live.discard(rid)
        peak = max(peak, len(live))
    return peak


def admission_latencies(eng, lengths, vocab, seed=11):
    """Wall time of each prefill_into_slot on a blank state, one admission
    per distinct prompt length (cold = includes tracing)."""
    rng = np.random.default_rng(seed)
    state = eng.blank_state()
    out = []
    for n in lengths:
        prompt = rng.integers(1, vocab - 2, size=int(n)).astype(np.int32)
        t0 = time.perf_counter()
        state, _, _ = eng.prefill_into_slot(state, prompt, 0, max_new=8)
        out.append(time.perf_counter() - t0)
        state = eng.free_slot(state, 0)
    return out


def run(epochs=15, n_requests=24, max_new=24):
    arch = "qwen2-1.5b"
    tcfg, m, tparams = get_target(arch)
    dcfg, dp, _ = train_drafter("table9_peagle_" + arch, arch=arch,
                                epochs=epochs, n_layers=4, k_train=8)

    def make(layout, batch, bucket, pool_pages=0, kv_growth="incremental"):
        return Engine(tcfg, dcfg, tparams, dp,
                      EngineConfig(K=5, max_new_tokens=max_new,
                                   drafter_mode="parallel", max_len=MAX_LEN,
                                   kv_layout=layout, page_size=PAGE,
                                   pool_pages=pool_pages,
                                   bucket_prefill=bucket,
                                   kv_growth=kv_growth), batch)

    # ---- residency at fixed KV memory ---------------------------------
    corpus = get_corpus(arch)
    rng = np.random.default_rng(5)
    rows_ = rng.choice(len(corpus), size=n_requests, replace=False)
    prompts = [np.asarray(corpus[i, :6]) for i in rows_]
    budgets = longtail_budgets(n_requests, max_new, rng)

    cont = make("contiguous", B_CONT, False)
    paged_up = make("paged", B_PAGED, True,
                    pool_pages=B_CONT * MAX_LEN // PAGE, kv_growth="upfront")
    paged_inc = make("paged", B_PAGED, True,
                     pool_pages=B_CONT * MAX_LEN // PAGE)
    bc, bp = kv_bytes(cont), kv_bytes(paged_inc)

    results, csv_rows = {}, []
    for name, eng in [("contiguous", cont), ("paged_upfront", paged_up),
                      ("paged_incremental", paged_inc)]:
        rep = None
        # the upfront row is the PR-2 baseline: static admission, no
        # eviction (preemption is an incremental-growth mechanism)
        preempt = None if name == "paged_incremental" else False
        for it in range(2):                      # warm first, measure second
            reqs = [Request(p, max_new_tokens=b)
                    for p, b in zip(prompts, budgets)]
            rep = Scheduler(eng, sync_every=2, preempt=preempt).serve(reqs)
            if it == 0 and eng.paged:
                # peak_pages must reflect the measured pass only, not the
                # max across both phases (BlockAllocator.reset_stats)
                eng.allocator.reset_stats()
        peak = peak_resident(rep["events"])
        byt = kv_bytes(eng)
        per_mib = peak / (byt / 2**20)
        pages = (f" peak_pages={eng.allocator.peak_used}/{eng.pool_pages}"
                 if eng.paged else "")
        results[name] = (peak, byt, rep["otps"])
        csv_rows.append(dict(
            layout=name, otps=round(rep["otps"], 2), peak_resident=peak,
            kv_bytes=byt, resident_per_mib=round(per_mib, 3),
            peak_pages=eng.allocator.peak_used if eng.paged else "",
            preemptions=rep["preemptions"]))
        row(f"table12/{name}", 1e6 / max(rep["otps"], 1e-9),
            f"OTPS={rep['otps']:.1f} peak_resident={peak} "
            f"kv_bytes={byt} resident_per_MiB={per_mib:.2f}{pages}")
    gain = (results["paged_incremental"][0] / results["paged_incremental"][1]
            ) / (results["contiguous"][0] / results["contiguous"][1])
    row("table12/residency_gain", gain,
        f"paged(incremental) vs contiguous resident-requests-per-byte = "
        f"{gain:.2f}x (pool bytes {bp} vs {bc})")
    csv_rows.append(dict(layout="residency_gain",
                         resident_per_mib=round(gain, 3)))
    print(f"# wrote {write_results_csv('table12_paged.csv', csv_rows)}")

    # ---- admission-prefill latency -----------------------------------
    # cold: a stream of distinct prompt lengths (every length is new — the
    #   realistic long-tail arrival pattern; contiguous retraces per length,
    #   buckets compile O(log2 max_len) times total).
    # warm: the same lengths re-admitted (min of 3 passes, CPU noise). Off-
    #   bucket lengths pay the pad tax — a <=2x-FLOPs forward, invisible on
    #   launch-bound accelerators but measurable on CPU.
    # aligned: warm pass at power-of-two lengths, where padding is a no-op
    #   and the bucketed trace does identical work to the exact one.
    lengths = list(range(3, 19))
    rng.shuffle(lengths)
    aligned = [4, 8, 16]
    lat = {}
    for name, eng in [
            ("contiguous_exact", make("contiguous", B_CONT, False)),
            ("paged_bucketed", make("paged", B_PAGED, True,
                                    pool_pages=B_CONT * MAX_LEN // PAGE))]:
        cold = float(np.mean(admission_latencies(eng, lengths,
                                                 tcfg.vocab_size)))
        warm = min(float(np.mean(admission_latencies(
            eng, lengths, tcfg.vocab_size, seed=12 + i))) for i in range(3))
        warm_al = min(float(np.mean(admission_latencies(
            eng, aligned, tcfg.vocab_size, seed=30 + i))) for i in range(3))
        lat[name] = (cold, warm, warm_al)
        row(f"table12/admit_{name}", cold * 1e6,
            f"cold_mean_ms={cold * 1e3:.1f} warm_mean_ms={warm * 1e3:.1f} "
            f"warm_aligned_ms={warm_al * 1e3:.1f} "
            f"({len(lengths)} distinct lengths)")
    ce, pb = lat["contiguous_exact"], lat["paged_bucketed"]
    row("table12/admit_cold_speedup", ce[0] / max(pb[0], 1e-9),
        f"bucketed cold admission {ce[0] / max(pb[0], 1e-9):.1f}x faster; "
        f"warm ratio {ce[1] / max(pb[1], 1e-9):.2f}x "
        f"(aligned {ce[2] / max(pb[2], 1e-9):.2f}x)")
    return results, lat


if __name__ == "__main__":
    run()
