"""Beyond-paper Table 17 — wall-clock streaming serving front-end
(serving/streaming.AsyncEngine) under Poisson arrivals.

Where table13 drives the deterministic VIRTUAL-clock scheduler (latency in
step-cost units), this table drives the same shared loop core through the
wall-clock streaming driver: requests arrive as real asyncio submissions
spaced by exponential gaps, tokens stream back as each speculative sync
commits, and the metrics are honest wall seconds:

  TTFT   — submit() to first streamed token, per request (p50/p99);
  OTPS   — total streamed tokens / makespan;
  wait   — the engine's own health() p50/p99 admission wait.

Before reporting, every streamed sequence is asserted token-for-token
equal to the virtual-clock twin's output for the identical (prompt,
sampling, budget) workload — the driver-equivalence acceptance criterion
(tests/test_streaming.py pins it under churn and aborts; here it gates the
numbers). A second pass runs the same arrivals with an abort for every
fourth request mid-stream, reporting abort turnaround and verifying the
survivors' numbers still hold. Rows go to results/table17_streaming.csv.
"""
import asyncio
import time

import numpy as np

from benchmarks.common import (get_corpus, get_target, longtail_budgets, row,
                               train_drafter, write_results_csv)
from repro.serving import (AsyncEngine, Engine, EngineConfig, SamplingParams,
                           virtual_twin_report)

PAGE = 16
MAX_LEN = 128
B_SLOTS = 8


def run(epochs=15, n_requests=16, max_new=24, mean_gap_s=0.05):
    arch = "qwen2-1.5b"
    tcfg, m, tparams = get_target(arch)
    dcfg, dp, _ = train_drafter("table9_peagle_" + arch, arch=arch,
                                epochs=epochs, n_layers=4, k_train=8)

    corpus = get_corpus(arch)
    rng = np.random.default_rng(17)
    rows_ = rng.choice(len(corpus), size=n_requests, replace=False)
    prompts = [np.asarray(corpus[i, :6]) for i in rows_]
    budgets = longtail_budgets(n_requests, max_new, rng)
    sps = [None if i % 2 == 0
           else SamplingParams(temperature=0.8, seed=100 + i)
           for i in range(n_requests)]
    gaps = rng.exponential(mean_gap_s, size=n_requests)
    workload = list(zip(prompts, sps, budgets))

    def make():
        return Engine(tcfg, dcfg, tparams, dp,
                      EngineConfig(K=5, max_new_tokens=max_new,
                                   drafter_mode="parallel", max_len=MAX_LEN,
                                   kv_layout="paged", page_size=PAGE,
                                   pool_pages=0, kv_growth="incremental"),
                      B_SLOTS)

    eng = make()
    # deterministic reference + jit warmup in one move
    twin = virtual_twin_report(eng, workload)

    async def drive(abort_every=None):
        aeng = AsyncEngine(eng, max_pending=2 * B_SLOTS)
        t0 = time.perf_counter()
        ttft = [None] * n_requests
        tabort = []
        streams = [None] * n_requests

        async def one(i):
            await asyncio.sleep(float(np.sum(gaps[:i + 1])))
            p, sp, b = workload[i]
            t_sub = time.perf_counter()
            handle = await aeng.submit(p, sp, max_new_tokens=b)
            out = []
            async for tok, _ in handle:
                if not out:
                    ttft[i] = time.perf_counter() - t_sub
                out.append(tok)
                if abort_every and i % abort_every == 0 and len(out) == 2:
                    ta = time.perf_counter()
                    handle.abort()
                    tabort.append(time.perf_counter() - ta)
            streams[i] = (out, handle.aborted)

        await asyncio.gather(*(one(i) for i in range(n_requests)))
        health = aeng.health()
        rep = await aeng.close()
        return dict(streams=streams, ttft=ttft, tabort=tabort,
                    makespan=time.perf_counter() - t0, health=health,
                    rep=rep)

    csv_rows = []
    for name, abort_every in [("streamed", None), ("with_aborts", 4)]:
        out = asyncio.run(drive(abort_every))
        # driver-equivalence gate: streamed == virtual twin, survivors
        # exactly, aborted prefixes exactly
        for (got, aborted), ref in zip(out["streams"], twin["results"]):
            full = ref["tokens"].tolist()
            want = full[:len(got)] if aborted else full
            assert got == want, "streamed output diverged from the twin"
        n_aborted = sum(ab for _, ab in out["streams"])
        toks = sum(len(g) for g, _ in out["streams"])
        ttfts = sorted(t for t in out["ttft"] if t is not None)
        pct = lambda p: ttfts[min(int(p / 100 * len(ttfts)),
                                  len(ttfts) - 1)]
        otps = toks / max(out["makespan"], 1e-9)
        r = dict(mode=name, otps_wall=otps, total_tokens=toks,
                 makespan_s=out["makespan"], n_aborted=n_aborted,
                 p50_ttft_s=pct(50), p99_ttft_s=pct(99),
                 p50_wait_s=out["health"]["p50_wait_s"],
                 p99_wait_s=out["health"]["p99_wait_s"],
                 preemptions=out["rep"]["preemptions"],
                 mean_abort_turnaround_s=(float(np.mean(out["tabort"]))
                                          if out["tabort"] else 0.0))
        csv_rows.append(r)
        row(f"table17/{name}", 1e6 / max(otps, 1e-9),
            f"OTPS_wall={otps:.1f} p50_TTFT={r['p50_ttft_s'] * 1e3:.0f}ms "
            f"p99_TTFT={r['p99_ttft_s'] * 1e3:.0f}ms "
            f"p99_wait={r['p99_wait_s'] * 1e3:.0f}ms "
            f"aborted={n_aborted} preempt={r['preemptions']} "
            f"twin_equal=PASS")
    path = write_results_csv("table17_streaming.csv", csv_rows)
    print(f"# wrote {path}")
    return csv_rows


if __name__ == "__main__":
    run()
