"""Beyond-paper Table 16 — cross-request prefix caching on a shared-preamble
workload (serving/prefix_cache.py) vs the cache-off paged engine of
tables 12/13.

Workload: every request shares a long preamble (system prompt / few-shot
header — the dominant serving-scale shape) followed by a distinct tail.
With the cache on, admission hash-cons-matches the preamble's full pages and
maps them into the request's block-table row, prefilling only the tail; with
it off every admission recomputes the whole prompt. Both engines run at
IDENTICAL pool bytes. Two claims:

  admission latency — a hit admission forwards only the uncached suffix
      (here a few tokens instead of the whole preamble), so warm admission
      latency drops roughly with the hit fraction of the prompt.

  residency — shared preamble pages are resident ONCE for the whole cohort
      instead of once per request, so the same pool bytes back strictly
      more concurrently-resident requests (and peak page demand falls).
      Reported as peak resident requests per MiB of pool, like tables
      12/13, with ``BlockAllocator.reset_stats()`` between the warm-up and
      measured phases.

Losslessness is a test invariant (tests/test_prefix_cache.py::
test_cache_hit_losslessness — hit == cold prefill token-for-token); this
table still cross-checks the two engines' streams and reports hit stats
(requests hit, prompt tokens served from cache). Rows are persisted to
results/table16_prefix.csv.
"""
import time

import numpy as np

from benchmarks.common import (get_corpus, get_target, longtail_budgets, row,
                               train_drafter, write_results_csv)
from benchmarks.table12_paged import kv_bytes, peak_resident
from repro.serving import Engine, EngineConfig, Request, Scheduler

PAGE = 16
MAX_LEN = 128
B_SLOTS = 8
POOL_ROWS = 3        # pool holds 3 max_len rows' worth of pages (24)
PRE_LEN = 48         # shared preamble: 3 full pages of every prompt
TAIL_LEN = 6


def shared_preamble_prompts(corpus, n_requests, rng):
    """Prompts = one fixed PRE_LEN-token preamble + per-request TAIL_LEN
    distinct tails, both drawn from the benchmark corpus. Drawn ONCE per
    run — every engine and phase must serve the identical workload."""
    pre = np.asarray(corpus[0, :PRE_LEN], np.int32)
    rows_ = rng.choice(np.arange(1, len(corpus)), size=n_requests,
                       replace=False)
    return [np.concatenate([pre, np.asarray(corpus[i, :TAIL_LEN], np.int32)])
            for i in rows_]


def admission_latency_sweep(eng, prompts, max_new=8):
    """Wall time of each prefill_into_slot, serially through slot 0 (the
    cache — when enabled — is warm from the first admission on)."""
    state = eng.serve_state()
    out = []
    for p in prompts:
        t0 = time.perf_counter()
        state, _, _ = eng.prefill_into_slot(state, p, 0, max_new=max_new)
        out.append(time.perf_counter() - t0)
        state = eng.free_slot(state, 0, final_tokens=p)
    eng.retain_state(state)
    return out


def run(epochs=15, n_requests=16, max_new=24):
    arch = "qwen2-1.5b"
    tcfg, m, tparams = get_target(arch)
    dcfg, dp, _ = train_drafter("table9_peagle_" + arch, arch=arch,
                                epochs=epochs, n_layers=4, k_train=8)
    corpus = get_corpus(arch)
    rng = np.random.default_rng(16)
    budgets = longtail_budgets(n_requests, max_new, rng)
    prompts = shared_preamble_prompts(corpus, n_requests, rng)

    def make_requests():          # fresh Request objects, same workload
        return [Request(p, max_new_tokens=b)
                for p, b in zip(prompts, budgets)]

    def make(prefix_cache):
        return Engine(tcfg, dcfg, tparams, dp,
                      EngineConfig(K=5, max_new_tokens=max_new,
                                   drafter_mode="parallel", max_len=MAX_LEN,
                                   kv_layout="paged", page_size=PAGE,
                                   pool_pages=POOL_ROWS * MAX_LEN // PAGE,
                                   kv_growth="incremental",
                                   prefix_cache=prefix_cache), B_SLOTS)

    # ---- residency + hit stats at fixed pool bytes ---------------------
    results, csv_rows, streams = {}, [], {}
    for name, cached in [("cache_off", False), ("cache_on", True)]:
        eng = make(cached)
        rep = None
        for it in range(2):                      # warm first, measure second
            rep = Scheduler(eng).serve(make_requests())
            if it == 0:
                eng.allocator.reset_stats()      # measured-phase peak only
        byt = kv_bytes(eng)
        peak = peak_resident(rep["events"])
        per_mib = peak / (byt / 2**20)
        streams[name] = [r["tokens"] for r in rep["results"]]
        hit_toks = rep["cache_hit_tokens"]
        prompt_toks = n_requests * (PRE_LEN + TAIL_LEN)
        results[name] = dict(
            otps=rep["otps"], peak_resident=peak, kv_bytes=byt,
            resident_per_mib=per_mib, peak_pages=eng.allocator.peak_used,
            preemptions=rep["preemptions"], hit_requests=
            rep["cache_hit_requests"], hit_tokens=hit_toks,
            hit_token_frac=hit_toks / prompt_toks)
        csv_rows.append({"config": name,
                         **{k: (round(v, 3) if isinstance(v, float) else v)
                            for k, v in results[name].items()}})
        row(f"table16/{name}", 1e6 / max(rep["otps"], 1e-9),
            f"OTPS={rep['otps']:.1f} peak_resident={peak} "
            f"resident_per_MiB={per_mib:.2f} "
            f"peak_pages={eng.allocator.peak_used}/{eng.pool_pages} "
            f"hit_requests={rep['cache_hit_requests']}/{n_requests} "
            f"hit_tokens={hit_toks} ({hit_toks / prompt_toks:.0%} of "
            "prompt tokens)")
    for a, b in zip(streams["cache_off"], streams["cache_on"]):
        np.testing.assert_array_equal(
            a, b, err_msg="cache hit diverged from cold prefill")
    gain = (results["cache_on"]["resident_per_mib"]
            / max(results["cache_off"]["resident_per_mib"], 1e-9))
    row("table16/residency_gain", gain,
        f"cache on vs off resident-requests-per-byte = {gain:.2f}x at "
        f"equal pool bytes "
        f"({'PASS' if gain > 1.0 else 'FAIL'}: shared preamble pages must "
        "be resident once, not once per request)")
    csv_rows.append({"config": "residency_gain",
                     "resident_per_mib": round(gain, 3)})

    # ---- admission latency: cold vs preamble-hit ----------------------
    # same prompt stream through both engines, warm jit caches (min of 3
    # passes); the cache-on engine serves the preamble from cached pages
    # after its first admission, so only the tail is forwarded
    lat = {}
    for name, cached in [("cache_off", False), ("cache_on", True)]:
        eng = make(cached)
        runs = [admission_latency_sweep(eng, prompts) for _ in range(3)]
        # drop each pass's first admission: cold-trace cost for cache_off,
        # the one necessarily-cold insert pass for cache_on
        lat[name] = min(float(np.mean(r[1:])) for r in runs)
        row(f"table16/admit_{name}", lat[name] * 1e6,
            f"warm_mean_ms={lat[name] * 1e3:.2f} "
            f"({n_requests - 1} admissions/pass)")
    speedup = lat["cache_off"] / max(lat["cache_on"], 1e-9)
    row("table16/admit_hit_speedup", speedup,
        f"preamble-hit admission {speedup:.2f}x faster than cold "
        f"({'PASS' if speedup > 1.0 else 'FAIL'}: hit prefills "
        f"{TAIL_LEN}/{PRE_LEN + TAIL_LEN} of the prompt)")
    csv_rows.append({"config": "admit_latency",
                     "admit_off_ms": round(lat["cache_off"] * 1e3, 3),
                     "admit_on_ms": round(lat["cache_on"] * 1e3, 3),
                     "admit_speedup": round(speedup, 3)})
    path = write_results_csv("table16_prefix.csv", csv_rows)
    print(f"# wrote {path}")
    return results, lat


if __name__ == "__main__":
    run()
