"""Paper Table 5 — frozen vs trainable embeddings (§4.3). The mask-token
embedding must learn; the paper reports +5% for unfreezing."""
from benchmarks.common import eval_engine, row, train_drafter


def run(epochs=15):
    als = {}
    for frozen in (True, False):
        tag = f"table5_frozen1" if frozen else "table3_shared"
        dcfg, dparams, _ = train_drafter(
            tag, epochs=epochs, n_layers=2, k_train=5,
            freeze_embeddings=frozen)
        r = eval_engine("qwen2-1.5b", dcfg, dparams, K=5)
        als[frozen] = r["acceptance_length"]
    d = (als[False] - als[True]) / als[True] * 100
    row("table5/frozen", als[True] * 1e6, f"AL={als[True]:.3f}")
    row("table5/trainable", als[False] * 1e6,
        f"AL={als[False]:.3f} delta={d:+.1f}%")
    return als


if __name__ == "__main__":
    run()
