"""Benchmark driver — one module per paper table + the roofline summary.

``PYTHONPATH=src python -m benchmarks.run [--tables 2,4] [--quick]``

Prints ``name,us_per_call,derived`` CSV per the repo convention. Trained
drafters are cached under results/bench_cache, so re-runs are fast.
"""
import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tables", default="all",
                    help="comma list, e.g. 2,4,10 (default: all)")
    ap.add_argument("--quick", action="store_true",
                    help="fewer epochs / smaller sweeps")
    args = ap.parse_args()

    from benchmarks import (table1_scaling, table2_overhead,
                            table3_hidden_state, table4_layers,
                            table5_embedding, table6_depth, table7_epochs,
                            table8_seqlen, table9_acceptance, table10_otps,
                            table11_continuous, table12_paged, table13_async,
                            table14_sharded, table15_sampling,
                            table16_prefix, table17_streaming,
                            table18_adaptive, table19_swap, roofline)

    epochs = 12 if args.quick else 22
    jobs = {
        "1": lambda: table1_scaling.run(),
        "2": lambda: table2_overhead.run(),
        "3": lambda: table3_hidden_state.run(epochs=epochs),
        "4": lambda: table4_layers.run(epochs=epochs),
        "5": lambda: table5_embedding.run(epochs=epochs),
        "6": lambda: table6_depth.run(epochs=epochs),
        "7": lambda: table7_epochs.run(),
        "8": lambda: table8_seqlen.run(epochs=epochs),
        "9": lambda: table9_acceptance.run(epochs=epochs),
        "10": lambda: table10_otps.run(epochs=epochs),
        "11": lambda: table11_continuous.run(epochs=epochs),
        "12": lambda: table12_paged.run(epochs=epochs),
        "13": lambda: table13_async.run(epochs=epochs),
        "14": lambda: table14_sharded.run(epochs=epochs),
        "15": lambda: table15_sampling.run(epochs=epochs),
        "16": lambda: table16_prefix.run(epochs=epochs),
        "17": lambda: table17_streaming.run(epochs=epochs),
        "18": lambda: table18_adaptive.run(epochs=epochs),
        "19": lambda: table19_swap.run(epochs=epochs),
        "roofline": lambda: roofline.run(),
    }
    wanted = list(jobs) if args.tables == "all" else [
        t.strip() for t in args.tables.split(",")]

    failures = 0
    for t in wanted:
        if t not in jobs:
            print(f"unknown table {t!r}", file=sys.stderr)
            continue
        t0 = time.time()
        print(f"# --- table {t} ---", flush=True)
        try:
            jobs[t]()
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"table{t}/FAILED,0,", flush=True)
        print(f"# table {t} done in {time.time() - t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
