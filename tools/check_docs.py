#!/usr/bin/env python
"""Docs health check, run by CI's docs job (and usable locally):

  1. every *relative* markdown link in README.md and docs/*.md resolves to
     an existing file (anchors are stripped; http(s)/mailto links skipped);
  2. every fenced ``>>>`` doctest example in docs/*.md passes under
     ``python -m doctest`` semantics.

Usage:  PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import doctest
import glob
import os
import re
import sys

ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
# [text](target) — markdown inline links; images share the syntax
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def doc_files() -> list:
    return [os.path.join(ROOT, "README.md")] + sorted(
        glob.glob(os.path.join(ROOT, "docs", "*.md")))


def check_links(path: str) -> list:
    errors = []
    text = open(path).read()
    # fenced code blocks can contain sample output that looks like links
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = os.path.normpath(
            os.path.join(os.path.dirname(path), rel))
        if not os.path.exists(resolved):
            errors.append(f"{os.path.relpath(path, ROOT)}: broken link "
                          f"-> {target}")
    return errors


def check_doctests(path: str) -> list:
    fails, _ = doctest.testfile(path, module_relative=False, verbose=False)
    return ([f"{os.path.relpath(path, ROOT)}: {fails} doctest failure(s)"]
            if fails else [])


def main() -> int:
    errors = []
    n_examples = 0
    for path in doc_files():
        errors += check_links(path)
        if os.sep + "docs" + os.sep in path:
            n_examples += len(
                doctest.DocTestParser().get_examples(open(path).read()))
            errors += check_doctests(path)
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    if not errors:
        print(f"docs OK: {len(doc_files())} files, "
              f"{n_examples} doctest examples")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
