"""Host-sync hygiene (SYNC01).

The scheduler's wall-clock contract (docs/serving.md, "The harvest
boundary") allows exactly one device->host readback point per scheduler
iteration: ``_harvest``. Every other ``np.asarray``/``jax.device_get``/
``block_until_ready`` on decode state stalls the dispatch pipeline — the
host blocks on the device stream mid-loop and speculation depth stops
hiding latency.

SYNC01 flags, inside ``src/repro/serving/`` and ``src/repro/launch/``,
any host-materializing call whose argument references decode state
(a ``state`` name, a ``*_state`` name, or a ``self._state``-style
attribute). Sanctioned sites — the harvest boundary itself, the
round-based reference scheduler's poll loop, the blocking
``Engine.run`` harness, swap-out's device_get — are grandfathered in
``tools/lint/baseline.txt`` with rationale comments, so NEW syncs fail
the lint run while the audited ones stay visible.
"""
from __future__ import annotations

import ast
from typing import List

from tools.lint.core import Finding, ParsedModule, dotted_name

SCOPES = ("src/repro/serving/", "src/repro/launch/")

SYNC_CALLS = {"numpy.asarray", "numpy.array", "jax.device_get",
              "jax.block_until_ready"}
# int(...)/float(...) of device state blocks exactly like np.asarray
CAST_CALLS = {"int", "float", "bool"}


def _references_state(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and (
                sub.id == "state" or sub.id.endswith("_state")):
            return True
        if isinstance(sub, ast.Attribute) and (
                sub.attr == "state" or sub.attr.endswith("_state")):
            return True
    return False


def check(mod: ParsedModule) -> List[Finding]:
    if not mod.relpath.startswith(SCOPES):
        return []
    out: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call) and node.args):
            continue
        target = mod.resolve(node.func)
        fname = dotted_name(node.func) or ""
        is_sync = target in SYNC_CALLS
        is_cast = fname in CAST_CALLS
        if not (is_sync or is_cast):
            continue
        if not _references_state(node.args[0]):
            continue
        if is_cast and any(isinstance(s, ast.Call)
                           for s in ast.walk(node.args[0])):
            continue    # int(np.asarray(...)) — the inner call is the sync
        label = fname or target
        out.append(mod.finding(
            "SYNC01", node,
            f"{label}(...) reads decode state back to the host outside "
            "the harvest boundary — this blocks the dispatch loop on the "
            "device stream; batch it into _harvest or baseline it with a "
            "rationale if this site IS a sanctioned boundary"))
    return out
