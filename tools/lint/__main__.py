"""``python -m tools.lint`` — run repro-lint over the tree.

Exit codes: 0 clean (modulo baseline), 1 new findings or stale baseline
entries, 2 usage error (refused path, malformed baseline).
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List

from tools.lint.core import (Finding, RefusedPath, collect_files,
                             lint_file, load_baseline, match_baseline,
                             write_baseline)
from tools.lint import surgery

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
DEFAULT_PATHS = ["src", "tools"]
DEFAULT_BASELINE = os.path.join("tools", "lint", "baseline.txt")


def main(argv: List[str] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.lint",
        description="repro-lint: AST checks for this repo's trace/PRNG/"
                    "state-surgery/sharding contracts "
                    "(docs/static-analysis.md)")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/dirs to lint (default: src tools)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline file of grandfathered findings")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring the baseline")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from current findings "
                         "(re-add rationale comments after!)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule IDs to run (default: all)")
    args = ap.parse_args(argv)

    rules = (set(r.strip() for r in args.rules.split(",") if r.strip())
             if args.rules else None)
    paths = args.paths or DEFAULT_PATHS

    try:
        files = collect_files(paths, ROOT)
    except RefusedPath as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    findings: List[Finding] = []
    for path in files:
        findings.extend(lint_file(path, ROOT, rules))
    if rules is None or "SURG01" in rules:
        findings.extend(f for f in surgery.check_repo(ROOT)
                        if rules is None or f.rule in rules)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))

    baseline_path = (args.baseline if os.path.isabs(args.baseline)
                     else os.path.join(ROOT, args.baseline))
    if args.update_baseline:
        write_baseline(baseline_path, findings)
        print(f"wrote {len(set(f.key for f in findings))} entries to "
              f"{os.path.relpath(baseline_path, ROOT)}")
        return 0

    if args.no_baseline:
        entries = []
    else:
        try:
            entries = load_baseline(baseline_path)
        except ValueError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
    new, stale = match_baseline(findings, entries)

    for f in new:
        print(f.render())
    for e in stale:
        print("stale baseline entry (no longer matches anything — delete "
              f"it): {chr(9).join(e)}")
    n_base = len(findings) - len(new)
    summary = (f"repro-lint: {len(files)} files, {len(new)} new finding(s), "
               f"{n_base} baselined, {len(stale)} stale baseline entr"
               f"{'y' if len(stale) == 1 else 'ies'}")
    print(summary, file=sys.stderr if (new or stale) else sys.stdout)
    return 1 if (new or stale) else 0


if __name__ == "__main__":
    sys.exit(main())
