"""jit sharding completeness (SHARD01).

Under a mesh, a ``jax.jit`` without explicit ``in_shardings`` /
``out_shardings`` silently falls back to GSPMD inference: the program
still runs, but layout decisions drift between entry points and the
bitwise cross-layout equivalence suite only catches it after the fact.
The engine's rule is mechanical — if a module works with a mesh, every
jit in it states its shardings (or forwards ``**jit_kwargs`` built from
them).

SHARD01 flags, inside ``src/repro/serving/`` and ``src/repro/launch/``,
any ``jax.jit(...)`` call (through aliases like ``jj = jax.jit``) with
neither ``in_shardings``/``out_shardings`` keywords nor a ``**kwargs``
forward, unless:

- the module never mentions a mesh at all (single-device helpers), or
- the call sits in the body of an ``if <...>mesh is None:`` branch —
  the engine's unsharded fallback path is explicitly mesh-free.
"""
from __future__ import annotations

import ast
from typing import List

from tools.lint.core import Finding, ParsedModule

SCOPES = ("src/repro/serving/", "src/repro/launch/")
JIT = "jax.jit"
SHARDING_KWARGS = {"in_shardings", "out_shardings"}


def _module_mentions_mesh(mod: ParsedModule) -> bool:
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Name) and "mesh" in node.id.lower():
            return True
        if isinstance(node, ast.Attribute) and "mesh" in node.attr.lower():
            return True
        if isinstance(node, ast.arg) and "mesh" in node.arg.lower():
            return True
    return False


def _is_mesh_none_test(test: ast.AST) -> bool:
    """``<anything>.mesh is None`` / ``mesh is None`` (possibly inside a
    BoolOp) — the guard that marks the unsharded fallback branch."""
    for node in ast.walk(test):
        if not isinstance(node, ast.Compare):
            continue
        if not (len(node.ops) == 1 and isinstance(node.ops[0], ast.Is)
                and isinstance(node.comparators[0], ast.Constant)
                and node.comparators[0].value is None):
            continue
        left = node.left
        name = left.attr if isinstance(left, ast.Attribute) else (
            left.id if isinstance(left, ast.Name) else "")
        if "mesh" in name.lower():
            return True
    return False


def _under_mesh_none_branch(node: ast.AST, mod: ParsedModule) -> bool:
    cur = mod.parents.get(id(node))
    child = node
    while cur is not None:
        if isinstance(cur, ast.If) and _is_mesh_none_test(cur.test):
            # only the THEN branch is the unsharded path
            if any(child is s or _contains(s, child) for s in cur.body):
                return True
        child = cur
        cur = mod.parents.get(id(cur))
    return False


def _contains(tree: ast.AST, target: ast.AST) -> bool:
    return any(sub is target for sub in ast.walk(tree))


def check(mod: ParsedModule) -> List[Finding]:
    if not mod.relpath.startswith(SCOPES):
        return []
    if not _module_mentions_mesh(mod):
        return []
    out: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call) or not mod.is_call_to(node, JIT):
            continue
        kw_names = {kw.arg for kw in node.keywords}
        if kw_names & SHARDING_KWARGS:
            continue
        if None in kw_names:        # **jit_kwargs forward
            continue
        if _under_mesh_none_branch(node, mod):
            continue
        out.append(mod.finding(
            "SHARD01", node,
            "jax.jit without explicit in_shardings/out_shardings in a "
            "mesh-aware module: GSPMD inference will pick layouts that "
            "drift between entry points — pass the specs (or **jit_kwargs "
            "carrying them), or guard the call under `if mesh is None:`"))
    return out
