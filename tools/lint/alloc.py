"""Allocator/refcount discipline (ALLOC01).

``BlockAllocator`` (src/repro/serving/paged.py) owns the free list and
per-page refcounts; prefix caching (PR 8) and swap-to-host (PR 9) both
layered lifecycles on top of its invariants (a page is either free,
owned-refcounted, or host-resident — never two at once). Any code that
reaches into ``._free`` / ``._ref`` from outside the class can violate
those states in ways the allocator's own assertions never see.

ALLOC01 flags attribute access on allocator internals (``._free``,
``._ref``, ``._free_list``, ``._refcount``, ``._refcounts``) through an
allocator-valued base — a name whose last component contains ``alloc``
(``self.allocator._free``, ``alloc._ref``) — anywhere outside a
``BlockAllocator`` class body. The base-name requirement keeps unrelated
``self._free`` attributes on other classes (the engine's jitted free fn)
out of scope; tests poking internals should suppress inline with a
comment saying what invariant they are deliberately breaking.
"""
from __future__ import annotations

import ast
from typing import List

from tools.lint.core import Finding, ParsedModule, dotted_name

INTERNALS = {"_free", "_ref", "_free_list", "_freelist", "_refcount",
             "_refcounts"}
OWNER_CLASS = "BlockAllocator"


def _allocator_base(node: ast.Attribute) -> bool:
    base = dotted_name(node.value)
    if base is None:
        return False
    return "alloc" in base.split(".")[-1].lower()


def _inside_owner(node: ast.AST, mod: ParsedModule) -> bool:
    cur = mod.parents.get(id(node))
    while cur is not None:
        if isinstance(cur, ast.ClassDef) and cur.name == OWNER_CLASS:
            return True
        cur = mod.parents.get(id(cur))
    return False


def check(mod: ParsedModule) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Attribute) or node.attr not in INTERNALS:
            continue
        if not _allocator_base(node) or _inside_owner(node, mod):
            continue
        out.append(mod.finding(
            "ALLOC01", node,
            f"direct access to allocator internal .{node.attr} outside "
            f"{OWNER_CLASS}: page lifecycle (free/owned/host-resident) is "
            "only sound through the public alloc/free/incref/refcount API"))
    return out
