"""Retrace-hazard checkers.

The engine's perf contract is ONE jit trace per entry point per layout
(pinned dynamically by the retrace-bound tests); the two mechanical ways to
break it are Python values in traced signatures and host materialization
inside traced bodies.

TRACE01  a jit-compiled function has a Python ``bool``/``str`` default
         parameter that is not marked static (``static_argnames`` /
         ``static_argnums``) nor bound by a ``functools.partial`` wrapper
         inside the ``jax.jit(...)`` call. Passing a fresh Python value
         per call retraces; unhashable values fail outright.
TRACE02  inside a jitted body: ``.item()``, ``int()``/``float()``/
         ``bool()`` of a (potentially traced) value, f-strings formatting
         non-static values, ``np.asarray``/``np.array``, ``jax.device_get``
         or ``jax.block_until_ready`` — each either forces a blocking
         host sync per trace or raises a TracerConversionError at the
         worst time. Shape arithmetic (``x.shape[0]``, ``.ndim``,
         ``len(...)``) is static and exempt.

A "jitted body" is a def decorated with ``jax.jit`` (bare or via
``functools.partial``), a def passed directly to a ``jax.jit(...)`` call
(through aliases like ``jj = jax.jit`` and the engine's ``_greedy_twins``
helper), a def whose name ends in ``_impl`` (the engine's jit-entry-point
naming convention), or ``speculative_step`` (traced from every step impl).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from tools.lint.core import Finding, ParsedModule, dotted_name

JIT = "jax.jit"
PARTIAL = "functools.partial"
# helpers that jit their first argument (possibly wrapping it in a partial)
JIT_WRAPPERS = {"_greedy_twins"}
# module-level functions that are traced from inside jitted bodies even
# though no jit call references them directly
ALWAYS_TRACED = {"speculative_step"}

SYNC_CALLS = {"numpy.asarray", "numpy.array", "jax.device_get",
              "jax.block_until_ready"}
SAFE_ATTRS = {"ndim", "size", "shape", "dtype", "itemsize", "nbytes"}


def _jit_decorated(fn, mod: ParsedModule) -> Optional[ast.Call]:
    """The decorator expression when ``fn`` is jit-decorated; a bare
    ``@jax.jit`` returns a synthetic empty Call for uniform handling."""
    for dec in fn.decorator_list:
        if mod.resolve(dec) == JIT:
            return ast.Call(func=dec, args=[], keywords=[])
        if isinstance(dec, ast.Call):
            target = mod.resolve(dec.func)
            if target == JIT:
                return dec
            if target == PARTIAL and dec.args \
                    and mod.resolve(dec.args[0]) == JIT:
                return dec
    return None


def _static_names(call: ast.Call, fn) -> Set[str]:
    """Parameter names the jit call marks static."""
    out: Set[str] = set()
    params = [a.arg for a in fn.args.args]
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for c in ast.walk(kw.value):
                if isinstance(c, ast.Constant) and isinstance(c.value, str):
                    out.add(c.value)
        elif kw.arg == "static_argnums":
            for c in ast.walk(kw.value):
                if isinstance(c, ast.Constant) and isinstance(c.value, int):
                    if 0 <= c.value < len(params):
                        out.add(params[c.value])
    return out


def _local_defs(mod: ParsedModule) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in ast.walk(mod.tree)
            if isinstance(n, ast.FunctionDef)}


def _jitted_defs(mod: ParsedModule) -> Dict[str, ast.Call]:
    """name -> the jit/partial call that compiles it (or a synthetic empty
    call when only the convention says it's traced)."""
    empty = ast.Call(func=ast.Name(id="jit"), args=[], keywords=[])
    defs = _local_defs(mod)
    out: Dict[str, ast.Call] = {}
    for name, fn in defs.items():
        dec = _jit_decorated(fn, mod)
        if dec is not None:
            out[name] = dec
        elif name.endswith("_impl") or name in ALWAYS_TRACED:
            out[name] = empty
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call) and node.args):
            continue
        target = mod.resolve(node.func)
        fname = dotted_name(node.func) or ""
        is_jit = target == JIT
        is_wrapper = fname.split(".")[-1] in JIT_WRAPPERS
        if not (is_jit or is_wrapper):
            continue
        arg = node.args[0]
        # unwrap functools.partial(fn, bound=...) around the jitted def
        if isinstance(arg, ast.Call) and mod.resolve(arg.func) == PARTIAL \
                and arg.args:
            arg = arg.args[0]
        name = (dotted_name(arg) or "").split(".")[-1]
        if name in defs:
            out[name] = node if is_jit else empty
    return out


def _partial_bound_names(mod: ParsedModule) -> Set[str]:
    """Kwarg names bound by any ``jax.jit(functools.partial(fn, kw=...))``
    in the module. Treated as static for every jitted def here: the
    engine's ``_greedy_twins`` binds ``greedy_only`` via partial inside
    the helper, so the binding isn't visible at the ``_greedy_twins(
    self._step_impl)`` call sites — a module-wide name set is the
    conservative way to honor it without interprocedural analysis."""
    bound: Set[str] = set()
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call) and node.args
                and mod.resolve(node.func) == JIT):
            continue
        arg = node.args[0]
        if isinstance(arg, ast.Call) and mod.resolve(arg.func) == PARTIAL:
            bound.update(kw.arg for kw in arg.keywords if kw.arg)
    return bound


def _check_static_args(mod: ParsedModule) -> List[Finding]:
    out: List[Finding] = []
    defs = _local_defs(mod)
    jitted = _jitted_defs(mod)
    module_bound = _partial_bound_names(mod)
    for name, fn in defs.items():
        call = jitted.get(name)
        if call is None:
            continue
        statics = _static_names(call, fn) | module_bound
        args = fn.args
        defaults = args.defaults
        params = args.args[len(args.args) - len(defaults):]
        for p, d in zip(params, defaults):
            if not (isinstance(d, ast.Constant)
                    and isinstance(d.value, (bool, str))):
                continue
            if p.arg in statics or p.arg == "self":
                continue
            out.append(mod.finding(
                "TRACE01", p,
                f"jitted function {name!r} takes Python "
                f"{type(d.value).__name__} parameter {p.arg!r} without "
                "marking it static — every distinct value retraces "
                "(add static_argnames or bind it with functools.partial)"))
        for p, d in zip(args.kwonlyargs, args.kw_defaults):
            if d is None or not (isinstance(d, ast.Constant)
                                 and isinstance(d.value, (bool, str))):
                continue
            if p.arg in statics:
                continue
            out.append(mod.finding(
                "TRACE01", p,
                f"jitted function {name!r} takes Python "
                f"{type(d.value).__name__} parameter {p.arg!r} without "
                "marking it static — every distinct value retraces "
                "(add static_argnames or bind it with functools.partial)"))
    return out


# ---------------------------------------------------------------------------
# TRACE02 — host materialization inside jitted bodies
# ---------------------------------------------------------------------------

def _is_safe(node: ast.AST, depth: int = 0) -> bool:
    """Statically-known-at-trace-time expressions: constants, shape/ndim
    arithmetic, len(). Conservative — anything else is assumed traced."""
    if depth > 8:
        return False
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Attribute) and node.attr in SAFE_ATTRS:
        return True
    if isinstance(node, ast.Subscript):
        return _is_safe(node.value, depth + 1)
    if isinstance(node, ast.Call):
        fname = dotted_name(node.func) or ""
        if fname == "len":
            return True
        if fname.split(".")[-1] in ("prod", "ceil", "floor", "log2",
                                    "max", "min"):
            return all(_is_safe(a, depth + 1) for a in node.args)
        return False
    if isinstance(node, ast.BinOp):
        return _is_safe(node.left, depth + 1) and _is_safe(node.right,
                                                           depth + 1)
    if isinstance(node, ast.UnaryOp):
        return _is_safe(node.operand, depth + 1)
    if isinstance(node, (ast.Tuple, ast.List)):
        return all(_is_safe(e, depth + 1) for e in node.elts)
    return False


def _check_jitted_bodies(mod: ParsedModule) -> List[Finding]:
    out: List[Finding] = []
    defs = _local_defs(mod)
    jitted = _jitted_defs(mod)
    for name, fn in defs.items():
        if name not in jitted:
            continue
        for node in ast.walk(fn):
            # nested defs inside a jitted body are traced too — keep them
            if isinstance(node, ast.Call):
                target = mod.resolve(node.func)
                if target in SYNC_CALLS:
                    out.append(mod.finding(
                        "TRACE02", node,
                        f"{(dotted_name(node.func) or target)} inside "
                        f"jitted body {name!r}: forces a host sync or "
                        "TracerConversionError at trace time"))
                    continue
                if isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "item":
                    out.append(mod.finding(
                        "TRACE02", node,
                        f".item() inside jitted body {name!r}: "
                        "concretizes a traced value"))
                    continue
                fname = dotted_name(node.func) or ""
                if fname in ("int", "float", "bool") and node.args \
                        and not _is_safe(node.args[0]):
                    out.append(mod.finding(
                        "TRACE02", node,
                        f"{fname}() of a traced value inside jitted body "
                        f"{name!r}: concretizes at trace time — use "
                        "jnp casts/asarray, or hoist to the host caller"))
            elif isinstance(node, ast.JoinedStr):
                dynamic = [v for v in node.values
                           if isinstance(v, ast.FormattedValue)
                           and not _is_safe(v.value)]
                if dynamic:
                    out.append(mod.finding(
                        "TRACE02", node,
                        f"f-string formats a traced value inside jitted "
                        f"body {name!r}: formatting concretizes — build "
                        "messages from static shapes only"))
    return out


def check(mod: ParsedModule) -> List[Finding]:
    return _check_static_args(mod) + _check_jitted_bodies(mod)
