"""repro-lint: repo-specific static analysis for this codebase's contracts.

The serving stack's guarantees — bitwise losslessness across layouts / mesh /
preempt-resume, bounded jit retraces, pure ``fold_in`` counter PRNG streams —
are enforced dynamically by the property suites, which fire only *after* a
violation lands. The failure modes are mechanical and statically detectable,
so this package encodes them as AST checkers (stdlib ``ast`` only, no deps):

==========  ===============================================================
rule        contract
==========  ===============================================================
PRNG01      no split-and-carried key streams (``key, sub = split(key)``)
PRNG02      a consumed PRNG key is never passed to two draw calls
PRNG03      serving-side key streams derive through a salted ``fold_in``
SURG01      every decode-state leaf is handled by each surgery surface
TRACE01     Python bool/str args of jitted functions are marked static
TRACE02     no host materialization (.item/int/f-string/np) in jitted bodies
SYNC01      no device-state host syncs outside the harvest boundary
SHARD01     serving/launch jits pass explicit shardings when a mesh exists
ALLOC01     no BlockAllocator internals (`_free`/`_ref`) touched outside it
==========  ===============================================================

Run ``python -m tools.lint`` from the repo root (CI's ``lint`` job does).
Suppress a finding inline with ``# repro-lint: disable=RULE[,RULE2]`` on the
offending line (or the line above it); grandfathered findings live in
``tools/lint/baseline.txt``. See docs/static-analysis.md for the catalog.
"""
from tools.lint.core import (Finding, collect_files, lint_file, lint_source,
                             load_baseline, match_baseline, write_baseline)

__all__ = ["Finding", "collect_files", "lint_file", "lint_source",
           "load_baseline", "match_baseline", "write_baseline"]
