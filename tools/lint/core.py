"""repro-lint plumbing: parsed-module model, suppressions, baseline, runner.

Checkers (tools/lint/{prng,trace,hostsync,shardmesh,alloc}.py) are per-file
AST passes fed a :class:`ParsedModule`; the state-surgery checker
(tools/lint/surgery.py) is repo-level and cross-references files. Everything
here is stdlib-only by design — the lint job must run before dependencies
are even importable.
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*disable=([A-Z0-9_,\s]+)")
SUPPRESS_FILE_RE = re.compile(r"#\s*repro-lint:\s*disable-file=([A-Z0-9_,\s]+)")

SKIP_DIRS = {"__pycache__", ".git", ".venv", "node_modules", ".pytest_cache",
             "results"}


class RefusedPath(Exception):
    """An explicitly passed path the linter refuses to scan (compiled
    artifacts: ``__pycache__`` directories, ``*.pyc`` files)."""


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str               # repo-relative, forward slashes
    line: int
    col: int
    qualname: str           # enclosing def/class chain, "<module>" at top
    message: str
    snippet: str = ""       # whitespace-normalized source line

    @property
    def key(self) -> Tuple[str, str, str, str]:
        """Baseline identity: line numbers are deliberately excluded so
        unrelated edits above a grandfathered finding don't invalidate it."""
        return (self.rule, self.path, self.qualname, self.snippet)

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"[{self.qualname}] {self.message}")


def dotted_name(node: ast.AST) -> Optional[str]:
    """``jax.random.split`` from an Attribute chain / Name, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class ImportMap:
    """Local name -> canonical dotted path, from imports plus simple
    module/function-level aliases (``jj = jax.jit``)."""

    def __init__(self, tree: ast.Module):
        self.table: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.table[a.asname or a.name.split(".")[0]] = \
                        a.name if a.asname else a.name.split(".")[0]
            elif isinstance(node, ast.ImportFrom) and node.module \
                    and node.level == 0:
                for a in node.names:
                    if a.name != "*":
                        self.table[a.asname or a.name] = \
                            f"{node.module}.{a.name}"
        # aliases: one pass after imports so `jj = jax.jit` resolves
        for node in ast.walk(tree):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                cand = self.resolve(node.value)
                if cand and cand.split(".")[0] in ("jax", "numpy",
                                                   "functools"):
                    self.table[node.targets[0].id] = cand

    def resolve(self, node: ast.AST) -> Optional[str]:
        name = dotted_name(node)
        if name is None:
            return None
        head, _, rest = name.partition(".")
        head = self.table.get(head, head)
        return f"{head}.{rest}" if rest else head


@dataclass
class ParsedModule:
    """One source file plus the derived maps every checker needs."""
    path: str                       # absolute
    relpath: str                    # repo-relative, forward slashes
    tree: ast.Module = None
    lines: List[str] = field(default_factory=list)
    imports: ImportMap = None
    parents: Dict[int, ast.AST] = field(default_factory=dict)
    quals: Dict[int, str] = field(default_factory=dict)

    @classmethod
    def parse(cls, src: str, path: str, relpath: str) -> "ParsedModule":
        tree = ast.parse(src, filename=relpath)
        mod = cls(path=path, relpath=relpath, tree=tree,
                  lines=src.splitlines(), imports=ImportMap(tree))
        mod._annotate(tree, None, "<module>")
        return mod

    def _annotate(self, node: ast.AST, parent, qual: str) -> None:
        self.parents[id(node)] = parent
        self.quals[id(node)] = qual
        for child in ast.iter_child_nodes(node):
            q = qual
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                q = child.name if qual == "<module>" \
                    else f"{qual}.{child.name}"
            self._annotate(child, node, q)

    def qualname(self, node: ast.AST) -> str:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            parent = self.parents.get(id(node))
            return self.quals.get(id(parent), "<module>") \
                if parent is not None else "<module>"
        return self.quals.get(id(node), "<module>")

    def resolve(self, node: ast.AST) -> Optional[str]:
        return self.imports.resolve(node)

    def is_call_to(self, node: ast.Call, canonical: str) -> bool:
        return self.resolve(node.func) == canonical

    def source_line(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return " ".join(self.lines[lineno - 1].split())
        return ""

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        return Finding(rule=rule, path=self.relpath, line=line,
                       col=getattr(node, "col_offset", 0) + 1,
                       qualname=self.quals.get(id(node), "<module>"),
                       message=message, snippet=self.source_line(line))

    # -- suppression comments ------------------------------------------
    def suppressed_rules(self, lineno: int) -> Set[str]:
        rules = set(self._file_suppressions())
        for ln in (lineno, lineno - 1):
            if 1 <= ln <= len(self.lines):
                text = self.lines[ln - 1]
                if ln != lineno and not text.lstrip().startswith("#"):
                    continue            # previous line counts only if pure
                m = SUPPRESS_RE.search(text)
                if m:
                    rules.update(r.strip() for r in m.group(1).split(","))
        return rules

    def _file_suppressions(self) -> Set[str]:
        out: Set[str] = set()
        for text in self.lines:
            m = SUPPRESS_FILE_RE.search(text)
            if m:
                out.update(r.strip() for r in m.group(1).split(","))
        return out


# ---------------------------------------------------------------------------
# file collection
# ---------------------------------------------------------------------------

def collect_files(paths: Sequence[str], root: str) -> List[str]:
    """Expand ``paths`` (relative to ``root``) into a sorted list of .py
    files. Skips ``__pycache__`` and friends while walking; REFUSES paths
    that explicitly name compiled artifacts — linting a stale .pyc (or a
    directory of them) silently checks code that is not the source tree."""
    out: Set[str] = set()
    for p in paths:
        full = p if os.path.isabs(p) else os.path.join(root, p)
        base = os.path.basename(full.rstrip(os.sep))
        if base == "__pycache__" or full.endswith((".pyc", ".pyo")):
            raise RefusedPath(
                f"refusing to scan compiled artifact {p!r} "
                "(__pycache__/*.pyc are not source)")
        if os.path.isdir(full):
            for dirpath, dirnames, filenames in os.walk(full):
                dirnames[:] = sorted(d for d in dirnames
                                     if d not in SKIP_DIRS
                                     and not d.endswith(".egg-info"))
                for fn in filenames:
                    if fn.endswith(".py"):
                        out.add(os.path.join(dirpath, fn))
        elif os.path.isfile(full):
            if not full.endswith(".py"):
                raise RefusedPath(f"not a Python source file: {p!r}")
            out.add(full)
    return sorted(out)


# ---------------------------------------------------------------------------
# running checkers
# ---------------------------------------------------------------------------

def _file_checkers():
    # imported lazily: checker modules import core for ParsedModule/Finding
    from tools.lint import alloc, hostsync, prng, shardmesh, trace
    return (prng.check, trace.check, hostsync.check, shardmesh.check,
            alloc.check)


def lint_module(mod: ParsedModule,
                rules: Optional[Set[str]] = None) -> List[Finding]:
    findings: List[Finding] = []
    for check in _file_checkers():
        findings.extend(check(mod))
    findings = [f for f in findings
                if f.rule not in mod.suppressed_rules(f.line)]
    if rules is not None:
        findings = [f for f in findings if f.rule in rules]
    return sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule))


def lint_source(src: str, relpath: str = "<fixture>.py",
                rules: Optional[Set[str]] = None) -> List[Finding]:
    """Lint a source string — the test-suite entry point."""
    return lint_module(ParsedModule.parse(src, relpath, relpath), rules)


def lint_file(path: str, root: str,
              rules: Optional[Set[str]] = None) -> List[Finding]:
    with open(path, "r", encoding="utf-8") as f:
        src = f.read()
    rel = os.path.relpath(path, root).replace(os.sep, "/")
    try:
        mod = ParsedModule.parse(src, path, rel)
    except SyntaxError as e:
        return [Finding(rule="PARSE", path=rel, line=e.lineno or 1, col=1,
                        qualname="<module>", message=f"syntax error: {e.msg}",
                        snippet="")]
    return lint_module(mod, rules)


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------

def load_baseline(path: str) -> List[Tuple[str, str, str, str]]:
    """Baseline entries are tab-separated ``rule<TAB>path<TAB>qualname<TAB>
    normalized-source-line`` — line numbers are omitted on purpose so the
    entries survive unrelated edits. ``#`` lines are rationale comments."""
    entries: List[Tuple[str, str, str, str]] = []
    if not os.path.exists(path):
        return entries
    with open(path, "r", encoding="utf-8") as f:
        for raw in f:
            line = raw.rstrip("\n")
            if not line.strip() or line.lstrip().startswith("#"):
                continue
            parts = line.split("\t")
            if len(parts) != 4:
                raise ValueError(
                    f"malformed baseline line (want 4 tab-separated "
                    f"fields): {line!r}")
            entries.append(tuple(parts))
    return entries


def match_baseline(findings: Iterable[Finding],
                   entries: Sequence[Tuple[str, str, str, str]]
                   ) -> Tuple[List[Finding],
                              List[Tuple[str, str, str, str]]]:
    """Split into (new findings, stale entries). An entry absorbs every
    finding with its key, so N identical grandfathered lines in one
    function need one entry; an entry matching nothing is STALE and fails
    the run — expired exemptions must be deleted, not accumulated."""
    keys = set(entries)
    new = [f for f in findings if f.key not in keys]
    seen = {f.key for f in findings}
    stale = [e for e in entries if e not in seen]
    return new, stale


def write_baseline(path: str, findings: Iterable[Finding]) -> None:
    with open(path, "w", encoding="utf-8") as f:
        f.write("# repro-lint baseline: grandfathered findings "
                "(tools/lint/core.py::load_baseline)\n"
                "# Regenerate with `python -m tools.lint "
                "--update-baseline`; re-add rationale comments after —\n"
                "# every entry should say WHY the site is exempt.\n")
        for fd in sorted(set(f.key for f in findings)):
            f.write("\t".join(fd) + "\n")
