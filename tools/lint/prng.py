"""PRNG discipline checkers.

The serving stack's determinism story (docs/serving.md, "Per-request
sampling") forbids the classic jax idiom ``key, sub = split(key)`` on any
stream a request's tokens depend on: a carried key makes the draw at
position p a function of *how many* splits happened before it — batch
composition, slot index, preemption count — instead of a pure counter
``fold_in(base, position)``. PR 5 designed that bug class out; these rules
keep it out.

PRNG01  split-and-carry: a ``jax.random.split`` result rebinds the very
        key it consumed (``key, sub = split(key)`` / ``self.rng, s =
        split(self.rng)``). Whitelist legitimate sites (init-time param
        derivation, training data-order streams) inline with a
        ``# repro-lint: disable=PRNG01`` comment explaining why.
PRNG02  key reuse: the same key expression passed to two consuming draw
        calls in one function — two draws from one key are correlated.
PRNG03  unsalted stream (``src/repro/serving/`` only): a ``split`` whose
        key traces back to the base/verify stream (``step_keys``,
        ``samp["key"]``, ``PRNGKey``) with no ``fold_in`` salt between.
        A new draft-style stream must fold in its own salt constant so it
        stays disjoint from the verify keys at the same position counter
        (sampling.py's ``DRAFT_SALT`` is the model).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional

from tools.lint.core import Finding, ParsedModule, dotted_name

SPLIT = "jax.random.split"
FOLD_IN = "jax.random.fold_in"
PRNGKEY = "jax.random.PRNGKey"
VMAP = "jax.vmap"

# draw calls that CONSUME a key (split/fold_in derive, they don't consume)
CONSUMERS = {f"jax.random.{n}" for n in (
    "categorical", "uniform", "normal", "bernoulli", "gumbel",
    "truncated_normal", "randint", "permutation", "choice", "exponential",
    "laplace", "rademacher")}

# functions that mint the base per-position verify stream
BASE_STREAMS = {"step_keys"}


def _norm(node: ast.AST) -> str:
    # unparse, not ast.dump: dump embeds Load/Store ctx, which would make
    # an assignment target never compare equal to the same expression read
    return ast.unparse(node)


def _split_call(node: ast.AST, mod: ParsedModule) -> Optional[ast.Call]:
    """The split call inside ``value`` — direct or through a subscript
    (``split(key)[0]`` carries just the same)."""
    if isinstance(node, ast.Subscript):
        node = node.value
    if isinstance(node, ast.Call) and mod.is_call_to(node, SPLIT):
        return node
    return None


def _check_split_carry(mod: ParsedModule) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Assign):
            continue
        call = _split_call(node.value, mod)
        if call is None or not call.args:
            continue
        key_dump = _norm(call.args[0])
        targets: List[ast.AST] = []
        for t in node.targets:
            targets.extend(t.elts if isinstance(t, ast.Tuple) else [t])
        if any(_norm(t) == key_dump for t in targets):
            out.append(mod.finding(
                "PRNG01", node,
                "split-and-carried PRNG key: the rebind makes every "
                "downstream draw depend on split order, not a position "
                "counter — derive per-use keys with "
                "fold_in(base, counter) instead"))
    return out


def _check_key_reuse(mod: ParsedModule) -> List[Finding]:
    out: List[Finding] = []
    funcs = [n for n in ast.walk(mod.tree)
             if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    for fn in funcs:
        seen: Dict[str, ast.Call] = {}
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Call) and node.args):
                continue
            target = mod.resolve(node.func)
            if target not in CONSUMERS:
                continue
            if mod.quals.get(id(node)) != mod.quals.get(id(fn.body[0])):
                continue                 # belongs to a nested def
            key_dump = _norm(node.args[0])
            if key_dump in seen:
                out.append(mod.finding(
                    "PRNG02", node,
                    f"PRNG key {ast.unparse(node.args[0])!r} already "
                    f"consumed by a draw on line "
                    f"{seen[key_dump].lineno} — two draws from one key "
                    "are correlated; fold_in a fresh counter per draw"))
            else:
                seen[key_dump] = node
    return out


# ---------------------------------------------------------------------------
# PRNG03: salt tracing through local dataflow (serving scope only)
# ---------------------------------------------------------------------------

SALTED, UNSALTED, UNKNOWN = "salted", "unsalted", "unknown"


def _salt_status(node: ast.AST, env: Dict[str, ast.AST],
                 mod: ParsedModule, depth: int = 0) -> str:
    if depth > 12:
        return UNKNOWN
    if isinstance(node, ast.Call):
        target = mod.resolve(node.func)
        if target == FOLD_IN:
            return SALTED
        if target == PRNGKEY:
            return UNSALTED
        if target == SPLIT and node.args:
            return _salt_status(node.args[0], env, mod, depth + 1)
        fname = dotted_name(node.func)
        if fname and fname.split(".")[-1] in BASE_STREAMS:
            return UNSALTED
        # jax.vmap(lambda k: ...)(actual): the lambda's result status with
        # params bound to the actuals — exactly the draft_keys idiom
        if isinstance(node.func, ast.Call) \
                and mod.resolve(node.func.func) == VMAP \
                and node.func.args \
                and isinstance(node.func.args[0], ast.Lambda):
            lam = node.func.args[0]
            inner = dict(env)
            for p, a in zip(lam.args.args, node.args):
                inner[p.arg] = a
            return _salt_status(lam.body, inner, mod, depth + 1)
        return UNKNOWN
    if isinstance(node, ast.Name):
        bound = env.get(node.id)
        if bound is None:
            return UNKNOWN
        # guard self-reference cycles (``k = fold_in(k, ...)`` rebinds)
        trimmed = {n: e for n, e in env.items() if n != node.id}
        return _salt_status(bound, trimmed, mod, depth + 1)
    if isinstance(node, ast.Subscript):
        return _salt_status(node.value, env, mod, depth + 1)
    return UNKNOWN


def _check_unsalted(mod: ParsedModule) -> List[Finding]:
    if not mod.relpath.startswith("src/repro/serving/"):
        return []
    out: List[Finding] = []
    funcs = [n for n in ast.walk(mod.tree)
             if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    for fn in funcs:
        env: Dict[str, ast.AST] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                env[node.targets[0].id] = node.value
        out.extend(_walk_splits(fn, env, mod))
    return out


def _walk_splits(node: ast.AST, env: Dict[str, ast.AST],
                 mod: ParsedModule) -> List[Finding]:
    out: List[Finding] = []
    for sub in ast.walk(node):
        if not (isinstance(sub, ast.Call) and mod.is_call_to(sub, SPLIT)
                and sub.args):
            continue
        key = sub.args[0]
        scope = dict(env)
        # a split inside a vmapped lambda sees its params bound to the
        # vmap call's actuals; find the nearest such binding
        lam = mod.parents.get(id(sub))
        while lam is not None and not isinstance(lam, ast.Lambda):
            lam = mod.parents.get(id(lam))
        if isinstance(lam, ast.Lambda):
            outer = mod.parents.get(id(lam))       # jax.vmap(lambda ...)
            call = mod.parents.get(id(outer)) if outer is not None else None
            if isinstance(outer, ast.Call) \
                    and mod.resolve(outer.func) == VMAP \
                    and isinstance(call, ast.Call):
                for p, a in zip(lam.args.args, call.args):
                    scope[p.arg] = a
        if _salt_status(key, scope, mod) == UNSALTED:
            out.append(mod.finding(
                "PRNG03", sub,
                "split of an unsalted base/verify key stream: a new "
                "serving key stream must fold_in its own salt constant "
                "first (sampling.py DRAFT_SALT is the model) so it stays "
                "disjoint from the verify keys at the same position"))
    return out


def check(mod: ParsedModule) -> List[Finding]:
    return (_check_split_carry(mod) + _check_key_reuse(mod)
            + _check_unsalted(mod))
