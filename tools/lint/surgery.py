"""State-surgery completeness (SURG01) — a repo-level cross-file check.

``make_decode_state`` (src/repro/serving/engine.py) is the ONE definition
of the decode-state skeleton. Five other places perform surgery on that
tree and must stay leaf-complete when someone adds a state leaf:

1. ``speculative_step`` rebuilds the dict explicitly — a leaf it doesn't
   produce is silently dropped from every decode step.
2. ``Engine.swap_out_slot`` resets the per-slot counters by name — a new
   counter that isn't reset breaks preempt-resume budget arithmetic.
3. engine.py must route slot surgery through the required
   ``cache_ops`` API (write_slot/reset_slot/gather_state/scatter_state/...),
   and each of those must still exist in cache_ops.py.
4. ``sharding/rules.py::serve_state_specs`` must handle the paged KV pool
   leaf names that ``cache_ops.paged_spec`` declares (``k``/``v``) — an
   unhandled pool leaf silently replicates gigabytes of KV.
5. ``launch/steps.py``'s serve-step ``state_specs`` template must name
   every leaf — a missing key KeyErrors only on the mesh path at launch.
6. ``scheduler._harvest`` must read back the harvest leaf set — dropping
   one silently freezes that counter at its admit-time value.

Checks 1/5 compare against the authoritative leaf set parsed from
``make_decode_state`` itself, so ADDING a leaf there immediately flags
every surface that wasn't updated; 2/4/6 pin the named handler constants,
so DELETING a handler line flags too. All structural: no imports of repro
code, stdlib ``ast`` only.
"""
from __future__ import annotations

import ast
import os
from typing import List, Optional, Set

from tools.lint.core import Finding, ParsedModule

ENGINE = "src/repro/serving/engine.py"
SCHEDULER = "src/repro/serving/scheduler.py"
CACHE_OPS = "src/repro/serving/cache_ops.py"
RULES = "src/repro/sharding/rules.py"
STEPS = "src/repro/launch/steps.py"

# cache_ops functions every slot-surgery path in the engine must go through
REQUIRED_CACHE_OPS = {"write_slot", "reset_slot", "gather_state",
                      "scatter_state", "extract_slot", "admit_pages",
                      "blank_pages", "commit"}
# per-slot counters swap-out must reset by name (scheduler resume convention)
SWAP_RESET_LEAVES = {"new_count", "slot_iters", "last"}
# leaves _harvest reads back each scheduler iteration
HARVEST_LEAVES = {"new_count", "slot_iters", "last", "tokens", "logprobs"}


def _load(root: str, rel: str) -> Optional[ParsedModule]:
    full = os.path.join(root, rel)
    if not os.path.exists(full):
        return None
    with open(full, "r", encoding="utf-8") as f:
        return ParsedModule.parse(f.read(), full, rel)


def _find_def(mod: ParsedModule, name: str):
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name == name:
            return node
    return None


def _missing_surface(rel: str, what: str) -> Finding:
    return Finding(rule="SURG01", path=rel, line=1, col=1,
                   qualname="<module>",
                   message=f"surgery surface not found: {what} — if it "
                           "moved, update tools/lint/surgery.py alongside",
                   snippet="")


def _str_constants(node: ast.AST) -> Set[str]:
    return {c.value for c in ast.walk(node)
            if isinstance(c, ast.Constant) and isinstance(c.value, str)}


def decode_state_leaves(engine: ParsedModule) -> Set[str]:
    """Authoritative leaf set: keys of the dict literal bound to ``state``
    in make_decode_state, plus any ``state["X"] = ...`` extensions (the
    conditional ``dcache``)."""
    fn = _find_def(engine, "make_decode_state")
    if fn is None:
        return set()
    leaves: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt = node.targets[0]
            if isinstance(tgt, ast.Name) and tgt.id == "state" \
                    and isinstance(node.value, ast.Dict):
                for k in node.value.keys:
                    if isinstance(k, ast.Constant) and isinstance(k.value,
                                                                  str):
                        leaves.add(k.value)
            elif isinstance(tgt, ast.Subscript) \
                    and isinstance(tgt.value, ast.Name) \
                    and tgt.value.id == "state" \
                    and isinstance(tgt.slice, ast.Constant):
                leaves.add(tgt.slice.value)
    return leaves


def _produced_leaves(fn, out_name: str) -> Set[str]:
    """Leaf names a rebuild site produces: ``X = dict(a=..., b=...)``
    keywords plus ``X["c"] = ...`` extensions."""
    produced: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt = node.targets[0]
            if isinstance(tgt, ast.Name) and tgt.id == out_name:
                v = node.value
                if isinstance(v, ast.Call) and isinstance(v.func, ast.Name) \
                        and v.func.id == "dict":
                    produced.update(kw.arg for kw in v.keywords if kw.arg)
                elif isinstance(v, ast.Dict):
                    produced.update(k.value for k in v.keys
                                    if isinstance(k, ast.Constant))
            elif isinstance(tgt, ast.Subscript) \
                    and isinstance(tgt.value, ast.Name) \
                    and tgt.value.id == out_name \
                    and isinstance(tgt.slice, ast.Constant):
                produced.add(tgt.slice.value)
    return produced


def _paged_pool_leaf_names(cache_ops_mod: ParsedModule) -> Set[str]:
    """KV pool leaf names as declared by cache_ops.paged_spec: the string
    constants compared with ``k in (...)`` whose IfExp arm is PAGED_KV."""
    fn = _find_def(cache_ops_mod, "paged_spec")
    if fn is None:
        return set()
    names: Set[str] = set()
    for node in ast.walk(fn):
        if not isinstance(node, ast.IfExp):
            continue
        body_is_kv = any(isinstance(n, ast.Name) and n.id == "PAGED_KV"
                         for n in ast.walk(node.body))
        if body_is_kv and isinstance(node.test, ast.Compare):
            names |= _str_constants(node.test)
    return names


def check_repo(root: str) -> List[Finding]:
    out: List[Finding] = []
    engine = _load(root, ENGINE)
    if engine is None:
        return [_missing_surface(ENGINE, "serving engine module")]
    leaves = decode_state_leaves(engine)
    if not leaves:
        return [_missing_surface(
            ENGINE, "make_decode_state state-dict literal")]

    # -- 1: speculative_step rebuild completeness -----------------------
    step = _find_def(engine, "speculative_step")
    if step is None:
        out.append(_missing_surface(ENGINE, "speculative_step"))
    else:
        produced = _produced_leaves(step, "new_state")
        for leaf in sorted(leaves - produced):
            out.append(Finding(
                rule="SURG01", path=ENGINE, line=step.lineno, col=1,
                qualname="speculative_step",
                message=f"decode-state leaf {leaf!r} (make_decode_state) "
                        "is not produced by speculative_step's new_state "
                        "rebuild — it would be silently dropped every step",
                snippet=f"missing-leaf:{leaf}"))

    # -- 2: swap_out_slot counter resets --------------------------------
    swap = _find_def(engine, "swap_out_slot")
    if swap is None:
        out.append(_missing_surface(ENGINE, "swap_out_slot"))
    else:
        handled = _str_constants(swap)
        for leaf in sorted(SWAP_RESET_LEAVES - handled):
            out.append(Finding(
                rule="SURG01", path=ENGINE, line=swap.lineno, col=1,
                qualname="swap_out_slot",
                message=f"swap_out_slot no longer touches per-slot leaf "
                        f"{leaf!r} — preempt-resume counter rebasing "
                        "depends on it being snapshot/reset by name",
                snippet=f"missing-leaf:{leaf}"))

    # -- 3: engine routes surgery through cache_ops, which provides it --
    cache_ops_mod = _load(root, CACHE_OPS)
    engine_attrs = {n.attr for n in ast.walk(engine.tree)
                    if isinstance(n, ast.Attribute)}
    cache_defs = set()
    if cache_ops_mod is not None:
        cache_defs = {n.name for n in ast.walk(cache_ops_mod.tree)
                      if isinstance(n, ast.FunctionDef)}
    else:
        out.append(_missing_surface(CACHE_OPS, "cache_ops module"))
    for api in sorted(REQUIRED_CACHE_OPS):
        if api not in engine_attrs:
            out.append(Finding(
                rule="SURG01", path=ENGINE, line=1, col=1,
                qualname="<module>",
                message=f"engine no longer references cache_ops.{api} — "
                        "slot surgery must go through the cache_ops API "
                        "so both layouts stay covered",
                snippet=f"missing-api:{api}"))
        if cache_ops_mod is not None and api not in cache_defs:
            out.append(Finding(
                rule="SURG01", path=CACHE_OPS, line=1, col=1,
                qualname="<module>",
                message=f"cache_ops.{api} is referenced by the engine but "
                        "not defined here",
                snippet=f"missing-def:{api}"))

    # -- 4: sharding rules handle the paged KV pool leaf names ----------
    rules_mod = _load(root, RULES)
    if rules_mod is None:
        out.append(_missing_surface(RULES, "sharding rules module"))
    elif cache_ops_mod is not None:
        pool_names = _paged_pool_leaf_names(cache_ops_mod)
        if not pool_names:
            out.append(_missing_surface(
                CACHE_OPS, "paged_spec PAGED_KV leaf-name declaration"))
        handled: Set[str] = set()
        for fname in ("serve_state_specs", "_serve_state_leaf"):
            fn = _find_def(rules_mod, fname)
            if fn is not None:
                handled |= _str_constants(fn)
        for leaf in sorted(pool_names - handled):
            out.append(Finding(
                rule="SURG01", path=RULES, line=1, col=1,
                qualname="serve_state_specs",
                message=f"KV pool leaf {leaf!r} (cache_ops.paged_spec) has "
                        "no handler in serve_state_specs/_serve_state_leaf "
                        "— the pool would silently replicate on every "
                        "device instead of sharding its KV-head axis",
                snippet=f"missing-leaf:{leaf}"))

    # -- 5: launch serve-step state_specs template names every leaf -----
    steps_mod = _load(root, STEPS)
    if steps_mod is None:
        out.append(_missing_surface(STEPS, "launch steps module"))
    else:
        spec_keys: Set[str] = set()
        spec_line = 1
        for node in ast.walk(steps_mod.tree):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and node.targets[0].id == "state_specs" \
                    and isinstance(node.value, ast.Dict):
                spec_keys = {k.value for k in node.value.keys
                             if isinstance(k, ast.Constant)}
                spec_line = node.lineno
        if not spec_keys:
            out.append(_missing_surface(STEPS, "state_specs dict literal"))
        else:
            for leaf in sorted(leaves - spec_keys):
                out.append(Finding(
                    rule="SURG01", path=STEPS, line=spec_line, col=1,
                    qualname="build_serve_step",
                    message=f"decode-state leaf {leaf!r} has no entry in "
                            "the serve-step state_specs template — the "
                            "mesh launch path KeyErrors (or mis-shards) "
                            "on it",
                    snippet=f"missing-leaf:{leaf}"))

    # -- 6: scheduler harvest reads back the harvest leaf set -----------
    sched = _load(root, SCHEDULER)
    if sched is None:
        out.append(_missing_surface(SCHEDULER, "scheduler module"))
    else:
        harvest = _find_def(sched, "_harvest")
        if harvest is None:
            out.append(_missing_surface(SCHEDULER, "_harvest"))
        else:
            read = _str_constants(harvest)
            for leaf in sorted(HARVEST_LEAVES - read):
                out.append(Finding(
                    rule="SURG01", path=SCHEDULER, line=harvest.lineno,
                    col=1, qualname="Scheduler._harvest",
                    message=f"_harvest no longer reads back leaf {leaf!r} "
                            "— streams would stall on a frozen counter or "
                            "lose committed output",
                    snippet=f"missing-leaf:{leaf}"))

    return sorted(out, key=lambda f: (f.path, f.line, f.snippet))
