"""Optimizer + schedule + accumulation unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (GradAccumulator, adamw_init, adamw_update,
                         apply_updates, clip_by_global_norm,
                         linear_warmup_schedule)


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0]), "b": jnp.array(2.0)}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(300):
        g = jax.grad(loss)(params)
        upd, opt, _ = adamw_update(g, opt, params, lr=0.05, weight_decay=0.0)
        params = apply_updates(params, upd)
    assert float(loss(params)) < 1e-3


def test_linear_warmup_schedule():
    sched = linear_warmup_schedule(1e-4, 1000, warmup_ratio=0.01)
    assert float(sched(jnp.asarray(0))) == 0.0
    assert float(sched(jnp.asarray(10))) == pytest.approx(1e-4)
    assert float(sched(jnp.asarray(505))) == pytest.approx(5e-5, rel=0.05)
    assert float(sched(jnp.asarray(1000))) == pytest.approx(0.0, abs=1e-9)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(20.0)
    total = jnp.sqrt(jnp.sum(jnp.square(clipped["a"])))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


def test_grad_accumulator_weighted_mean():
    params = {"w": jnp.zeros(3)}
    acc = GradAccumulator(params)
    a = acc.init()
    a = GradAccumulator.add(a, {"w": jnp.ones(3)}, 1.0)
    a = GradAccumulator.add(a, {"w": 4 * jnp.ones(3)}, 3.0)
    mean = GradAccumulator.mean(a)
    np.testing.assert_allclose(np.asarray(mean["w"]),
                               (1 * 1 + 4 * 3) / 4 * np.ones(3))


def test_weight_decay_decoupled():
    params = {"w": jnp.array([1.0])}
    opt = adamw_init(params)
    zero_g = {"w": jnp.array([0.0])}
    upd, opt, _ = adamw_update(zero_g, opt, params, lr=0.1, weight_decay=0.5)
    assert float(upd["w"][0]) == pytest.approx(-0.05)
