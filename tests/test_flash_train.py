"""Flash custom-VJP MTP attention (core/flash_train.py): forward and
gradients must match the dense-mask oracle exactly — the §Perf A1
optimization must not change training semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cod
from repro.core.flash_train import mtp_flash_attention
from repro.kernels import ref

KEY = jax.random.PRNGKey(0)


def _setup(n, K, r, B, H, KV, hd, pad_to=None):
    rng = np.random.default_rng(0)
    pos_np, dep_np = cod.sample_cod(rng, n, K, r)
    M = pad_to or int(np.ceil(len(pos_np) / 64) * 64)
    pos_np, dep_np = cod.pad_to(pos_np, dep_np, M)
    q = 0.3 * jax.random.normal(KEY, (B, M, H, hd))
    k = 0.3 * jax.random.normal(jax.random.fold_in(KEY, 1), (B, M, KV, hd))
    v = 0.3 * jax.random.normal(jax.random.fold_in(KEY, 2), (B, M, KV, hd))
    pos = jnp.broadcast_to(jnp.asarray(pos_np)[None], (B, M))
    dep = jnp.broadcast_to(jnp.asarray(dep_np)[None], (B, M))
    return q, k, v, pos, dep, jnp.asarray(pos_np), jnp.asarray(dep_np)


@pytest.mark.parametrize("n,K,r", [(48, 4, 0.7), (24, 3, 0.6)])
@pytest.mark.parametrize("B,H,KV,hd", [(2, 4, 2, 32), (1, 2, 1, 64)])
def test_forward_matches_oracle(n, K, r, B, H, KV, hd):
    q, k, v, pos, dep, pos1, dep1 = _setup(n, K, r, B, H, KV, hd)
    o = mtp_flash_attention(q, k, v, pos, dep, scale=hd ** -0.5, block_k=64)
    r_ = ref.mtp_attention_reference(q, k, v, pos1, dep1, scale=hd ** -0.5)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r_), atol=3e-6)


def test_gradients_match_oracle():
    B, H, KV, hd = 2, 4, 2, 32
    q, k, v, pos, dep, pos1, dep1 = _setup(48, 4, 0.7, B, H, KV, hd)

    def loss_flash(q, k, v):
        o = mtp_flash_attention(q, k, v, pos, dep, scale=hd ** -0.5,
                                block_k=64)
        return jnp.sum(jnp.sin(o))

    def loss_ref(q, k, v):
        o = ref.mtp_attention_reference(q, k, v, pos1, dep1,
                                        scale=hd ** -0.5)
        return jnp.sum(jnp.sin(o))

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip("qkv", g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-4,
                                   err_msg=f"d{name}")


def test_used_inside_mtp_forward():
    """mtp_forward must produce identical logits with and without the flash
    path (flash kicks in at M >= 512)."""
    from repro.configs import DrafterConfig, get_config
    from repro.core import drafter as D
    tcfg = get_config("qwen2-1.5b").reduced()
    import dataclasses
    B, n = 1, 200
    rng = np.random.default_rng(1)
    pos_np, dep_np = cod.sample_cod(rng, n, 4, 0.8)
    M = int(np.ceil(len(pos_np) / 64) * 64)
    pos_np, dep_np = cod.pad_to(pos_np, dep_np, M)
    assert M >= 512, "test needs the flash threshold to trigger"
    tokens = jax.random.randint(KEY, (B, n), 0, tcfg.vocab_size)
    taps = 0.1 * jax.random.normal(KEY, (B, n, 3 * tcfg.d_model))
    for flash in (True, False):
        dcfg = DrafterConfig(n_layers=1, k_train=4,
                             flash_train=flash).resolve(tcfg)
        params = D.init_params(dcfg, tcfg, KEY)
        lg, _ = D.mtp_forward(dcfg, tcfg, params, tokens, taps,
                              jnp.asarray(pos_np), jnp.asarray(dep_np))
        if flash:
            lg_flash = lg
    np.testing.assert_allclose(np.asarray(lg_flash), np.asarray(lg),
                               atol=1e-4, rtol=1e-3)
