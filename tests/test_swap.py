"""Swap-to-host preemption property suite (the SWAPPED page-lifecycle
state: cache_ops.HostPagePool + Engine.swap_out_slot/swap_in_slot +
scheduler policy/fallback wiring).

The acceptance pins:

- **bitwise restore**: swap-out parks a victim's device state (all KV
  leaves + recurrent stream state + sampling/logprob rows) in a host pool
  and swap-in scatters it back byte-for-byte, so a swapped-and-resumed
  request emits token-for-token — bitwise for seeded-sampled rows — what
  BOTH the never-preempted run and the recompute-prefill resume emit, for
  dense/SSM/hybrid, single-device and model-sharded (mesh {1,4,8};
  swap requires the paged layout, so kv_layout is pinned there);
- **dual-pool hygiene**: randomized admit/swap/recompute/abort churn
  leaves zero leaked or aliased pages in the DEVICE pool and zero leaked
  bytes/handles in the HOST pool (the fault-injection suite: a tiny
  ``host_pool_bytes`` budget injects swap-out failures mid-churn);
- **graceful degradation**: when the host pool can't take a snapshot,
  preemption falls back to recompute-prefill losslessly — no crash, no
  stall — and the report counts both preemption kinds honestly
  (``preemptions == preempt_swap + preempt_recompute``);
- **immediate reclamation**: aborting a swapped request frees its host
  bytes right away (streaming ``abort()``), and ``health()`` exposes
  host-pool occupancy;
- **honest peaks**: the host pool's ``peak_used`` high-water mark feeds
  scheduler reports and resets with ``Engine.reset_stats`` so
  tables 13/19 compare warm-up and measured phases honestly.
"""
from functools import lru_cache

import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.serving import (AsyncEngine, Engine, EngineConfig, HostPagePool,
                           Request, SamplingParams, Scheduler)
from repro.sharding.utils import serving_mesh

from conftest import require_devices  # noqa: E402  (tests dir on sys.path)
from test_async_serving import (FAMILY_ARCHS, _setup, assert_pool_drained,
                                churn_workload, get_engine, solo_tokens)


@lru_cache(maxsize=None)
def get_swap_engine(family="dense", pool_pages=0, host_bytes=0, batch=2,
                    shard=0, prefix_cache=False):
    """Paged engine with swap-to-host preemption; same reduced geometry as
    test_async_serving.get_engine so solo references are interchangeable."""
    tcfg, dcfg, tparams, dparams = _setup(family)
    return Engine(tcfg, dcfg, tparams, dparams,
                  EngineConfig(K=2, max_new_tokens=16,
                               drafter_mode="parallel", max_len=64,
                               kv_layout="paged", page_size=8,
                               pool_pages=pool_pages,
                               kv_growth="incremental",
                               swap="host", host_pool_bytes=host_bytes,
                               prefix_cache=prefix_cache,
                               shard_model=shard > 0,
                               mesh=serving_mesh(shard) if shard else None),
                  batch)


def assert_both_pools_drained(eng):
    assert_pool_drained(eng)
    assert len(eng.host_pool) == 0, "host pool still holds a snapshot"
    assert eng.host_pool.used_bytes == 0, "host bytes leaked"


def solo_sampled(eng, prompt, budget, sp):
    rep = Scheduler(eng).serve([Request(prompt, max_new_tokens=budget,
                                        sampling=sp)])
    return rep["results"][0]


def preempt_workload(seed=3):
    """The tight-pool forcing mix the recompute-preemption tests use: pool
    of 5 pages fits both initial claims but not both full-grown requests."""
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(1, 200, size=6).astype(np.int32)
               for _ in range(3)]
    return prompts, [14, 14, 8]


# ---------------------------------------------------------------------------
# HostPagePool unit behavior
# ---------------------------------------------------------------------------

def test_host_page_pool_accounting():
    hp = HostPagePool(100)
    assert hp.can_store(100) and not hp.can_store(101)
    assert hp.put("a", "h1", 60)
    assert "a" in hp and len(hp) == 1
    assert hp.used_bytes == 60 and hp.peak_used == 60
    assert not hp.put("b", "h2", 50), "over-budget put must refuse"
    assert "b" not in hp and hp.used_bytes == 60, "refused put stored bytes"
    with pytest.raises(ValueError):
        hp.put("a", "dup", 1)            # double snapshot = lost resume
    assert hp.pop("a") == "h1"
    assert hp.used_bytes == 0 and hp.peak_used == 60, \
        "pop must release bytes but keep the high-water mark"
    with pytest.raises(KeyError):
        hp.pop("a")                      # double-free raises, like the
    assert hp.get("a") is None           # BlockAllocator
    hp.reset_stats()
    assert hp.peak_used == 0
    unbounded = HostPagePool(0)
    assert unbounded.can_store(10 ** 12)
    with pytest.raises(ValueError):
        HostPagePool(-1)


def test_swap_config_validation():
    tcfg, dcfg, tparams, dparams = _setup("dense")
    with pytest.raises(ValueError, match="paged"):
        Engine(tcfg, dcfg, tparams, dparams,
               EngineConfig(K=2, max_new_tokens=8, drafter_mode="parallel",
                            max_len=64, swap="host"), 2)
    with pytest.raises(ValueError):
        Engine(tcfg, dcfg, tparams, dparams,
               EngineConfig(K=2, max_new_tokens=8, drafter_mode="parallel",
                            max_len=64, kv_layout="paged", page_size=8,
                            swap="disk"), 2)


# ---------------------------------------------------------------------------
# engine-level bitwise roundtrip
# ---------------------------------------------------------------------------

def test_swap_roundtrip_restores_slot_bitwise():
    """swap_out → swap_in restores the slot's gathered view byte-for-byte
    (device→host→device copies preserve bytes; fresh page ids differ but
    the block-table view is identical), except the committed counters,
    which the snapshot zeroes to the scheduler's resume convention."""
    from repro.serving import cache_ops

    eng = get_swap_engine("dense", pool_pages=6)
    state = eng.blank_state()
    prompt = np.arange(1, 7, dtype=np.int32)
    state, _, _ = eng.prefill_into_slot(state, prompt, 0, max_new=8)
    # compare only the slot's CLAIMED span: the block-table row's -1 tail
    # clips to physical page 0 in the gather, whose identity legitimately
    # changes when swap-in re-allocates pages in a different order (its
    # positions are forced -1, so it is never attendable history)
    valid = np.arange(len(eng._slot_pages[0]) * eng.ecfg.page_size)

    def view(state):
        raw = jax.device_get(eng._swap_gather(
            state, jnp.asarray(0, jnp.int32), state["block_table"][0]))

        def clip(leaf, tag):
            if tag == cache_ops.NOT_PAGED:
                return leaf
            return np.take(leaf, valid,
                           axis=cache_ops.view_width_axis(leaf.ndim, tag))

        return jax.tree.map(clip, raw, eng.pspec)

    before = view(state)
    nbytes_est = eng.swap_bytes_estimate(0)
    state, ok = eng.swap_out_slot(state, 0, rid="r0")
    assert ok
    assert eng.swap_last_bytes == nbytes_est, \
        "swap_bytes_estimate must price exactly what swap-out stores"
    assert not eng._slot_pages[0] and eng.has_swap("r0")
    assert eng.host_pool.used_bytes == nbytes_est
    assert eng.can_swap_in("r0")
    state, last = eng.swap_in_slot(state, 0, "r0")
    after = view(state)
    assert int(before["last"][0]) == last
    want = dict(before)
    want["new_count"] = np.zeros_like(want["new_count"])
    want["slot_iters"] = np.zeros_like(want["slot_iters"])
    got_leaves = jax.tree_util.tree_flatten_with_path(after)[0]
    want_leaves = jax.tree.leaves(want)
    assert len(got_leaves) == len(want_leaves)
    for (path, got), exp in zip(got_leaves, want_leaves):
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(exp),
            err_msg=f"leaf {jax.tree_util.keystr(path)} not restored "
                    "bitwise")
    assert len(eng.host_pool) == 0 and eng.host_pool.used_bytes == 0
    state = eng.free_slot(state, 0)
    assert_both_pools_drained(eng)


# ---------------------------------------------------------------------------
# lossless swap-resume, per family (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_swap_resume_equals_solo_and_recompute(family):
    """A swapped-and-resumed request emits the exact token (and logprob)
    sequence of BOTH the uninterrupted solo run and the recompute-prefill
    resume — for SSM/hybrid this is the cheap-resume path the prefix cache
    can't give them (the whole recurrent stream state swaps with the
    slot)."""
    eng = get_swap_engine(family, pool_pages=5)
    ref = get_engine(family, pool_pages=5)         # recompute twin
    prompts, budgets = preempt_workload()

    def reqs():
        return [Request(p, max_new_tokens=b)
                for p, b in zip(prompts, budgets)]

    rep = Scheduler(eng).serve(reqs())
    assert rep["preempt_swap"] >= 1, "workload was meant to force a swap"
    assert rep["preemptions"] == (rep["preempt_swap"]
                                  + rep["preempt_recompute"])
    assert rep["recomputed_prefill_tokens"] == 0, \
        "swap resumes must not recompute any prefill tokens"
    assert rep["host_pool"]["peak_bytes"] > 0, \
        "a swap happened but the report shows no host high-water mark"
    assert any(r["n_swap"] > 0 for r in rep["results"])
    rep_rc = Scheduler(ref).serve(reqs())
    assert rep_rc["preemptions"] >= 1
    for res, rc, p, b in zip(rep["results"], rep_rc["results"],
                             prompts, budgets):
        solo = solo_sampled(ref, p, b, None)
        np.testing.assert_array_equal(
            res["tokens"], solo["tokens"],
            err_msg=f"{family}: rid {res['rid']} diverged from solo")
        # swap restores the eviction state bitwise, so even the logprobs
        # continue exactly as the uninterrupted run's
        np.testing.assert_array_equal(res["logprobs"], solo["logprobs"])
        np.testing.assert_array_equal(res["tokens"], rc["tokens"])
        # the recompute twin re-derives resume logits through a bucketed
        # prefill — same tokens, logprobs equal only to float tolerance
        np.testing.assert_allclose(res["logprobs"], rc["logprobs"],
                                   rtol=1e-5, atol=1e-6)
    assert_both_pools_drained(eng)
    assert_pool_drained(ref)


@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_sampled_swap_resume_bitwise(family):
    """Seeded-sampled rows restore bitwise: swap-in rebuilds the sampling
    state (keys, logprob accumulators) byte-for-byte, so the resumed rows
    replay the uninterrupted draw exactly — stronger than the recompute
    path, which relies on the fold_in(seed, position) replay invariant."""
    eng = get_swap_engine(family, pool_pages=5)
    prompts, budgets = preempt_workload()
    sps = [SamplingParams(temperature=0.8, seed=100 + i) for i in range(3)]
    rep = Scheduler(eng).serve(
        [Request(p, max_new_tokens=b, sampling=sp)
         for p, b, sp in zip(prompts, budgets, sps)])
    assert rep["preempt_swap"] >= 1, "workload was meant to force a swap"
    for res, p, b, sp in zip(rep["results"], prompts, budgets, sps):
        solo = solo_sampled(eng, p, b, sp)
        np.testing.assert_array_equal(
            res["tokens"], solo["tokens"],
            err_msg=f"{family}: sampled rid {res['rid']} diverged")
        np.testing.assert_array_equal(res["logprobs"], solo["logprobs"])
    assert_both_pools_drained(eng)


@pytest.mark.parametrize("family,shard", [
    ("dense", 4),
    pytest.param("ssm", 4, marks=pytest.mark.slow),
    pytest.param("hybrid", 4, marks=pytest.mark.slow),
    pytest.param("dense", 8, marks=pytest.mark.slow),
])
def test_sharded_sampled_swap_resume_matches_single_device(family, shard):
    """The mesh pin: on {4,8} forced host devices the swap gather/scatter
    cross the storage-sharded page pools, and the seeded-sampled streams
    must still match the single-device engine bitwise (mesh 1 is
    test_sampled_swap_resume_bitwise)."""
    require_devices(shard)
    eng = get_swap_engine(family, pool_pages=5, shard=shard)
    ref = get_engine(family, pool_pages=5)         # single-device twin
    prompts, budgets = preempt_workload()
    sps = [SamplingParams(temperature=0.8, seed=100 + i) for i in range(3)]
    rep = Scheduler(eng).serve(
        [Request(p, max_new_tokens=b, sampling=sp)
         for p, b, sp in zip(prompts, budgets, sps)])
    assert rep["preempt_swap"] >= 1, "workload was meant to force a swap"
    for res, p, b, sp in zip(rep["results"], prompts, budgets, sps):
        solo = solo_sampled(ref, p, b, sp)
        np.testing.assert_array_equal(
            res["tokens"], solo["tokens"],
            err_msg=f"{family}@mesh{shard}: rid {res['rid']} diverged "
                    "from the single-device stream")
        np.testing.assert_array_equal(res["logprobs"], solo["logprobs"])
    assert_both_pools_drained(eng)


# ---------------------------------------------------------------------------
# host-pool exhaustion: graceful, honest degradation
# ---------------------------------------------------------------------------

def test_host_pool_exhaustion_falls_back_to_recompute():
    """With a host budget too small for any snapshot, every preemption
    falls back to recompute-prefill: no crash, no stall, streams still
    lossless, and the report splits the preemption kinds honestly."""
    eng = get_swap_engine("dense", pool_pages=5, host_bytes=64)
    ref = get_engine("dense", pool_pages=5)
    prompts, budgets = preempt_workload()
    rep = Scheduler(eng).serve([Request(p, max_new_tokens=b)
                                for p, b in zip(prompts, budgets)])
    assert rep["preemptions"] >= 1, "workload was meant to force eviction"
    assert rep["preempt_swap"] == 0, "64 bytes cannot hold a snapshot"
    assert rep["preempt_recompute"] == rep["preemptions"]
    assert rep["recomputed_prefill_tokens"] > 0
    assert rep["host_pool"]["peak_bytes"] == 0
    assert rep["host_pool"]["capacity_bytes"] == 64
    for res, p, b in zip(rep["results"], prompts, budgets):
        np.testing.assert_array_equal(
            res["tokens"], solo_tokens(ref, p, b),
            err_msg=f"fallback rid {res['rid']} diverged")
    assert_both_pools_drained(eng)


# ---------------------------------------------------------------------------
# fault-injection churn: randomized admit/swap/recompute/abort cycles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
@settings(max_examples=2, deadline=None)
@given(n=st.integers(1, 4), seed=st.integers(0, 2 ** 31 - 1))
def test_swap_churn_hygiene_and_losslessness(family, n, seed):
    """Random arrival/length/budget workloads over a tight pool with swap
    enabled (unbounded host budget): every grow/swap-out/swap-in/finish
    cycle leaks and aliases nothing in either pool, budgets are met
    exactly, and every stream matches its solo run."""
    eng = get_swap_engine(family, pool_pages=6)
    reqs = churn_workload(seed, n, max_budget=6)
    want = [(r.prompt.copy(), r.max_new_tokens) for r in reqs]
    rep = Scheduler(eng).serve(reqs)
    assert rep["preemptions"] == (rep["preempt_swap"]
                                  + rep["preempt_recompute"])
    assert_both_pools_drained(eng)
    assert eng.allocator.peak_used <= eng.pool_pages
    for res, (p, b) in zip(rep["results"], want):
        assert res["n_new"] == b                # no EOS id ⇒ exact budget
        np.testing.assert_array_equal(res["tokens"], solo_tokens(eng, p, b))
    assert_both_pools_drained(eng)


@settings(max_examples=3, deadline=None)
@given(n=st.integers(2, 5), seed=st.integers(0, 2 ** 31 - 1))
def test_swap_churn_with_tiny_host_pool_mixes_kinds(n, seed):
    """The fault-injection axis: a host budget that fits roughly ONE
    snapshot makes swap-out succeed or fail depending on what's already
    parked, so churn interleaves swap preemptions, recompute fallbacks,
    and swap drops — hygiene and losslessness must survive the mix."""
    eng = get_swap_engine("dense", pool_pages=6, host_bytes=60_000)
    reqs = churn_workload(seed, n, max_budget=6)
    want = [(r.prompt.copy(), r.max_new_tokens) for r in reqs]
    rep = Scheduler(eng).serve(reqs)
    assert rep["preemptions"] == (rep["preempt_swap"]
                                  + rep["preempt_recompute"])
    assert rep["host_pool"]["peak_bytes"] <= 60_000, "budget overrun"
    assert_both_pools_drained(eng)
    for res, (p, b) in zip(rep["results"], want):
        np.testing.assert_array_equal(res["tokens"], solo_tokens(eng, p, b))
    assert_both_pools_drained(eng)


def test_swap_composes_with_prefix_cache_shared_pages_stay_resident():
    """The SWAPPED state composes with refcounts: pages a victim shares
    with the prefix cache stay resident (the handle pins them), only the
    refcount==1 remainder moves to the host — and the streams still match
    a cache-off, swap-off solo run. Identical prompts force sharing."""
    eng = get_swap_engine("dense", pool_pages=5, prefix_cache=True)
    ref = get_engine("dense", pool_pages=5)
    prompt = np.arange(11, 17, dtype=np.int32)
    budgets = [14, 14, 8]
    rep = Scheduler(eng).serve([Request(prompt, max_new_tokens=b)
                                for b in budgets])
    assert rep["preemptions"] >= 1, "workload was meant to force eviction"
    for res, b in zip(rep["results"], budgets):
        np.testing.assert_array_equal(
            res["tokens"], solo_tokens(ref, prompt, b),
            err_msg=f"cached swap: rid {res['rid']} diverged")
    alloc, cache = eng.allocator, eng.prefix_cache
    assert all(not ps for ps in eng._slot_pages), "slot still holds pages"
    held = cache.pages()
    assert len(held) == len(set(held)), "cache aliases a page"
    assert alloc.n_used == len(held), "page neither free nor cache-held"
    assert all(alloc.refcount(p) == 1 for p in held), "leaked refcount"
    assert len(eng.host_pool) == 0 and eng.host_pool.used_bytes == 0
    cache.flush(alloc)
    assert_both_pools_drained(eng)


# ---------------------------------------------------------------------------
# streaming: abort frees host bytes immediately; health() occupancy
# ---------------------------------------------------------------------------

def test_abort_swapped_request_frees_host_bytes_immediately():
    """Aborting a swapped-out request reclaims its host bytes right away
    (no deferred sweep), health() exposes the host-pool gauges, and the
    surviving streams still finish losslessly."""
    eng = get_swap_engine("dense", pool_pages=5, batch=2)
    ref = get_engine("dense", pool_pages=5)
    prompts, budgets = preempt_workload()

    async def go():
        aeng = AsyncEngine(eng)
        handles = [await aeng.submit(p, max_new_tokens=b)
                   for p, b in zip(prompts, budgets)]
        while aeng.health()["swapped"] == 0:
            assert not all(hd.done for hd in handles), \
                "session drained without ever swapping a request out"
            await asyncio.sleep(0.005)
        h = aeng.health()
        assert h["swapped"] == 1
        assert h["host_pool_used_bytes"] > 0
        assert h["host_pool_peak_bytes"] >= h["host_pool_used_bytes"]
        assert h["host_pool_bytes"] == 0          # unbounded budget
        victim = next(hd for hd in handles if eng.has_swap(hd.rid))
        assert victim.abort()
        assert eng.host_pool.used_bytes == 0, \
            "abort must free host bytes immediately"
        assert aeng.health()["swapped"] == 0
        survivors = [hd for hd in handles if hd is not victim]
        outs = []
        for hd in survivors:
            toks = [t async for t, _ in hd]
            outs.append(np.asarray(toks, np.int32))
        await aeng.close()
        return [hd.rid for hd in handles].index(victim.rid), outs

    v_idx, outs = asyncio.run(asyncio.wait_for(go(), 300))
    keep = [i for i in range(len(prompts)) if i != v_idx]
    for i, got in zip(keep, outs):
        np.testing.assert_array_equal(
            got, solo_tokens(ref, prompts[i], budgets[i]),
            err_msg=f"survivor {i} diverged after a swapped abort")
    assert_both_pools_drained(eng)


# ---------------------------------------------------------------------------
# honest peaks across phases (tables 13/19)
# ---------------------------------------------------------------------------

def test_reset_stats_covers_both_pools():
    eng = get_swap_engine("dense", pool_pages=5)
    prompts, budgets = preempt_workload()
    rep = Scheduler(eng).serve([Request(p, max_new_tokens=b)
                                for p, b in zip(prompts, budgets)])
    assert rep["preempt_swap"] >= 1
    assert eng.host_pool.peak_used > 0
    assert eng.allocator.peak_used > 0
    assert rep["peak_pages"] == eng.allocator.peak_used
    eng.reset_stats()
    assert eng.host_pool.peak_used == 0, "drained pool resets to usage"
    assert eng.allocator.peak_used == eng.allocator.n_used
