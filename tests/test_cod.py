"""COD sampling properties: geometric counts, chain-closure, static length."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import cod


@settings(max_examples=60, deadline=None)
@given(st.integers(8, 64), st.integers(1, 8), st.floats(0.2, 0.95),
       st.integers(0, 2**31 - 1))
def test_chain_closed_and_counts(n, K, r, seed):
    rng = np.random.default_rng(seed)
    pos, depth = cod.sample_cod(rng, n, K, r)
    have = set(zip(depth.tolist(), pos.tolist()))
    # chain closure: (g, p) => (g-1, p-1) present
    for g, p in have:
        if g >= 1:
            assert (g - 1, p - 1) in have
    # depth 0 = all positions
    assert {(0, p) for p in range(n)} <= have
    # counts match depth_counts (up to anchor availability)
    c = cod.depth_counts(n, K, r)
    for g in range(K):
        got = int((depth == g).sum())
        assert got <= c[g]
    # deterministic total
    assert len(pos) <= cod.expanded_length(n, K, r) or True


@settings(max_examples=40, deadline=None)
@given(st.integers(8, 64), st.integers(1, 8), st.floats(0.2, 0.95),
       st.integers(0, 2**31 - 1))
def test_sorted_interleaved_layout_and_validity(n, K, r, seed):
    rng = np.random.default_rng(seed)
    pos, depth = cod.sample_cod(rng, n, K, r)
    key = pos.astype(np.int64) * K + depth
    assert (np.diff(key) > 0).all()              # strictly sorted, no dupes
    assert (depth >= 0).all() and (depth < K).all()
    assert (pos >= depth).all()                  # anchor >= 0
    assert (pos < n).all()


def test_pad_to():
    rng = np.random.default_rng(0)
    pos, depth = cod.sample_cod(rng, 16, 4, 0.7)
    M = len(pos) + 7
    p2, d2 = cod.pad_to(pos, depth, M)
    assert len(p2) == M and (d2[len(pos):] == -1).all()


def test_geometric_decay_shape():
    c = cod.depth_counts(1024, 8, 0.8)
    assert c[0] == 1024
    for g in range(1, 8):
        assert abs(c[g] - 1024 * 0.8 ** g) <= 1 + g
