"""Continuous-batching scheduler invariants (serving/scheduler.py):

- every submitted request finishes exactly once, in FIFO admission order;
- no slot serves two requests at once (admission intervals per slot are
  disjoint);
- per-request token counts respect max_new_tokens and EOS;
- mid-stream admission into a freed slot does not change what
  already-decoding neighbor slots emit (row independence, the correctness
  backbone of per-slot refill).

Engines are cached per (batch, mode): the per-request budgets all ride the
scheduler's per-slot max_new path, so one compiled engine serves every test.
"""
from functools import lru_cache

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import DrafterConfig, get_config
from repro.core import drafter as D
from repro.models import get_model, make_extras
from repro.serving import Engine, EngineConfig, Request, Scheduler

KEY = jax.random.PRNGKey(11)


@lru_cache(maxsize=None)
def _setup():
    tcfg = get_config("qwen2-1.5b").reduced()
    m = get_model(tcfg)
    tparams = m.init(KEY)
    dcfg = DrafterConfig(n_layers=1, k_infer=3).resolve(tcfg)
    dparams = D.init_params(dcfg, tcfg, jax.random.fold_in(KEY, 2))
    return tcfg, dcfg, tparams, dparams


_ENGINES = {}


def get_engine(batch=2, mode="parallel", kv_layout="contiguous"):
    if (batch, mode, kv_layout) not in _ENGINES:
        tcfg, dcfg, tparams, dparams = _setup()
        K = 3
        if mode == "none":
            dcfg = dparams = None
            K = 0
        _ENGINES[batch, mode, kv_layout] = Engine(
            tcfg, dcfg, tparams, dparams,
            EngineConfig(K=K, max_new_tokens=16, drafter_mode=mode,
                         max_len=64, kv_layout=kv_layout, page_size=8),
            batch)
    return _ENGINES[batch, mode, kv_layout]


def make_prompts(n, length=4, seed=0, vocab=200):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, size=length).astype(np.int32)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# lifecycle invariants
# ---------------------------------------------------------------------------

def test_every_request_finishes_exactly_once():
    eng = get_engine(batch=2)
    reqs = [Request(p, max_new_tokens=3 + i % 4)
            for i, p in enumerate(make_prompts(7))]
    rep = Scheduler(eng).serve(reqs)
    assert rep["n_requests"] == 7
    rids = [r["rid"] for r in rep["results"]]
    assert len(set(rids)) == 7 and sorted(rids) == sorted(r.rid for r in reqs)
    assert all(r.status == "finished" for r in reqs)
    assert rep["total_new_tokens"] == sum(r["n_new"] for r in rep["results"])


def test_token_budgets_respected():
    eng = get_engine(batch=3)
    budgets = [1, 2, 5, 9, 16]
    reqs = [Request(p, max_new_tokens=b)
            for p, b in zip(make_prompts(5, seed=3), budgets)]
    rep = Scheduler(eng).serve(reqs)
    for res, b in zip(rep["results"], budgets):
        # speculative commits may overshoot on device; emitted output may not
        assert res["n_new"] == b
        assert res["tokens"].shape == (b,)


def test_no_slot_serves_two_requests_at_once():
    eng = get_engine(batch=2)
    reqs = [Request(p, max_new_tokens=2 + i % 5)
            for i, p in enumerate(make_prompts(9, seed=5))]
    Scheduler(eng).serve(reqs)
    by_slot = {}
    for r in reqs:
        assert r.slot is not None
        by_slot.setdefault(r.slot, []).append((r.t_admit, r.t_finish))
    assert set(by_slot) <= {0, 1}
    for spans in by_slot.values():
        spans.sort()
        for (a0, f0), (a1, _) in zip(spans, spans[1:]):
            assert f0 <= a1, "slot admitted a request before freeing"


def test_fifo_admission():
    eng = get_engine(batch=2)
    reqs = [Request(p, max_new_tokens=4) for p in make_prompts(6, seed=7)]
    Scheduler(eng).serve(reqs)
    admits = [r.t_admit for r in reqs]
    assert admits == sorted(admits)          # FIFO: rid order == admit order


def test_eos_terminates_and_trims():
    eng = get_engine(batch=2)
    prompts = make_prompts(3, seed=9)
    ref = Scheduler(eng).serve([Request(p, max_new_tokens=10)
                                for p in prompts])
    # pick a token from the middle of request 0's output as the EOS id
    eos = int(ref["results"][0]["tokens"][4])
    rep = Scheduler(eng, eos_id=eos).serve([Request(p, max_new_tokens=10)
                                            for p in prompts])
    for res, refres in zip(rep["results"], ref["results"]):
        full = refres["tokens"].tolist()
        want = (full[:full.index(eos) + 1] if eos in full else full)
        assert res["tokens"].tolist() == want
        if eos in full:
            assert res["tokens"][-1] == eos


# ---------------------------------------------------------------------------
# row independence: mid-stream refill must not perturb neighbors
# ---------------------------------------------------------------------------

def test_midstream_refill_leaves_neighbor_unchanged():
    eng = get_engine(batch=2)
    pa, pb, pc = make_prompts(3, seed=13)
    # A decodes long; B finishes fast and frees its slot; C is admitted into
    # the live batch while A is mid-stream.
    ra, rb, rc = (Request(pa, max_new_tokens=14), Request(pb, max_new_tokens=3),
                  Request(pc, max_new_tokens=8))
    rep = Scheduler(eng).serve([ra, rb, rc])
    assert rc.t_admit > rb.t_finish - 1e-9   # C really was a mid-stream refill
    assert rc.slot == rb.slot and ra.slot != rb.slot
    # solo references: each request alone in an otherwise idle batch
    for req, prompt, budget in [(ra, pa, 14), (rb, pb, 3), (rc, pc, 8)]:
        solo = Scheduler(eng).serve([Request(prompt, max_new_tokens=budget)])
        got = [r for r in rep["results"] if r["rid"] == req.rid][0]
        np.testing.assert_array_equal(got["tokens"],
                                      solo["results"][0]["tokens"])


def test_refill_invariance_none_mode():
    """Same invariance through the vanilla-AR path (K=0, no drafter)."""
    eng = get_engine(batch=2, mode="none")
    prompts = make_prompts(4, seed=17)
    budgets = [10, 3, 6, 4]
    rep = Scheduler(eng).serve(
        [Request(p, max_new_tokens=b) for p, b in zip(prompts, budgets)])
    for i, (p, b) in enumerate(zip(prompts, budgets)):
        solo = Scheduler(eng).serve([Request(p, max_new_tokens=b)])
        np.testing.assert_array_equal(rep["results"][i]["tokens"],
                                      solo["results"][0]["tokens"])


# ---------------------------------------------------------------------------
# property-style: random workloads keep the invariants
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(n_requests=st.integers(1, 7), budget=st.integers(1, 9),
       seed=st.integers(0, 2**31 - 1))
def test_random_workload_invariants(n_requests, budget, seed):
    eng = get_engine(batch=2)                # hypothesis can't use fixtures
    rng = np.random.default_rng(seed)
    reqs = [Request(rng.integers(1, 200, size=4).astype(np.int32),
                    max_new_tokens=int(rng.integers(1, budget + 1)))
            for _ in range(n_requests)]
    rep = Scheduler(eng).serve(reqs)
    assert rep["n_requests"] == n_requests
    assert all(r.status == "finished" for r in reqs)
    for req, res in zip(sorted(reqs, key=lambda r: r.rid), rep["results"]):
        assert res["n_new"] == req.max_new_tokens  # no EOS id ⇒ exact budget
        assert 1.0 <= res["acceptance_length"] <= eng.ecfg.K + 1 or \
            res["iters"] == 0


@settings(max_examples=3, deadline=None)
@given(n_requests=st.integers(1, 6), budget=st.integers(1, 8),
       seed=st.integers(0, 2**31 - 1))
def test_random_workload_invariants_paged(n_requests, budget, seed):
    """The same lifecycle invariants hold through the paged engine — with
    variable prompt lengths (exercising bucketed admission and partial
    pages) — and the page pool drains to empty afterwards."""
    eng = get_engine(batch=2, kv_layout="paged")
    rng = np.random.default_rng(seed)
    reqs = [Request(rng.integers(1, 200,
                                 size=int(rng.integers(1, 10))).astype(
                        np.int32),
                    max_new_tokens=int(rng.integers(1, budget + 1)))
            for _ in range(n_requests)]
    rep = Scheduler(eng).serve(reqs)
    assert rep["n_requests"] == n_requests
    assert all(r.status == "finished" for r in reqs)
    for req, res in zip(sorted(reqs, key=lambda r: r.rid), rep["results"]):
        assert res["n_new"] == req.max_new_tokens
    assert eng.allocator.n_free == eng.pool_pages
    assert eng.allocator.n_used == 0


# ---------------------------------------------------------------------------
# vlm/encdec admission: per-request extras plumbed through the scheduler
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def _modality_engine(arch, batch=2):
    tcfg = get_config(arch).reduced()
    m = get_model(tcfg)
    return Engine(tcfg, None, m.init(KEY), None,
                  EngineConfig(K=0, max_new_tokens=6, drafter_mode="none",
                               max_len=64), batch)


@pytest.mark.parametrize("arch", ["internvl2-1b", "whisper-base"])
def test_vlm_encdec_scheduler_serve(arch):
    """Formerly the strict-xfail red test for the ROADMAP extras follow-up:
    serving a vlm/encdec request through the continuous scheduler end-to-end
    (extras default to a deterministic per-prompt stub frontend)."""
    eng = _modality_engine(arch)
    rep = Scheduler(eng).serve(
        [Request(np.asarray([3, 4, 5], np.int32), max_new_tokens=2)])
    assert rep["n_requests"] == 1
    assert rep["results"][0]["n_new"] == 2


@pytest.mark.parametrize("arch", ["internvl2-1b", "whisper-base"])
def test_vlm_encdec_extras_match_whole_batch(arch):
    """Explicit per-request extras through per-slot admission must reproduce
    the whole-batch Engine.run with the same extras token-for-token — the
    extras really reach the frontend, they aren't dropped."""
    tcfg = get_config(arch).reduced()
    extras = make_extras(tcfg, 1, "prefill", jax.random.fold_in(KEY, 5))
    prompt = np.asarray([7, 9, 11, 2], np.int32)
    solo = Engine(tcfg, None, get_model(tcfg).init(KEY), None,
                  EngineConfig(K=0, max_new_tokens=5, drafter_mode="none",
                               max_len=64), 1)
    ref = solo.run(prompt[None], extras)
    P = prompt.size + solo.pos_offset
    want = np.asarray(ref["tokens"])[0, P:P + 5]
    rep = Scheduler(_modality_engine(arch)).serve(
        [Request(prompt, max_new_tokens=5, extras=extras)])
    np.testing.assert_array_equal(rep["results"][0]["tokens"], want)
