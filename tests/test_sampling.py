"""Per-request SamplingParams API: validation, logit warping, deterministic
PRNG streams, and the mixed-policy serving invariants.

The acceptance pins of the SamplingParams redesign:

- **per-request determinism**: a seeded sampled request's tokens are a pure
  function of ``(seed, prompt)`` — bitwise identical across runs, batch
  compositions, slot indices, KV layouts, and mesh sizes;
- **mixed-policy batches**: greedy and sampled requests share one jitted
  step per layout, and the greedy rows emit exactly what a pure-greedy
  engine emits (the pre-redesign output);
- **deprecation**: ``EngineConfig(greedy=...)`` still works but emits
  exactly one DeprecationWarning;
- warp correctness (temperature / top-k / top-p) and the spec_decode
  robustness fixes (zero-active stats guard, explicit residual
  renormalization) are unit-tested directly.
"""
import warnings
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DrafterConfig, get_config
from repro.core import drafter as D
from repro.core import spec_decode as SD
from repro.models import get_model
from repro.serving import (Engine, EngineConfig, LLMEngine, Request,
                           SamplingParams, Scheduler)
from repro.sharding.utils import serving_mesh

from conftest import require_devices  # noqa: E402  (tests dir on sys.path)

KEY = jax.random.PRNGKey(23)


# ---------------------------------------------------------------------------
# SamplingParams validation + EngineConfig deprecation
# ---------------------------------------------------------------------------

def test_sampling_params_validation():
    SamplingParams(temperature=0.7, top_k=5, top_p=0.9, seed=3,
                   stop_token_ids=(7,), max_new_tokens=4)   # all fine
    assert SamplingParams.greedy().is_greedy
    assert not SamplingParams(temperature=0.1).is_greedy
    for bad in [dict(temperature=-0.1), dict(temperature=float("inf")),
                dict(top_k=-1), dict(top_p=0.0), dict(top_p=1.5),
                dict(seed=1.5), dict(max_new_tokens=0)]:
        with pytest.raises(ValueError):
            SamplingParams(**bad)


def test_engine_config_greedy_deprecated_exactly_once():
    """The alias still constructs a working default SamplingParams but warns
    exactly once per construction."""
    for flag, want_greedy in [(True, True), (False, False)]:
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            cfg = EngineConfig(greedy=flag)
        dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
        assert len(dep) == 1, f"greedy={flag}: {len(dep)} warnings"
        assert cfg.sampling.is_greedy == want_greedy
    # the replacement spelling is silent
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        cfg = EngineConfig(sampling=SamplingParams(temperature=0.5, seed=9))
        EngineConfig()
    assert not [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert cfg.sampling.temperature == 0.5


# ---------------------------------------------------------------------------
# warp + spec_decode units
# ---------------------------------------------------------------------------

def _warp1(logits, **kw):
    sp = dict(temperature=1.0, top_k=0, top_p=1.0)
    sp.update(kw)
    return np.asarray(SD.warp_probs(
        jnp.asarray(logits, jnp.float32)[None, None, :],
        jnp.full((1,), sp["temperature"], jnp.float32),
        jnp.full((1,), sp["top_k"], jnp.int32),
        jnp.full((1,), sp["top_p"], jnp.float32)))[0, 0]


def test_warp_temperature_scales_logits():
    logits = [0.0, 1.0, 2.0, -1.0]
    for t in (0.5, 1.0, 2.0):
        want = np.asarray(jax.nn.softmax(jnp.asarray(logits) / t))
        np.testing.assert_allclose(_warp1(logits, temperature=t), want,
                                   rtol=1e-6)


def test_warp_top_k_masks_and_renormalizes():
    p = _warp1([3.0, 2.0, 1.0, 0.0], top_k=2)
    assert p[2] == 0.0 and p[3] == 0.0
    np.testing.assert_allclose(p.sum(), 1.0, rtol=1e-6)
    want = np.asarray(jax.nn.softmax(jnp.asarray([3.0, 2.0])))
    np.testing.assert_allclose(p[:2], want, rtol=1e-6)


def test_warp_top_p_keeps_minimal_nucleus():
    # probs ~ [0.643, 0.237, 0.087, 0.032]: top_p=0.8 keeps the first two
    p = _warp1([3.0, 2.0, 1.0, 0.0], top_p=0.8)
    assert p[2] == 0.0 and p[3] == 0.0 and p[0] > p[1] > 0
    np.testing.assert_allclose(p.sum(), 1.0, rtol=1e-6)
    # top-1 always kept even under a degenerate top_p from a blank slot
    p = _warp1([3.0, 2.0, 1.0, 0.0], top_p=1e-9)
    assert np.isfinite(p).all() and p[0] == 1.0


def test_sample_token_greedy_rows_are_argmax():
    logits = jax.random.normal(KEY, (4, 16))
    keys = jax.random.split(jax.random.PRNGKey(1), 4)
    t = jnp.asarray([0.0, 0.0, 1.0, 1.0])
    tok = SD.sample_token(keys, logits, t, jnp.zeros(4, jnp.int32),
                          jnp.ones(4))
    np.testing.assert_array_equal(np.asarray(tok[:2]),
                                  np.asarray(jnp.argmax(logits[:2], -1)))
    # sampled rows: deterministic per key
    tok2 = SD.sample_token(keys, logits, t, jnp.zeros(4, jnp.int32),
                           jnp.ones(4))
    np.testing.assert_array_equal(np.asarray(tok), np.asarray(tok2))


def test_acceptance_stats_zero_active_guard():
    s = SD.update_acceptance_stats({}, jnp.array([2, 3]),
                                   active=jnp.array([False, False]))
    assert int(s["iters"]) == 0 and int(s["tokens"]) == 0
    assert np.isfinite(float(s["mean"]))          # no 0/0 NaN
    s = SD.update_acceptance_stats(s, jnp.array([2, 3]),
                                   active=jnp.array([True, False]))
    assert (int(s["iters"]), int(s["tokens"])) == (1, 3)
    assert float(s["mean"]) == 3.0
    assert SD.acceptance_length(s) == 3.0


def test_rejection_residual_renormalization_exact():
    """Deterministic rejection: q is a delta on token 0, p a delta on token
    1 — the draft is always rejected and the residual norm(max(p-q, 0)) is a
    delta on token 1, with no epsilon fudge leaking probability elsewhere."""
    V = 6
    q = jnp.zeros((1, 1, V)).at[0, 0, 0].set(1.0)
    p = jnp.zeros((1, 2, V)).at[:, :, 1].set(1.0)
    for s in range(5):
        acc, committed = SD.rejection_verify(
            jax.random.PRNGKey(s), jnp.zeros((1, 1), jnp.int32), q, p)
        assert int(acc[0]) == 0
        assert int(committed[0, 0]) == 1          # exactly the residual token
    # p == q exactly: the residual is all-zero; the guarded renormalization
    # falls back to the target row instead of emitting NaN
    acc, committed = SD.rejection_verify(
        KEY, jnp.zeros((1, 1), jnp.int32), p[:, :1], p)
    assert np.isfinite(np.asarray(committed)).all()
    assert int(committed[0, 0]) == 1


def test_deterministic_draft_one_hot_proposal_is_lossless():
    """The engine's drafts are argmax — a deterministic proposal — so it
    verifies them against a ONE-HOT draft distribution: accept w.p. p(d),
    residual norm(p masked at d). The committed token's empirical
    distribution must then match the target p exactly, whatever token the
    drafter proposed. (Using the drafter softmax as q here would
    over-accept the drafter's argmax — the bias this test guards against.)"""
    V, N = 8, 30_000
    key = jax.random.PRNGKey(3)
    p = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (V,)))
    d = int(jnp.argmax(p))                        # worst case: most-likely
    q = jax.nn.one_hot(jnp.asarray([d]), V)[None]

    def one(k):
        _, committed = SD.rejection_verify(
            k, jnp.asarray([[d]], jnp.int32), q, jnp.stack([p, p])[None])
        return committed[0, 0]

    toks = jax.vmap(one)(jax.random.split(key, N))
    emp = np.bincount(np.asarray(toks), minlength=V) / N
    np.testing.assert_allclose(emp, np.asarray(p), atol=0.015)


# ---------------------------------------------------------------------------
# serving invariants (determinism, mixed policy, layouts, mesh)
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def _setup():
    tcfg = get_config("qwen2-1.5b").reduced()
    m = get_model(tcfg)
    tparams = m.init(KEY)
    dcfg = DrafterConfig(n_layers=1, k_infer=2).resolve(tcfg)
    dparams = D.init_params(dcfg, tcfg, jax.random.fold_in(KEY, 1))
    return tcfg, dcfg, tparams, dparams


@lru_cache(maxsize=None)
def get_engine(kv_layout="contiguous", batch=2, shard=0, bucket=True):
    tcfg, dcfg, tparams, dparams = _setup()
    return Engine(tcfg, dcfg, tparams, dparams,
                  EngineConfig(K=2, max_new_tokens=8,
                               drafter_mode="parallel", max_len=64,
                               kv_layout=kv_layout, page_size=8,
                               bucket_prefill=bucket, shard_model=shard > 0,
                               mesh=serving_mesh(shard) if shard else None),
                  batch)


def _prompts(n, seed=0, lo=4, hi=10):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, 200, size=int(rng.integers(lo, hi))
                         ).astype(np.int32) for _ in range(n)]


SAMPLED = SamplingParams(temperature=0.8, top_k=40, top_p=0.95, seed=1234)


def test_same_seed_same_tokens_regardless_of_batch_composition():
    """The determinism acceptance pin: one seeded request's tokens are
    identical whether it runs alone, first, last, or among different
    neighbors — per-row keys make the stream independent of everything but
    (seed, prompt)."""
    eng = get_engine()
    target = _prompts(1, seed=3)[0]
    others = _prompts(4, seed=4)
    solo = Scheduler(eng).serve(
        [Request(target, sampling=SAMPLED)])["results"][0]["tokens"]
    for order in ([target] + others, others + [target],
                  others[:2] + [target] + others[2:]):
        reqs = [Request(p, sampling=SAMPLED if p is target else None)
                for p in order]
        rep = Scheduler(eng).serve(reqs)
        got = [r for q, r in zip(sorted(reqs, key=lambda r: r.rid),
                                 rep["results"]) if q.sampling == SAMPLED]
        assert len(got) == 1
        np.testing.assert_array_equal(
            got[0]["tokens"], solo,
            err_msg="seeded stream changed with batch composition")


@pytest.mark.parametrize("shard", [0, 4, 8])
def test_mixed_policy_cross_layout_losslessness(shard):
    """A batch mixing greedy and seeded sampled requests: paged + bucketed
    (and optionally model-sharded over ``shard`` forced host devices)
    equals the contiguous exact-length single-device engine bitwise — for
    BOTH policies. One jitted step per layout serves the whole mix."""
    if shard:
        require_devices(shard)
    prompts = _prompts(5, seed=7, lo=3, hi=10)
    sps = [SamplingParams.greedy(),
           SamplingParams(temperature=0.7, seed=1),
           SamplingParams(temperature=1.0, top_p=0.9, seed=2),
           None,                                  # engine default (greedy)
           SamplingParams(temperature=0.5, top_k=25, seed=3)]
    reqs = lambda: [Request(p, max_new_tokens=6, sampling=sp)   # noqa: E731
                    for p, sp in zip(prompts, sps)]
    ref = Scheduler(get_engine(bucket=False)).serve(reqs())
    eng = get_engine("paged", shard=shard)
    got = Scheduler(eng).serve(reqs())
    for r, g in zip(ref["results"], got["results"]):
        np.testing.assert_array_equal(
            r["tokens"], g["tokens"],
            err_msg=f"rid {r['rid']} diverged across layouts (shard={shard})")
    assert eng.allocator.n_free == eng.pool_pages


def test_mixed_batch_greedy_rows_match_pure_greedy_engine():
    """Greedy rows of a mixed batch must emit exactly what the engine
    emitted before the redesign — pinned by comparing against an engine
    whose every request is default-greedy (itself pinned lossless vs
    vanilla AR by tests/test_serving.py)."""
    eng = get_engine()
    prompts = _prompts(4, seed=11)
    all_greedy = Scheduler(eng).serve(
        [Request(p, max_new_tokens=7) for p in prompts])
    sps = [None, SamplingParams(temperature=1.0, seed=5), None,
           SamplingParams(temperature=0.8, seed=6)]
    mixed = Scheduler(eng).serve(
        [Request(p, max_new_tokens=7, sampling=sp)
         for p, sp in zip(prompts, sps)])
    for i in (0, 2):                              # the greedy rows
        np.testing.assert_array_equal(
            mixed["results"][i]["tokens"], all_greedy["results"][i]["tokens"],
            err_msg="greedy row perturbed by sampled neighbors")
    for i in (1, 3):                              # sampled rows differ
        assert not np.array_equal(mixed["results"][i]["tokens"],
                                  all_greedy["results"][i]["tokens"])


def test_sampled_rows_reproducible_across_runs_and_seeds_distinct():
    eng = get_engine()
    p = _prompts(1, seed=13)[0]
    runs = [Scheduler(eng).serve(
        [Request(p, sampling=SamplingParams(temperature=0.9, seed=s))]
        )["results"][0]["tokens"] for s in (42, 42, 43)]
    np.testing.assert_array_equal(runs[0], runs[1])
    assert not np.array_equal(runs[0], runs[2])


def test_sampling_max_new_tokens_and_stop_ids():
    """Budget precedence (SamplingParams.max_new_tokens) and per-request
    stop_token_ids trimming (vLLM semantics: stop token included)."""
    eng = get_engine()
    p = _prompts(1, seed=17)[0]
    sp = SamplingParams(temperature=0.0, max_new_tokens=5)
    rep = Scheduler(eng).serve([Request(p, sampling=sp)])
    assert rep["results"][0]["n_new"] == 5
    full = rep["results"][0]["tokens"].tolist()
    stop = full[2]
    rep2 = Scheduler(eng).serve([Request(p, sampling=SamplingParams(
        temperature=0.0, max_new_tokens=5, stop_token_ids=(stop,)))])
    assert rep2["results"][0]["tokens"].tolist() == full[:3]
    assert rep2["results"][0]["tokens"][-1] == stop


def test_llm_engine_generate_front_end():
    """vLLM-style LLMEngine.generate: outputs in prompt order, per-prompt
    SamplingParams (broadcast or list), mixed batch in one call."""
    eng = get_engine()
    prompts = _prompts(3, seed=19)
    llm = LLMEngine(eng)
    outs = llm.generate(prompts, SamplingParams(temperature=0.8, seed=2,
                                                max_new_tokens=4))
    assert len(outs) == 3 and all(o["n_new"] == 4 for o in outs)
    # per-prompt list, mixed policies; order preserved under re-submission
    sps = [None, SamplingParams(temperature=0.8, seed=2), None]
    a = llm.generate(prompts, sps)
    b = llm.generate(list(reversed(prompts)), list(reversed(sps)))
    for x, y in zip(a, reversed(b)):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
    assert llm.last_report is not None and llm.last_report["n_requests"] == 3
    with pytest.raises(ValueError, match="sampling_params"):
        llm.generate(prompts, [None])
