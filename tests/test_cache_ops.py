"""cache_ops invariants: per-slot surgery roundtrips across every registered
model family, page-pool allocator hygiene, and the bucketed-prefill retrace
bound.

The slot-surgery properties are the correctness backbone of mid-stream
admission (scheduler → engine → cache_ops): writing a batch-1 state into
slot j then reading it back must be the identity, and every other slot must
be bit-identical — for stacked super-block KV, ring buffers, recurrent
snapshots, paged pools, and drafter caches alike, since ``batch_axes``
infers the layout structurally.
"""
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import DrafterConfig, get_config
from repro.core import drafter as D
from repro.models import get_model, make_extras
from repro.serving import Engine, EngineConfig, Request, Scheduler, cache_ops

KEY = jax.random.PRNGKey(3)

# one representative reduced arch per registered family
FAMILY_ARCHS = {
    "dense": "qwen2-1.5b",
    "moe": "dbrx-132b",
    "ssm": "mamba2-780m",
    "hybrid": "recurrentgemma-2b",
    "vlm": "internvl2-1b",
    "encdec": "whisper-base",
}
BATCH = 3


@lru_cache(maxsize=None)
def _setup(family: str):
    tcfg = get_config(FAMILY_ARCHS[family]).reduced()
    m = get_model(tcfg)
    tparams = m.init(KEY)
    dcfg = DrafterConfig(n_layers=1, k_infer=2).resolve(tcfg)
    dparams = D.init_params(dcfg, tcfg, jax.random.fold_in(KEY, 1))
    return tcfg, dcfg, tparams, dparams


def fresh_engine(family: str, **ecfg_kw):
    """Uncached engine (fresh jit caches — the retrace tests count them)."""
    tcfg, dcfg, tparams, dparams = _setup(family)
    kw = dict(K=2, max_new_tokens=8, drafter_mode="parallel", max_len=64,
              page_size=8)
    kw.update(ecfg_kw)
    return Engine(tcfg, dcfg, tparams, dparams, EngineConfig(**kw), BATCH)


@lru_cache(maxsize=None)
def get_engine(family: str, kv_layout: str = "contiguous"):
    return fresh_engine(family, kv_layout=kv_layout)


def _prefill_src(eng, seed: int):
    tcfg = eng.tcfg
    prompt = jax.random.randint(jax.random.fold_in(KEY, seed), (1, 4), 1,
                                tcfg.vocab_size - 2)
    extras = (make_extras(tcfg, 1, "prefill", KEY)
              if tcfg.family in ("vlm", "encdec") else {})
    return eng.prefill(prompt, extras)


def _rows(tree, axes, slot: int):
    """Slice batch row ``slot`` out of every batched leaf."""
    return jax.tree.map(
        lambda leaf, ax: leaf if ax < 0
        else jax.lax.index_in_dim(leaf, slot, axis=ax, keepdims=True),
        tree, axes)


def _assert_trees_equal(a, b, msg):
    def chk(path, x, y):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y),
            err_msg=f"{msg} at {jax.tree_util.keystr(path)}")
    jax.tree_util.tree_map_with_path(chk, a, b)


# ---------------------------------------------------------------------------
# write_slot / reset_slot roundtrip properties (every family)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
@settings(max_examples=3, deadline=None)
@given(slot=st.integers(0, BATCH - 1), seed=st.integers(0, 2**31 - 1))
def test_write_slot_roundtrip_identity(family, slot, seed):
    """write(src → slot j) then read(slot j) == src row 0, bit-exact."""
    eng = get_engine(family)
    axes = eng.slot_axes
    blank = eng.blank_state()
    src = _prefill_src(eng, seed)
    out = cache_ops.write_slot(blank, src, jnp.asarray(slot, jnp.int32), axes)
    _assert_trees_equal(_rows(out, axes, slot), _rows(src, axes, 0),
                        f"{family}: slot {slot} readback != src")


@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
@settings(max_examples=3, deadline=None)
@given(slot=st.integers(0, BATCH - 1), seed=st.integers(0, 2**31 - 1))
def test_write_slot_neighbors_untouched(family, slot, seed):
    eng = get_engine(family)
    axes = eng.slot_axes
    blank = eng.blank_state()
    src = _prefill_src(eng, seed)
    out = cache_ops.write_slot(blank, src, jnp.asarray(slot, jnp.int32), axes)
    for other in range(BATCH):
        if other == slot:
            continue
        _assert_trees_equal(_rows(out, axes, other), _rows(blank, axes, other),
                            f"{family}: neighbor slot {other} perturbed")


@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
@settings(max_examples=3, deadline=None)
@given(slot=st.integers(0, BATCH - 1), seed=st.integers(0, 2**31 - 1))
def test_reset_slot_restores_blank(family, slot, seed):
    """write then reset returns the slot (and the whole state) to blank."""
    eng = get_engine(family)
    axes = eng.slot_axes
    blank = eng.blank_state()
    src = _prefill_src(eng, seed)
    out = cache_ops.write_slot(blank, src, jnp.asarray(slot, jnp.int32), axes)
    out = cache_ops.reset_slot(out, jnp.asarray(slot, jnp.int32), axes,
                               fills={"new_count": eng.ecfg.max_new_tokens})
    for s in range(BATCH):
        _assert_trees_equal(_rows(out, axes, s), _rows(blank, axes, s),
                            f"{family}: slot {s} not blank after reset")


def _scrub_invalid_kv(tree):
    """Zero K/V entries whose position slot is empty (-1): unallocated page
    regions gather arbitrary pool bytes that no attention path can read, so
    equality is defined up to them."""
    def walk(node):
        if isinstance(node, dict) and {"k", "v", "positions"} <= set(node):
            ok = (node["positions"] >= 0)[..., None, None]
            return {**node, "k": jnp.where(ok, node["k"], 0),
                    "v": jnp.where(ok, node["v"], 0)}
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        return node
    return walk(tree)


@pytest.mark.parametrize("family", ["dense", "ssm", "hybrid"])
def test_paged_admit_roundtrip_identity(family):
    """Paged twin of the roundtrip: admitting through page scatter then
    gathering the view back must reproduce the contiguous admission
    bit-exactly (up to unreadable K/V under empty position slots), with
    neighbor slots blank; freeing returns every page."""
    engc = get_engine(family)
    engp = get_engine(family, "paged")
    prompt = np.asarray([5, 9, 2, 11, 4], np.int32)
    slot = 1
    sc, fc, lc = engc.prefill_into_slot(engc.blank_state(), prompt, slot)
    sp, fp, lp = engp.prefill_into_slot(engp.blank_state(), prompt, slot)
    assert (fc, lc) == (fp, lp)
    axes = engc.slot_axes         # axes of the *contiguous view* structure
    view = cache_ops.gather_state(
        {k: v for k, v in sp.items() if k != "block_table"},
        sp["block_table"], engp.pspec)
    view, sc = _scrub_invalid_kv(view), _scrub_invalid_kv(sc)
    for s in range(BATCH):
        _assert_trees_equal(_rows(view, axes, s), _rows(sc, axes, s),
                            f"{family}: paged view slot {s} != contiguous")
    sp = engp.free_slot(sp, slot)
    assert eng_pool_restored(engp)
    assert int(sp["block_table"][slot].max()) == -1


def eng_pool_restored(eng) -> bool:
    return (eng.allocator.n_free == eng.pool_pages
            and eng.allocator.n_used == 0
            and all(not ps for ps in eng._slot_pages))


# ---------------------------------------------------------------------------
# BlockAllocator unit tests
# ---------------------------------------------------------------------------

def test_allocator_alloc_free_cycle():
    a = cache_ops.BlockAllocator(8)
    p1 = a.alloc(3)
    p2 = a.alloc(5)
    assert sorted(p1 + p2) == list(range(8)) and a.n_free == 0
    assert a.alloc(1) is None          # exhausted: caller waits, no raise
    a.free(p1)
    assert a.n_free == 3
    p3 = a.alloc(2)
    assert set(p3) <= set(p1)
    a.free(p2)
    a.free(p3)
    assert a.n_free == 8 and a.n_used == 0


def test_allocator_rejects_double_free_and_foreign():
    a = cache_ops.BlockAllocator(4)
    p = a.alloc(2)
    a.free(p)
    with pytest.raises(ValueError):
        a.free(p)                      # double free
    with pytest.raises(ValueError):
        a.free([99])                   # never allocated


@settings(max_examples=10, deadline=None)
@given(n_pages=st.integers(1, 32), seed=st.integers(0, 2**31 - 1))
def test_allocator_never_leaks_or_aliases(n_pages, seed):
    rng = np.random.default_rng(seed)
    a = cache_ops.BlockAllocator(n_pages)
    live = []
    for _ in range(50):
        if live and rng.random() < 0.4:
            a.free(live.pop(int(rng.integers(len(live)))))
        else:
            got = a.alloc(int(rng.integers(0, n_pages + 1)))
            if got is not None:
                live.append(got)
        flat = [p for ps in live for p in ps]
        assert len(flat) == len(set(flat)), "aliased pages"
        assert len(flat) + a.n_free == n_pages, "leaked pages"
    for ps in live:
        a.free(ps)
    assert a.n_free == n_pages


def test_allocator_refcount_semantics():
    """Refcounted frees: a page returns to the free list only when every
    holder has released it — the sharing substrate of the prefix cache."""
    a = cache_ops.BlockAllocator(4)
    p = a.alloc(1)[0]
    assert a.refcount(p) == 1
    a.incref([p])
    a.incref([p])
    assert a.refcount(p) == 3
    a.free([p])
    a.free([p])
    assert a.refcount(p) == 1 and a.n_free == 3   # still held
    a.free([p])
    assert a.refcount(p) == 0 and a.n_free == 4   # now recycled
    with pytest.raises(ValueError):
        a.free([p])                    # past zero == double free
    with pytest.raises(ValueError):
        a.incref([p])                  # can't revive a freed page
    with pytest.raises(ValueError):
        a.incref([99])                 # never allocated


def test_allocator_reset_stats():
    a = cache_ops.BlockAllocator(8)
    p = a.alloc(6)
    assert a.peak_used == 6
    a.free(p[2:])
    assert a.peak_used == 6            # peak is sticky ...
    a.reset_stats()
    assert a.peak_used == 2            # ... until reset re-bases it to now
    a.alloc(3)
    assert a.peak_used == 5


@settings(max_examples=10, deadline=None)
@given(n_pages=st.integers(1, 16), seed=st.integers(0, 2**31 - 1))
def test_allocator_refcounts_never_leak_or_alias(n_pages, seed):
    """Random alloc/incref/decref churn against a host-side model: the
    allocator's refcounts track the model exactly, distinct live pages plus
    the free list always cover the pool, and nothing is ever handed out
    twice while held."""
    rng = np.random.default_rng(seed)
    a = cache_ops.BlockAllocator(n_pages)
    refs: dict = {}                    # page -> expected refcount
    for _ in range(80):
        r = rng.random()
        if refs and r < 0.35:          # decref a random holder
            p = int(rng.choice(list(refs)))
            a.free([p])
            refs[p] -= 1
            if refs[p] == 0:
                del refs[p]
        elif refs and r < 0.55:        # share a random live page
            p = int(rng.choice(list(refs)))
            a.incref([p])
            refs[p] += 1
        else:
            got = a.alloc(int(rng.integers(0, n_pages + 1)))
            if got is not None:
                assert not set(got) & set(refs), "aliased a held page"
                for p in got:
                    refs[p] = 1
        assert a.n_used == len(refs), "live-page count drifted"
        assert a.n_used + a.n_free == n_pages, "leaked pages"
        for p, want in refs.items():
            assert a.refcount(p) == want
    for p, want in list(refs.items()):
        a.free([p] * want)
    assert a.n_free == n_pages and a.n_used == 0


def test_recycled_page_reads_empty():
    """Blank-on-alloc pin: pages recycled through free/alloc — including the
    decode-time growth path, which scatters nothing into the new page — must
    gather as empty (positions -1), not as the previous tenant's stale KV.
    (Blanking at free time is no longer possible: under refcounted sharing a
    freed slot's pages may still be mapped by the prefix cache.)"""
    eng = fresh_engine("dense", kv_layout="paged", kv_growth="incremental")
    rng = np.random.default_rng(0)
    state = eng.blank_state()
    # tenant A dirties every pool page it can: long prompt, then freed
    long = rng.integers(1, eng.tcfg.vocab_size - 2, size=16).astype(np.int32)
    state, _, _ = eng.prefill_into_slot(state, long, 0)
    state = eng.free_slot(state, 0)
    # tenant B: short prompt, then pure growth over recycled pages
    short = np.asarray([3, 1, 4], np.int32)
    state, _, last = eng.prefill_into_slot(state, short, 0)
    state, ok = eng.ensure_capacity(state, 0, 24)   # 3 pages, 2 recycled
    assert ok
    view = cache_ops.gather_state(
        {k: v for k, v in state.items() if k != "block_table"},
        state["block_table"], eng.pspec)

    # any surviving entry from tenant A would carry a position in
    # (last, 16) — stale history the attention mask would treat as valid
    def check(node):
        if isinstance(node, dict) and "positions" in node:
            pos = np.asarray(node["positions"])
            valid = pos[pos >= 0]
            assert valid.size, "tenant B's own entries missing"
            assert valid.max() <= last, \
                f"recycled page leaked stale positions: {np.unique(valid)}"
        elif isinstance(node, dict):
            for v in node.values():
                check(v)
    check({k: v for k, v in view.items() if k in ("tcache", "dcache")})


def test_no_page_leak_after_eos_and_rollback():
    """A full paged serve — speculative rollback-invalidation every
    iteration, EOS mid-stream retiring slots — must return every page."""
    eng = get_engine("dense", "paged")
    prompts = [np.asarray([5, 6, 7, 8, 9][:n], np.int32)
               for n in (3, 4, 5, 2, 5)]
    ref = Scheduler(eng).serve([Request(p, max_new_tokens=6)
                                for p in prompts])
    eos = int(ref["results"][0]["tokens"][2])   # EOS hit mid-decode
    rep = Scheduler(eng, eos_id=eos).serve([Request(p, max_new_tokens=6)
                                            for p in prompts])
    assert rep["n_requests"] == len(prompts)
    assert eng_pool_restored(eng)


def test_pool_smaller_than_slots_serializes_admission():
    """With a pool that fits only one request, admissions serialize through
    the free list but every request still completes with exact tokens."""
    eng = get_engine("dense", "paged")
    tight = fresh_engine("dense", kv_layout="paged", pool_pages=3)
    prompts = [np.asarray([3, 4, 5], np.int32),
               np.asarray([7, 8, 9, 10], np.int32)]
    rep_ref = Scheduler(eng).serve([Request(p, max_new_tokens=4)
                                    for p in prompts])
    rep = Scheduler(tight).serve([Request(p, max_new_tokens=4)
                                  for p in prompts])
    for a, b in zip(rep_ref["results"], rep["results"]):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert tight.allocator.n_free == 3


# ---------------------------------------------------------------------------
# bucketed-prefill retrace bound
# ---------------------------------------------------------------------------

def _admit_lengths(eng, lengths):
    rng = np.random.default_rng(0)
    for n in lengths:
        state = eng.blank_state()
        prompt = rng.integers(1, eng.tcfg.vocab_size - 2,
                              size=int(n)).astype(np.int32)
        eng.prefill_into_slot(state, prompt, 0)
        if eng.paged:
            eng.free_slot(state, 0)


def test_prefill_retrace_bound_padded():
    """N distinct prompt lengths compile at most ceil(log2(max_len)) padded
    prefill traces (the jit cache-size counter is the compile count)."""
    eng = fresh_engine("dense")
    max_len = eng.ecfg.max_len
    bound = int(np.ceil(np.log2(max_len)))
    lengths = list(range(1, 13))       # 12 distinct lengths > bound
    assert len(lengths) > bound
    _admit_lengths(eng, lengths)
    assert eng._prefill_pad._cache_size() <= bound
    assert eng._prefill._cache_size() == 0     # exact-length path never used


def test_prefill_retrace_bound_chunked():
    """Recurrent families chunk instead of pad: prefill traces are bounded
    by the distinct leading buckets, chunk traces by the distinct trailing
    ones — both within ceil(log2(max_len))."""
    eng = fresh_engine("ssm")
    bound = int(np.ceil(np.log2(eng.ecfg.max_len)))
    _admit_lengths(eng, list(range(1, 13)))
    assert eng._prefill._cache_size() <= bound
    assert eng._chunk._cache_size() <= bound
    assert eng._prefill_pad._cache_size() == 0


def test_incremental_growth_retrace_bound():
    """Decode-time ``ensure_capacity`` must not add jit traces per page
    count: the block-table row update is ONE trace for every (slot, page
    count) combination — slot index and the full-width row are both traced
    — and the paged step itself never retraces. A workload whose slots
    cross page boundaries at many distinct counts pins the bound."""
    eng = fresh_engine("dense", kv_layout="paged")
    rng = np.random.default_rng(2)
    prompts = [rng.integers(1, eng.tcfg.vocab_size - 2,
                            size=int(n)).astype(np.int32)
               for n in (3, 5, 7, 4, 6, 2)]
    budgets = [8, 6, 4, 8, 5, 7]
    rep = Scheduler(eng).serve([Request(p, max_new_tokens=b)
                                for p, b in zip(prompts, budgets)])
    assert rep["n_requests"] == len(prompts)
    # exactly one growth trace — and at least one (the workload really did
    # cross page boundaries; 0 would mean the bound wasn't exercised)
    assert eng._set_table_row._cache_size() == 1
    # the step is a {greedy_only: trace} twin pair; an all-greedy workload
    # must compile only the greedy-only twin — one trace total
    assert sum(f._cache_size() for f in eng._paged_step.values()) <= 1
    assert eng_pool_restored(eng)
    # upfront growth never touches the growth path at all
    up = fresh_engine("dense", kv_layout="paged", kv_growth="upfront")
    Scheduler(up).serve([Request(p, max_new_tokens=b)
                         for p, b in zip(prompts, budgets)])
    assert up._set_table_row._cache_size() == 0


def test_prefill_buckets_decomposition():
    assert Engine.prefill_buckets(1) == [1]
    assert Engine.prefill_buckets(8) == [8]
    assert Engine.prefill_buckets(7) == [4, 2, 1]
    assert Engine.prefill_buckets(13) == [8, 4, 1]
    for n in range(1, 200):
        bs = Engine.prefill_buckets(n)
        assert sum(bs) == n and bs == sorted(bs, reverse=True)
        assert all(b & (b - 1) == 0 for b in bs)
