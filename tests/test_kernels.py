"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret
mode on CPU — the kernel body is executed in Python, validating the same
code that runs on TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cod
from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    return (0.5 * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 3e-5


@pytest.mark.parametrize("B,Sq,Skv,H,KV,hd", [
    (2, 128, 128, 4, 2, 64),
    (1, 256, 256, 4, 4, 32),
    (1, 64, 192, 2, 1, 128),       # cross lengths + padding path
    (2, 96, 96, 6, 2, 64),         # non-multiple of block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window,cap", [
    (True, 0, 0.0), (True, 64, 0.0), (True, 0, 50.0), (False, 0, 0.0),
])
def test_flash_attention_sweep(B, Sq, Skv, H, KV, hd, dtype, causal,
                               window, cap):
    k = jax.random.PRNGKey(0)
    q = _rand(k, (B, Sq, H, hd), dtype)
    kk = _rand(jax.random.fold_in(k, 1), (B, Skv, KV, hd), dtype)
    v = _rand(jax.random.fold_in(k, 2), (B, Skv, KV, hd), dtype)
    o = ops.flash_attention(q, kk, v, scale=hd ** -0.5, causal=causal,
                            window=window, softcap=cap,
                            block_q=64, block_k=64)
    r = ref.attention_reference(q, kk, v, scale=hd ** -0.5, causal=causal,
                                window=window, softcap=cap)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("n,K,r", [(48, 4, 0.7), (32, 8, 0.8),
                                   (24, 2, 0.5)])
@pytest.mark.parametrize("B,H,KV,hd", [(2, 4, 2, 64), (1, 2, 2, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mtp_attention_sweep(n, K, r, B, H, KV, hd, dtype):
    rng = np.random.default_rng(0)
    pos_np, dep_np = cod.sample_cod(rng, n, K, r)
    M = int(np.ceil(cod.expanded_length(n, K, r) / 64) * 64)
    pos_np, dep_np = cod.pad_to(pos_np, dep_np, M)
    pos, dep = jnp.asarray(pos_np), jnp.asarray(dep_np)
    k = jax.random.PRNGKey(1)
    q = _rand(k, (B, M, H, hd), dtype)
    kk = _rand(jax.random.fold_in(k, 1), (B, M, KV, hd), dtype)
    v = _rand(jax.random.fold_in(k, 2), (B, M, KV, hd), dtype)
    o = ops.mtp_attention(q, kk, v, pos, dep, scale=hd ** -0.5,
                          block_q=64, block_k=64)
    r_ = ref.mtp_attention_reference(q, kk, v, pos, dep, scale=hd ** -0.5)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r_, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_mtp_padding_rows_zero():
    rng = np.random.default_rng(0)
    pos_np, dep_np = cod.sample_cod(rng, 16, 3, 0.6)
    m = len(pos_np)
    pos_np, dep_np = cod.pad_to(pos_np, dep_np, 64)
    k = jax.random.PRNGKey(2)
    q = _rand(k, (1, 64, 2, 32), jnp.float32)
    kk = _rand(jax.random.fold_in(k, 1), (1, 64, 2, 32), jnp.float32)
    v = _rand(jax.random.fold_in(k, 2), (1, 64, 2, 32), jnp.float32)
    o = ops.mtp_attention(q, kk, v, jnp.asarray(pos_np), jnp.asarray(dep_np),
                          scale=1.0, block_q=32, block_k=32)
    assert np.abs(np.asarray(o)[:, m:]).max() == 0.0


@pytest.mark.parametrize("B,T,H,KV,hd,S,window", [
    (2, 6, 4, 2, 64, 256, 0),
    (1, 1, 4, 4, 32, 512, 0),
    (2, 6, 4, 2, 64, 256, 64),     # sliding window
    (1, 8, 2, 1, 128, 96, 0),      # pad path
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, T, H, KV, hd, S, window, dtype):
    k = jax.random.PRNGKey(3)
    q = _rand(k, (B, T, H, hd), dtype)
    kk = _rand(jax.random.fold_in(k, 1), (B, S, KV, hd), dtype)
    v = _rand(jax.random.fold_in(k, 2), (B, S, KV, hd), dtype)
    valid = S * 3 // 4
    kpos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    kpos = jnp.where(kpos < valid, kpos, -1)
    qpos = valid - 1 + jnp.broadcast_to(jnp.arange(T)[None],
                                        (B, T)).astype(jnp.int32)
    o = ops.decode_attention(q, kk, v, kpos, qpos, scale=hd ** -0.5,
                             window=window, block_k=64)
    r = ref.decode_reference(q, kk, v, kpos, qpos, scale=hd ** -0.5,
                             window=window)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("B,T,H,KV,hd,NP,page,nb", [
    (2, 6, 4, 2, 64, 12, 16, 4),
    (1, 1, 4, 4, 32, 8, 32, 3),
    (3, 4, 2, 1, 128, 16, 8, 6),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention_sweep(B, T, H, KV, hd, NP, page, nb, dtype):
    """Block-table gather path vs the gather-then-dense oracle, with rows of
    different lengths, unallocated (-1) table entries, and pool pages holding
    *other* rows' positions (must be invisible through the table)."""
    k = jax.random.PRNGKey(5)
    q = _rand(k, (B, T, H, hd), dtype)
    kp_ = _rand(jax.random.fold_in(k, 1), (NP, page, KV, hd), dtype)
    vp_ = _rand(jax.random.fold_in(k, 2), (NP, page, KV, hd), dtype)
    rng = np.random.default_rng(B * 100 + nb)
    # each row owns a distinct prefix of pages; later pages unallocated
    table = np.full((B, nb), -1, np.int32)
    perm = rng.permutation(NP)
    pos_pool = np.full((NP, page), -1, np.int32)
    qpos = np.zeros((B, T), np.int32)
    used = 0
    for b in range(B):
        n_alloc = int(rng.integers(1, nb + 1))
        pages = perm[used:used + n_alloc]
        used += n_alloc
        table[b, :n_alloc] = pages
        length = int(rng.integers(1, n_alloc * page + 1))
        for i, p in enumerate(pages):
            lo = i * page
            fill = np.clip(length - lo, 0, page)
            pos_pool[p, :fill] = lo + np.arange(fill)
        qpos[b] = length - 1 + np.arange(T)
    o = ops.paged_decode_attention(q, kp_, vp_, jnp.asarray(pos_pool),
                                   jnp.asarray(table), jnp.asarray(qpos),
                                   scale=hd ** -0.5)
    r = ref.paged_decode_reference(q, kp_, vp_, jnp.asarray(pos_pool),
                                   jnp.asarray(table), jnp.asarray(qpos),
                                   scale=hd ** -0.5)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_paged_decode_matches_contiguous_kernel():
    """A paged pool whose tables are the identity layout must reproduce the
    contiguous flash-decode kernel exactly (same math, different gather)."""
    B, T, H, KV, hd, S, page = 2, 5, 4, 2, 64, 128, 32
    k = jax.random.PRNGKey(6)
    q = _rand(k, (B, T, H, hd), jnp.float32)
    kk = _rand(jax.random.fold_in(k, 1), (B, S, KV, hd), jnp.float32)
    v = _rand(jax.random.fold_in(k, 2), (B, S, KV, hd), jnp.float32)
    valid = S // 2
    kpos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    kpos = jnp.where(kpos < valid, kpos, -1)
    qpos = valid - 1 + jnp.broadcast_to(jnp.arange(T)[None],
                                        (B, T)).astype(jnp.int32)
    o_cont = ops.decode_attention(q, kk, v, kpos, qpos, scale=hd ** -0.5,
                                  block_k=64)
    nb = S // page
    table = jnp.arange(B * nb, dtype=jnp.int32).reshape(B, nb)
    o_paged = ops.paged_decode_attention(
        q, kk.reshape(B * nb, page, KV, hd), v.reshape(B * nb, page, KV, hd),
        kpos.reshape(B * nb, page), table, qpos, scale=hd ** -0.5)
    np.testing.assert_allclose(np.asarray(o_paged), np.asarray(o_cont),
                               atol=3e-5, rtol=3e-5)


def test_kernel_matches_model_attention_path():
    """The Pallas flash kernel and the model's blocked-jnp attention agree
    (they are the TPU/CPU twins of the same math)."""
    from repro.models import layers as L
    k = jax.random.PRNGKey(4)
    B, S, H, KV, hd = 2, 128, 4, 2, 64
    q = _rand(k, (B, S, H, hd), jnp.float32)
    kk = _rand(jax.random.fold_in(k, 1), (B, S, KV, hd), jnp.float32)
    v = _rand(jax.random.fold_in(k, 2), (B, S, KV, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    o_jnp = L.blocked_attention(q, kk, v, scale=hd ** -0.5,
                                mask_fn=L.causal_mask_fn(pos))
    o_pl = ops.flash_attention(q, kk, v, scale=hd ** -0.5, causal=True)
    np.testing.assert_allclose(np.asarray(o_jnp), np.asarray(o_pl),
                               atol=3e-5, rtol=3e-5)
