"""Adaptive speculation controller + sampled-draft proposals: the
acceptance pins of the adaptive-K / warped-proposal PR.

What must hold with ``adaptive_k`` and/or ``draft_sampling`` enabled:

- **greedy losslessness**: greedy rows emit bitwise what the fixed-K
  pre-controller engine emits — the max-K mask only changes *pacing*
  (which iteration a token commits on), never content, because the
  greedy path recovers ``t_star[accept_len]`` = target argmax at every
  depth;
- **per-request determinism**: a seeded sampled request's stream (with
  sampled drafts drawn from the warped drafter distribution) is a pure
  function of ``(seed, prompt)`` — invariant to batch composition, KV
  layout, mesh size, and preempt/resume, because the draft keys are
  ``fold_in``-derived counters over the committed prefix on a salted
  stream disjoint from the verify keys;
- **streamed ≡ virtual twin**: the wall-clock AsyncEngine with the
  controller on yields exactly the virtual-clock Scheduler's streams,
  because the controller is rid-keyed and fed only by the request's own
  harvest deltas — wall pacing never leaks into ``k_row`` decisions;
- **one trace per layout**: ``k_row`` is a traced ``(B,)`` argument of
  the jitted step, so per-row depth changes never recompile (pinned via
  the jit cache size);
- the metrics/health bugfixes ride along: ``health()`` with zero
  completed / all-aborted sessions, and iteration-weighted
  ``update_acceptance_stats`` under a partially idle batch.
"""
import asyncio
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DrafterConfig, get_config
from repro.core import drafter as D
from repro.core import spec_decode as SD
from repro.models import get_model
from repro.serving import (AsyncEngine, Engine, EngineConfig, Request,
                           SamplingParams, Scheduler, SpeculationConfig,
                           SpeculationController, virtual_twin_report)
from repro.serving.sampling import draft_keys
from repro.sharding.utils import serving_mesh

from conftest import require_devices  # noqa: E402  (tests dir on sys.path)

KEY = jax.random.PRNGKey(29)


@lru_cache(maxsize=None)
def _setup():
    tcfg = get_config("qwen2-1.5b").reduced()
    m = get_model(tcfg)
    tparams = m.init(KEY)
    dcfg = DrafterConfig(n_layers=1, k_infer=2).resolve(tcfg)
    dparams = D.init_params(dcfg, tcfg, jax.random.fold_in(KEY, 1))
    return tcfg, dcfg, tparams, dparams


@lru_cache(maxsize=None)
def get_engine(kv_layout="paged", batch=2, shard=0, pool_pages=0,
               sampled_drafts=True, drafter_mode="parallel"):
    tcfg, dcfg, tparams, dparams = _setup()
    return Engine(tcfg, dcfg, tparams, dparams,
                  EngineConfig(K=2, max_new_tokens=8,
                               drafter_mode=drafter_mode, max_len=64,
                               kv_layout=kv_layout, page_size=8,
                               pool_pages=pool_pages,
                               draft_sampling=sampled_drafts,
                               shard_model=shard > 0,
                               mesh=serving_mesh(shard) if shard else None),
                  batch)


def _prompts(n, seed=0, lo=4, hi=10):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, 200, size=int(rng.integers(lo, hi))
                         ).astype(np.int32) for _ in range(n)]


def run(coro, timeout=600):
    return asyncio.run(asyncio.wait_for(coro, timeout))


SAMPLED = SamplingParams(temperature=0.8, top_k=40, top_p=0.95, seed=1234)


# ---------------------------------------------------------------------------
# units: k_row mask in the verifier
# ---------------------------------------------------------------------------

def _verify_inputs(B=3, K=4, V=16, seed=5):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    drafts = jax.random.randint(ks[0], (B, K), 0, V, jnp.int32)
    dlogits = jax.random.normal(ks[1], (B, K, V))
    tlogits = jax.random.normal(ks[2], (B, K + 1, V))
    temperature = jnp.asarray([0.0, 0.8, 1.2], jnp.float32)
    top_k = jnp.zeros((B,), jnp.int32)
    top_p = jnp.ones((B,), jnp.float32)
    q = SD.warp_probs(dlogits, jnp.maximum(temperature, 1e-3), top_k, top_p)
    keys = jax.random.split(ks[3], B)
    return keys, drafts, q, tlogits, temperature, top_k, top_p


def test_k_row_full_depth_is_bitwise_identity():
    """``k_row = K`` must be the exact unmasked verifier — the controller
    in its optimistic state changes nothing."""
    keys, drafts, q, tl, t, tk, tp = _verify_inputs()
    B, K = drafts.shape
    a0, t0 = SD.mixed_verify(keys, drafts, q, tl, t, tk, tp)
    a1, t1 = SD.mixed_verify(keys, drafts, q, tl, t, tk, tp,
                             jnp.full((B,), K, jnp.int32))
    np.testing.assert_array_equal(np.asarray(a0), np.asarray(a1))
    np.testing.assert_array_equal(np.asarray(t0), np.asarray(t1))


def test_k_row_caps_accept_len_per_row():
    """Rows are force-rejected at their own ``k_row``: accept_len never
    exceeds it, and rows at full depth are untouched by neighbors'
    masks (per-row independence of the vmap)."""
    keys, drafts, q, tl, t, tk, tp = _verify_inputs(seed=11)
    B, K = drafts.shape
    full, _ = SD.mixed_verify(keys, drafts, q, tl, t, tk, tp)
    k_row = jnp.asarray([0, 1, K], jnp.int32)
    capped, _ = SD.mixed_verify(keys, drafts, q, tl, t, tk, tp, k_row)
    assert (np.asarray(capped) <= np.asarray(k_row)).all()
    assert int(capped[0]) == 0
    assert int(capped[2]) == int(full[2])   # unmasked row unaffected


def test_k_row_forced_rejection_is_lossless():
    """With the draft masked out at the forced-rejection slot the resample
    must draw from the FULL target distribution: q is zeroed there, so the
    residual norm(max(p - 0, 0)) == p exactly. Empirically the committed
    token at a ``k_row = 0`` slot matches p."""
    V, N = 8, 30_000
    key = jax.random.PRNGKey(7)
    p = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (V,)))
    q = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 2), (1, V)))
    d = int(jnp.argmax(q[0]))

    def one(k):
        _, committed = SD.rejection_verify(
            k, jnp.asarray([[d]], jnp.int32), q[None],
            jnp.stack([p, p])[None], k_row=jnp.zeros((1,), jnp.int32))
        return committed[0, 0]

    toks = jax.vmap(one)(jax.random.split(key, N))
    emp = np.bincount(np.asarray(toks), minlength=V) / N
    np.testing.assert_allclose(emp, np.asarray(p), atol=0.015)


def test_draft_keys_disjoint_from_verify_keys():
    """The sampled-draft key stream is salted off the verify stream: same
    (seed, position) must never reuse a verify key for a draft draw, and
    the draft keys are pure counters (batch-size independent)."""
    base = jax.random.PRNGKey(3)
    samp = {"key": jnp.tile(base[None, :], (2, 1)),
            "temperature": jnp.asarray([0.8, 0.8], jnp.float32),
            "top_k": jnp.zeros((2,), jnp.int32),
            "top_p": jnp.ones((2,), jnp.float32)}
    pos = jnp.asarray([5, 9], jnp.int32)
    from repro.serving.sampling import step_keys
    vk = np.asarray(step_keys(samp, pos))
    dk = np.asarray(draft_keys(samp, pos, K=3))
    assert dk.shape == (2, 3) + vk.shape[1:]
    flat = {tuple(k) for k in dk.reshape(-1, dk.shape[-1])}
    assert not ({tuple(k) for k in vk} & flat), "draft key == verify key"
    # counters: row 0 of a size-2 batch == row 0 of a size-1 batch
    solo = {k: v[:1] for k, v in samp.items()}
    np.testing.assert_array_equal(
        np.asarray(draft_keys(solo, pos[:1], K=3))[0], dk[0])


# ---------------------------------------------------------------------------
# units: iteration-weighted acceptance stats (satellite 3)
# ---------------------------------------------------------------------------

def test_acceptance_stats_partially_idle_weighted():
    """Regression for the stats-deflation bug: idle rows contribute
    NOTHING (no iterations, no tokens), and the ``iters`` weights let a
    multi-iteration harvest delta fold in as its true iteration count."""
    # two active rows (3 and 2 iters), one idle row that must be ignored
    s = SD.update_acceptance_stats(
        {}, jnp.asarray([4, 1, 7]),              # accepted drafts over window
        active=jnp.asarray([True, True, False]),
        iters=jnp.asarray([3, 2, 5]))
    assert int(s["iters"]) == 5                  # 3 + 2, idle row excluded
    assert int(s["tokens"]) == (4 + 3) + (1 + 2)  # AL*it = drafts + iters
    np.testing.assert_allclose(float(s["mean"]), 10 / 5)
    # folding another delta accumulates; all-idle folds are no-ops
    s2 = SD.update_acceptance_stats(
        s, jnp.asarray([0, 0, 0]),
        active=jnp.asarray([False, False, False]),
        iters=jnp.asarray([9, 9, 9]))
    assert (int(s2["iters"]), int(s2["tokens"])) == (5, 10)
    assert np.isfinite(float(s2["mean"]))


# ---------------------------------------------------------------------------
# units: controller policy + state machine
# ---------------------------------------------------------------------------

def test_controller_policy_converges_and_recovers():
    K = 5
    c = SpeculationController(K)
    assert c.k_for(1) == K                       # optimistic admission
    for _ in range(12):                          # AL=1: nothing accepted
        c.observe(1, d_tok=2, d_it=2)
    assert c.k_for(1) == 1                       # floor (k_min=1)
    for _ in range(12):                          # AL=K+1: everything lands
        c.observe(1, d_tok=2 * (K + 1), d_it=2)
    assert c.k_for(1) == K                       # recovered to full depth
    c.observe(1, d_tok=0, d_it=0)                # idle delta is a no-op
    rep = c.request_report(1)
    assert rep["observed_iters"] == 48 and rep["k_final"] == K
    c.finish(1)
    c.finish(1)                                  # double-finish is a no-op
    agg = c.report()
    assert agg["requests"] == 1 and agg["max_k"] == K


def test_controller_state_is_rid_keyed_not_slot_keyed():
    """Preemption hands a request a NEW slot; the controller must resume
    the same EMA trajectory regardless — interleaving another rid's
    observations must not perturb it."""
    a = SpeculationController(4)
    b = SpeculationController(4)
    deltas = [(3, 2), (2, 2), (6, 2), (2, 1)]
    for d_tok, d_it in deltas:
        a.observe(7, d_tok, d_it)
    for i, (d_tok, d_it) in enumerate(deltas):
        b.observe(7, d_tok, d_it)
        b.observe(1000 + i, 2, 1)                # noisy neighbor
    assert a.k_for(7) == b.k_for(7)
    assert a.request_report(7) == b.request_report(7)


def test_speculation_config_validation():
    SpeculationConfig(k_min=1, ema_decay=0.5, headroom=0)
    for bad in [dict(k_min=-1), dict(ema_decay=0.0), dict(ema_decay=1.0),
                dict(headroom=-1)]:
        with pytest.raises(ValueError):
            SpeculationConfig(**bad)


# ---------------------------------------------------------------------------
# serving invariants with the controller + sampled drafts on
# ---------------------------------------------------------------------------

def _mixed_requests(prompts, budget=7):
    sps = [None, SAMPLED, None,
           SamplingParams(temperature=1.0, top_p=0.9, seed=77)]
    return [Request(p, max_new_tokens=budget, sampling=sp)
            for p, sp in zip(prompts, sps[:len(prompts)])]


def test_greedy_rows_bitwise_with_controller_and_sampled_drafts():
    """THE losslessness pin: greedy rows of a mixed batch served with
    ``adaptive_k=True`` on a ``draft_sampling`` engine emit exactly what a
    plain fixed-K engine without the controller emits."""
    base = get_engine(sampled_drafts=False)
    eng = get_engine(sampled_drafts=True)
    prompts = _prompts(4, seed=21)
    ref = Scheduler(base).serve(
        [Request(p, max_new_tokens=7) for p in prompts])
    got = Scheduler(eng, adaptive_k=True).serve(_mixed_requests(prompts))
    for i in (0, 2):                             # the greedy rows
        np.testing.assert_array_equal(
            got["results"][i]["tokens"], ref["results"][i]["tokens"],
            err_msg="greedy row perturbed by controller/sampled neighbors")
    assert "k_final" in got["results"][0]
    assert "speculation" in got and "weighted_acceptance_length" in got


def test_adaptive_k_fixed_point_is_bitwise_fixed_k():
    """A controller pinned to full depth (k_min=K, headroom>=0 with an
    optimistic EMA) must reproduce the fixed-K scheduler bitwise for BOTH
    policies — the mask at K is the identity end to end."""
    eng = get_engine()
    prompts = _prompts(4, seed=23)
    ref = Scheduler(eng).serve(_mixed_requests(prompts))
    cfg = SpeculationConfig(k_min=eng.ecfg.K)
    got = Scheduler(eng, adaptive_k=cfg).serve(_mixed_requests(prompts))
    for r, g in zip(ref["results"], got["results"]):
        np.testing.assert_array_equal(r["tokens"], g["tokens"])


def test_sampled_draft_composition_invariance():
    """A seeded sampled request with warped-proposal drafting emits the
    same stream solo and among arbitrary neighbors — the draft keys are
    per-row counters, so neighbors can't perturb the draws."""
    eng = get_engine()
    target = _prompts(1, seed=31)[0]
    others = _prompts(3, seed=32)
    solo = Scheduler(eng, adaptive_k=True).serve(
        [Request(target, sampling=SAMPLED)])["results"][0]["tokens"]
    for order in ([target] + others, others + [target]):
        reqs = [Request(p, sampling=SAMPLED if p is target else None)
                for p in order]
        rep = Scheduler(eng, adaptive_k=True).serve(reqs)
        got = [r for q, r in zip(sorted(reqs, key=lambda r: r.rid),
                                 rep["results"]) if q.sampling == SAMPLED]
        np.testing.assert_array_equal(
            got[0]["tokens"], solo,
            err_msg="sampled-draft stream changed with batch composition")


@pytest.mark.parametrize("shard", [0, 4, 8])
def test_adaptive_sampled_cross_layout_mesh_losslessness(shard):
    """Paged + adaptive + sampled drafts on a mesh of ``shard`` forced
    host devices equals the contiguous single-device engine bitwise, both
    policies in one batch."""
    if shard:
        require_devices(shard)
    prompts = _prompts(4, seed=41, lo=3, hi=10)
    ref = Scheduler(get_engine("contiguous"), adaptive_k=True).serve(
        _mixed_requests(prompts, budget=6))
    got = Scheduler(get_engine("paged", shard=shard), adaptive_k=True).serve(
        _mixed_requests(prompts, budget=6))
    for r, g in zip(ref["results"], got["results"]):
        np.testing.assert_array_equal(
            r["tokens"], g["tokens"],
            err_msg=f"rid {r['rid']} diverged across layouts (shard={shard})")


def test_preempt_resume_with_adaptive_sampled_drafts():
    """Tight pool forces eviction mid-stream: every request — greedy and
    seeded sampled with warped-proposal drafts — resumes bitwise, and the
    rid-keyed controller state survives the slot change."""
    eng = get_engine(pool_pages=5)
    prompts = _prompts(3, seed=51, lo=6, hi=7)
    budgets = [14, 14, 8]
    sps = [SAMPLED, None, SamplingParams(temperature=0.9, seed=9)]

    def reqs():
        return [Request(p, max_new_tokens=b, sampling=sp)
                for p, b, sp in zip(prompts, budgets, sps)]

    rep = Scheduler(eng, adaptive_k=True).serve(reqs())
    assert rep["preemptions"] >= 1, "workload was meant to force eviction"
    for res, p, b, sp in zip(rep["results"], prompts, budgets, sps):
        solo = Scheduler(eng, adaptive_k=True).serve(
            [Request(p, max_new_tokens=b, sampling=sp)])["results"][0]
        np.testing.assert_array_equal(
            res["tokens"], solo["tokens"],
            err_msg=f"rid {res['rid']} diverged after preemption")
    assert eng.allocator.n_free == eng.pool_pages


def test_streamed_equals_virtual_twin_with_controller():
    """Wall-clock AsyncEngine with ``adaptive_k=True`` on the sampled-draft
    engine yields exactly the virtual twin's streams: wall pacing feeds the
    clock, never the controller."""
    eng = get_engine()
    rng = np.random.default_rng(61)
    workload = [(rng.integers(1, 200, size=int(rng.integers(2, 9))
                              ).astype(np.int32),
                 None if i % 2 == 0
                 else SamplingParams(temperature=0.8, seed=90 + i),
                 int(rng.integers(3, 9)))
                for i in range(5)]
    twin = virtual_twin_report(eng, workload, adaptive_k=True)

    async def go():
        aeng = AsyncEngine(eng, adaptive_k=True)

        async def one(p, sp, b):
            return [t async for t, _ in aeng.generate(p, sp,
                                                      max_new_tokens=b)]

        streams = await asyncio.gather(*(one(*w) for w in workload))
        return streams, await aeng.close()

    streams, rep = run(go())
    assert rep["n_requests"] == len(workload)
    for got, ref in zip(streams, twin["results"]):
        assert got == ref["tokens"].tolist()


def test_one_jitted_trace_per_layout_with_adaptive_k():
    """``k_row`` is traced: serving mixed batches at many per-row depths
    must compile each greedy-twin of the step exactly once."""
    eng = get_engine(batch=3)
    for seed in (71, 72):
        prompts = _prompts(3, seed=seed)
        Scheduler(eng, adaptive_k=True).serve(_mixed_requests(prompts))
    n_traces = sum(f._cache_size() for f in eng._paged_step.values())
    assert n_traces <= 2, (
        f"{n_traces} traces of the paged step — k_row retraced the jit "
        f"(expected at most one per greedy/mixed twin)")


def test_ar_drafter_sampled_drafts_deterministic():
    """The autoregressive drafter samples in-scan: same seeded request
    twice on the AR engine is bitwise stable (keys are scan xs, not
    trace-order dependent)."""
    eng = get_engine(drafter_mode="ar")
    p = _prompts(1, seed=81)[0]
    runs = [Scheduler(eng, adaptive_k=True).serve(
        [Request(p, sampling=SAMPLED)])["results"][0]["tokens"]
        for _ in range(2)]
    np.testing.assert_array_equal(runs[0], runs[1])


# ---------------------------------------------------------------------------
# health() fixes (satellite 1) + weighted AL report (satellite 2)
# ---------------------------------------------------------------------------

def test_health_zero_completed_no_error():
    """Zero completed requests: percentiles are 0.0, never an IndexError."""
    eng = get_engine()

    async def go():
        aeng = AsyncEngine(eng)
        await aeng.start()
        h = aeng.health()
        await aeng.close()
        return h

    h = run(go())
    assert h["finished"] == 0 and h["aborted"] == 0
    assert h["p50_wait_s"] == 0.0 and h["p99_wait_s"] == 0.0


def test_health_all_aborted_session():
    """Every request aborted (some before ever being admitted): health()
    must screen never-admitted requests by the WALL admission stamp and
    still return finite percentiles."""
    eng = get_engine(batch=2)
    prompts = _prompts(4, seed=91)

    async def go():
        aeng = AsyncEngine(eng, max_pending=8)
        handles = [await aeng.submit(p, max_new_tokens=8) for p in prompts]
        # abort the queued tail first (never admitted: t_admit == 0.0),
        # then the running head
        for h in reversed(handles):
            aeng.abort(h)
        health = aeng.health()
        rep = await aeng.close()
        return health, rep

    h, rep = run(go())
    assert h["finished"] == 0
    assert h["aborted"] == len(prompts)
    assert h["p50_wait_s"] == 0.0 and h["p99_wait_s"] == 0.0
    assert rep["aborted"] == len(prompts)


def test_report_weighted_acceptance_length():
    """The aggregate ``weighted_acceptance_length`` is total committed
    decode tokens over total decode iterations — short requests no longer
    dominate the mean the way the unweighted per-request average lets
    them."""
    eng = get_engine()
    prompts = _prompts(3, seed=95)
    rep = Scheduler(eng).serve(
        [Request(p, max_new_tokens=b)
         for p, b in zip(prompts, (2, 8, 8))])
    w = rep["weighted_acceptance_length"]
    assert 0.0 < w <= eng.ecfg.K + 2
    # per-request acceptance_length = dec_tok / iters, so the weighted
    # aggregate must equal sum(AL_r * iters_r) / sum(iters_r)
    tot_tok = sum(r["acceptance_length"] * r["iters"]
                  for r in rep["results"])
    tot_it = sum(r["iters"] for r in rep["results"])
    np.testing.assert_allclose(w, tot_tok / tot_it, rtol=1e-5)
    # the unweighted per-request mean is still reported alongside
    assert "mean_acceptance_length" in rep
