"""Training integration: loss decreases, segmented trainer runs, AR (TTT)
baseline trains, checkpoint of trained drafter restores."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DrafterConfig, get_config
from repro.data import MTPPipeline, markov_corpus
from repro.models import get_model
from repro.training import Trainer, TrainConfig

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    tcfg = get_config("qwen2-1.5b").reduced()
    m = get_model(tcfg)
    tparams = m.init(KEY)
    corpus = markov_corpus(0, 24, 24, tcfg.vocab_size, branch=2)
    return tcfg, m, tparams, corpus


@pytest.mark.slow
def test_parallel_loss_decreases(setup):
    tcfg, m, tparams, corpus = setup
    dcfg = DrafterConfig(n_layers=1, k_train=3).resolve(tcfg)
    pipe = MTPPipeline(corpus, k_train=3, cod_rate=0.7, batch=8, seed=0)
    tr = Trainer(tcfg, dcfg, tparams, TrainConfig(lr=2e-3, total_steps=60))
    log = tr.train(pipe, epochs=10)
    first = np.mean([m_["loss"] for m_ in log[:3]])
    last = np.mean([m_["loss"] for m_ in log[-3:]])
    assert last < 0.7 * first


@pytest.mark.slow
def test_segmented_trainer_runs_and_learns(setup):
    tcfg, m, tparams, corpus = setup
    dcfg = DrafterConfig(n_layers=1, k_train=3).resolve(tcfg)
    pipe = MTPPipeline(corpus, k_train=3, cod_rate=0.7, batch=8, seed=0,
                       segments=2)
    tr = Trainer(tcfg, dcfg, tparams, TrainConfig(lr=2e-3, total_steps=60))
    log = tr.train(pipe, epochs=8)
    assert log[-1]["loss"] < log[0]["loss"]


@pytest.mark.slow
def test_ar_ttt_baseline_trains(setup):
    tcfg, m, tparams, corpus = setup
    dcfg = DrafterConfig(n_layers=1, parallel=False, ttt_steps=2,
                         hca=True).resolve(tcfg)
    pipe = MTPPipeline(corpus, k_train=1, cod_rate=0.9, batch=8, seed=0)
    tr = Trainer(tcfg, dcfg, tparams, TrainConfig(lr=2e-3, total_steps=40))
    log = tr.train(pipe, epochs=6)
    assert log[-1]["loss"] < log[0]["loss"]


def test_trained_drafter_checkpoint_roundtrip(setup, tmp_path):
    from repro.checkpoint import load_pytree, save_pytree
    tcfg, m, tparams, corpus = setup
    dcfg = DrafterConfig(n_layers=1, k_train=3).resolve(tcfg)
    pipe = MTPPipeline(corpus, k_train=3, cod_rate=0.7, batch=8, seed=0)
    tr = Trainer(tcfg, dcfg, tparams, TrainConfig(lr=2e-3, total_steps=10))
    tr.train(pipe, epochs=1)
    save_pytree(tr.dparams, str(tmp_path), "drafter", step=1)
    restored = load_pytree(tr.dparams, str(tmp_path), "drafter")
    for a, b in zip(jax.tree.leaves(tr.dparams), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
