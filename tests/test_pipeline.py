"""Data pipeline: corpora, MTP batch layout, labels, segment batching."""
import numpy as np
import pytest

from repro.core import cod
from repro.data import MTPPipeline, markov_corpus


def test_markov_corpus_learnable_structure():
    c = markov_corpus(0, 16, 64, 256, branch=2)
    assert c.shape == (16, 64)
    # with branch=2, bigram entropy is low: successor sets small
    succ = {}
    for row in c:
        for a, b in zip(row[:-1], row[1:]):
            succ.setdefault(int(a), set()).add(int(b))
    avg = np.mean([len(v) for v in succ.values()])
    assert avg <= 2.5


def test_batch_layout_and_labels():
    c = markov_corpus(1, 8, 32, 100)
    pipe = MTPPipeline(c, k_train=4, cod_rate=0.7, batch=4, seed=0)
    batch = next(iter(pipe))
    assert batch.pos.shape == (4, pipe.M)
    valid = batch.depth >= 0
    # label of (g, p) is token[p+2] (EAGLE pairing)
    for b in range(4):
        for j in np.nonzero(valid[b])[0][:64]:
            p = batch.pos[b, j]
            lab = batch.labels[b, j]
            if p + 2 < 32:
                assert lab == batch.tokens[b, p + 2]
            else:
                assert lab == -1


def test_segmented_batches_cover_all_queries():
    c = markov_corpus(2, 4, 48, 100)
    pipe = MTPPipeline(c, k_train=4, cod_rate=0.8, batch=2, seed=0,
                       segments=3)
    segs = next(iter(pipe))
    assert isinstance(segs, list) and len(segs) >= 2
    # total labeled positions across segments == labeled positions of a
    # whole-sequence pipeline with the same rng
    pipe2 = MTPPipeline(c, k_train=4, cod_rate=0.8, batch=2, seed=0)
    whole = next(iter(pipe2))
    n_whole = int((whole.labels >= 0).sum())
    n_seg = sum(int((s.labels >= 0).sum()) for s in segs)
    assert n_seg == n_whole
    # weights sum to ~1
    assert sum(s.weight for s in segs) == pytest.approx(1.0, rel=1e-6)


def test_expanded_length_static():
    for n, K, r in [(64, 4, 0.7), (128, 8, 0.8)]:
        M = cod.expanded_length(n, K, r)
        rng = np.random.default_rng(0)
        for _ in range(5):
            pos, _ = cod.sample_cod(rng, n, K, r)
            assert len(pos) == M
