"""Minimal deterministic stand-in for ``hypothesis``.

Installed by conftest.py (as ``sys.modules["hypothesis"]``) only when the
real library is missing, so the property tests still *run* — against a fixed
number of seeded random examples — instead of failing at collection. The
repo's tests only use ``integers``/``floats`` strategies, with ``@given``
optionally stacked under ``@pytest.mark.parametrize`` (parametrize arguments
pass through, strategies bind to the remaining parameters — positional ones
rightmost, as in real hypothesis); anything fancier should use the real
dependency (``pip install -e .[test]``).
"""
from __future__ import annotations

import inspect
import zlib

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, int(max_value) + 1)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def given(*strats, **kw_strats):
    def deco(fn):
        # no functools.wraps: __wrapped__ would make pytest introspect the
        # original signature and demand fixtures named after the strategies.
        # Parameters NOT drawn by a strategy (e.g. pytest.mark.parametrize
        # arguments stacked outside @given, matching real-hypothesis
        # composition) are exposed via an explicit __signature__ so pytest
        # still injects them; they are forwarded to every drawn example.
        undrawn = [p for p in inspect.signature(fn).parameters.values()
                   if p.name not in kw_strats]
        # real hypothesis binds positional strategies to the RIGHTMOST
        # parameters; everything left of them passes through from pytest
        split = len(undrawn) - len(strats)
        passthrough, pos_names = undrawn[:split], [p.name
                                                   for p in undrawn[split:]]

        def wrapper(**params):
            n = getattr(wrapper, "_stub_max_examples", 20)
            # per-test fixed seed: failures reproduce across runs
            rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = {nm: s.draw(rng) for nm, s in zip(pos_names, strats)}
                kw = {k: s.draw(rng) for k, s in kw_strats.items()}
                fn(**params, **drawn, **kw)
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.__signature__ = inspect.Signature(passthrough)
        wrapper._stub_given = True
        return wrapper
    return deco


def settings(max_examples: int = 20, **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco
