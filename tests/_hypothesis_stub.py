"""Minimal deterministic stand-in for ``hypothesis``.

Installed by conftest.py (as ``sys.modules["hypothesis"]``) only when the
real library is missing, so the property tests still *run* — against a fixed
number of seeded random examples — instead of failing at collection. The
repo's tests only use ``integers``/``floats`` strategies; anything fancier
should use the real dependency (``pip install -e .[test]``).
"""
from __future__ import annotations

import zlib

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, int(max_value) + 1)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def given(*strats, **kw_strats):
    def deco(fn):
        # no functools.wraps: __wrapped__ would make pytest introspect the
        # original signature and demand fixtures named after the strategies
        def wrapper():
            n = getattr(wrapper, "_stub_max_examples", 20)
            # per-test fixed seed: failures reproduce across runs
            rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = [s.draw(rng) for s in strats]
                kw = {k: s.draw(rng) for k, s in kw_strats.items()}
                fn(*drawn, **kw)
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._stub_given = True
        return wrapper
    return deco


def settings(max_examples: int = 20, **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco
