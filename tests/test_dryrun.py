"""Distribution-layer tests.

Multi-device lowering runs in a SUBPROCESS (jax locks the device count on
first init, and the rest of the suite needs the real single CPU device).
The subprocess uses reduced configs + scaled-down shapes on a (2,2,2) debug
mesh — structurally the same code path as the 512-chip production dry-run.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.launch import roofline as RL

ROOT = os.path.join(os.path.dirname(__file__), "..")

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax
from repro.configs import get_config
from repro.configs.base import InputShape
import repro.launch.steps as S
from repro.launch.mesh import make_debug_mesh
from repro.launch.steps import (build_prefill_step, build_serve_step,
                                build_train_step, mesh_context,
                                resolve_drafter)

S.INPUT_SHAPES = dict(S.INPUT_SHAPES)
S.INPUT_SHAPES["train_4k"] = InputShape("train_4k", 64, 8, "train")
S.INPUT_SHAPES["decode_32k"] = InputShape("decode_32k", 128, 8, "decode")

arch, kind = sys.argv[1], sys.argv[2]
tcfg = get_config(arch).reduced()
dcfg = resolve_drafter(tcfg, n_layers=2, remat=True)
mesh = make_debug_mesh(2, 2, multi_pod=True)
if kind == "train":
    fn, mi = build_train_step(tcfg, dcfg, "train_4k", n_micro=2)
    order = ["tparams", "dparams", "opt_state", "tokens", "pos", "depth",
             "labels", "rng"]
elif kind == "decode":
    fn, mi = build_serve_step(tcfg, dcfg, "decode_32k", K=3)
    order = ["tparams", "dparams", "state"]
args, extras, sh, exsh = mi(mesh)
av = [args[k] for k in order]
sv = [sh[k] for k in order]
if kind == "train":
    av.append(extras); sv.append(exsh)
with mesh_context(mesh):
    comp = jax.jit(fn, in_shardings=tuple(sv)).lower(*av).compile()
cost = comp.cost_analysis()
if isinstance(cost, list):        # jax 0.4.x: one dict per device
    cost = cost[0] if cost else {}
txt = comp.as_text()
n_coll = sum(txt.count(k) for k in
             ("all-reduce", "all-gather", "reduce-scatter", "all-to-all"))
print(json.dumps({"flops": float(cost.get("flops", 0)),
                  "collectives": n_coll}))
"""


def _run(arch, kind):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", _SUBPROC, arch, kind],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow          # multi-device subprocess compile, ~5-15 s each
@pytest.mark.parametrize("arch", ["qwen2-1.5b", "dbrx-132b", "mamba2-780m"])
def test_multipod_train_lowers(arch):
    r = _run(arch, "train")
    assert r["flops"] > 0
    assert r["collectives"] > 0    # model-sharded training must communicate


@pytest.mark.slow          # multi-device subprocess compile, ~5-15 s each
@pytest.mark.parametrize("arch", ["qwen2-1.5b", "recurrentgemma-2b"])
def test_multipod_decode_lowers(arch):
    r = _run(arch, "decode")
    assert r["flops"] > 0


# ---------------------------------------------------------------------------
# roofline unit tests (pure parsing, no devices)
# ---------------------------------------------------------------------------

def test_collective_parser():
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(%x), dims={0}
  %ar.1 = f32[16,16]{1,0} all-reduce(%y), to_apply=%add
  %rs = f32[4]{0} reduce-scatter(%z), dimensions={0}
  %cp = u32[2]{0} collective-permute(%w)
  %a2a = bf16[8,8]{1,0} all-to-all(%v), dimensions={1}
  %ars = f32[16,16]{1,0} all-reduce-start(%y2), to_apply=%add
"""
    st = RL.collective_stats(hlo)
    assert st["all-gather"]["count"] == 1
    assert st["all-gather"]["bytes"] == 8 * 128 * 2
    assert st["all-reduce"]["count"] == 2          # sync + async start
    assert st["reduce-scatter"]["bytes"] == 16
    assert st["all-to-all"]["count"] == 1
    assert st["collective-permute"]["bytes"] == 8


def test_roofline_terms_bottleneck():
    cost = {"flops": 197e12, "bytes accessed": 819e9 * 2}
    coll = {"all-gather": {"count": 1, "bytes": 50e9}}
    t = RL.roofline_terms(cost, coll, 256)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(2.0)
    assert t["collective_s"] == pytest.approx(1.0)
    assert t["bottleneck"] == "memory_s"


def test_param_count_sane():
    from repro.configs import get_config
    n = RL.param_count(get_config("qwen2-1.5b"))
    assert 1.2e9 < n < 2.2e9
    n_moe_total = RL.param_count(get_config("dbrx-132b"))
    n_moe_active = RL.param_count(get_config("dbrx-132b"), active_only=True)
    assert 1.1e11 < n_moe_total < 1.6e11
    assert n_moe_active < n_moe_total / 2.5
