"""Churn property suite for async arrival-time serving with incremental
paged-KV growth and lossless preemption (serving/scheduler.py +
Engine.ensure_capacity).

Random arrival/length/budget workloads over a deliberately tight page pool
drive the full churn cycle — admission, page-by-page growth, preemption
(pages freed, tokens retained host-side), recompute-prefill resume, EOS/
budget frees — and pin four invariants:

- **allocator hygiene**: after every serve the pool drains to empty with no
  slot holding pages (the BlockAllocator itself raises on double-free /
  foreign pages mid-run, so aliasing can't pass silently);
- **arrival gating**: no request is admitted before its ``arrival_time`` on
  the deterministic virtual clock;
- **FIFO fairness**: first admissions happen in ``(arrival_time,
  submission)`` order — head-of-line blocking, no admission around a
  waiting earlier request;
- **lossless preemption**: every request's token stream equals an
  uninterrupted solo run on the same engine, token for token — for dense,
  SSM, and hybrid targets, under BOTH decoding policies: greedy recompute
  resume is a pure function of the prefix, and seeded sampling replays
  bitwise because its per-step keys are fold_in(seed, position) counters
  re-derived over the recomputed prefix (the seeded-sampling replay
  invariant, docs/serving.md).

The virtual clock is step-cost-driven, so every scenario here replays
bit-identically across runs (test_virtual_clock_deterministic pins that
too).
"""
from functools import lru_cache

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import DrafterConfig, get_config
from repro.core import drafter as D
from repro.models import get_model
from repro.serving import (Engine, EngineConfig, Request, SamplingParams,
                           Scheduler)
from repro.sharding.utils import serving_mesh

KEY = jax.random.PRNGKey(17)

FAMILY_ARCHS = {
    "dense": "qwen2-1.5b",
    "ssm": "mamba2-780m",
    "hybrid": "recurrentgemma-2b",
}


@lru_cache(maxsize=None)
def _setup(family):
    tcfg = get_config(FAMILY_ARCHS[family]).reduced()
    m = get_model(tcfg)
    tparams = m.init(KEY)
    dcfg = DrafterConfig(n_layers=1, k_infer=2).resolve(tcfg)
    dparams = D.init_params(dcfg, tcfg, jax.random.fold_in(KEY, 1))
    return tcfg, dcfg, tparams, dparams


@lru_cache(maxsize=None)
def get_engine(family="dense", pool_pages=0, kv_growth="incremental",
               batch=2, shard=0):
    """``shard`` > 0 builds the engine model-sharded over that many devices
    (weights + KV page pools storage-sharded; lossless by construction —
    the sharded tests below pin it against single-device references)."""
    tcfg, dcfg, tparams, dparams = _setup(family)
    return Engine(tcfg, dcfg, tparams, dparams,
                  EngineConfig(K=2, max_new_tokens=16,
                               drafter_mode="parallel", max_len=64,
                               kv_layout="paged", page_size=8,
                               pool_pages=pool_pages, kv_growth=kv_growth,
                               shard_model=shard > 0,
                               mesh=serving_mesh(shard) if shard else None),
                  batch)


from conftest import require_devices  # noqa: E402  (tests dir on sys.path)


def assert_pool_drained(eng):
    assert eng.allocator.n_free == eng.pool_pages, "leaked pages"
    assert eng.allocator.n_used == 0
    assert all(not ps for ps in eng._slot_pages), "slot still holds pages"


def solo_tokens(eng, prompt, budget):
    """Uninterrupted single-request reference on the same engine."""
    rep = Scheduler(eng).serve([Request(prompt, max_new_tokens=budget)])
    return rep["results"][0]["tokens"]


def churn_workload(seed, n, max_len_prompt=8, max_budget=9, max_arrival=12.0):
    rng = np.random.default_rng(seed)
    return [Request(rng.integers(1, 200,
                                 size=int(rng.integers(1, max_len_prompt + 1))
                                 ).astype(np.int32),
                    max_new_tokens=int(rng.integers(1, max_budget + 1)),
                    arrival_time=float(np.round(
                        rng.uniform(0, max_arrival), 2)))
            for _ in range(n)]


# ---------------------------------------------------------------------------
# lossless preemption, per family (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_preempted_stream_equals_uninterrupted(family):
    """A preempted-and-resumed request emits the exact token sequence of an
    uninterrupted run. The pool (5 pages) fits both initial claims but not
    both full-grown requests, so decode-time growth must evict the
    lower-priority slot and later resume it by recompute-prefill."""
    eng = get_engine(family, pool_pages=5)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, 200, size=6).astype(np.int32)
               for _ in range(3)]
    budgets = [14, 14, 8]
    rep = Scheduler(eng).serve([Request(p, max_new_tokens=b)
                                for p, b in zip(prompts, budgets)])
    assert rep["preemptions"] >= 1, "workload was meant to force eviction"
    assert any(r["n_preempt"] > 0 for r in rep["results"])
    for res, p, b in zip(rep["results"], prompts, budgets):
        np.testing.assert_array_equal(
            res["tokens"], solo_tokens(eng, p, b),
            err_msg=f"{family}: rid {res['rid']} diverged after preemption")
    assert_pool_drained(eng)


@pytest.mark.parametrize("family,shard", [("dense", 4), ("ssm", 4),
                                          ("hybrid", 4), ("dense", 8)])
def test_sharded_preempt_resume_matches_single_device(family, shard):
    """The acceptance pin for model-sharded serving: on a mesh of forced
    host devices, the full churn cycle — tight pool, decode-time growth
    failure, eviction, recompute-prefill resume — emits token-for-token
    what the *single-device* engine emits for every request. Preemption and
    growth are exactly where a resharding bug would hide (pages freed and
    recycled between slots cross the sharded pools), so the workload is
    forced to preempt at least once."""
    require_devices(shard)
    eng = get_engine(family, pool_pages=5, shard=shard)
    ref = get_engine(family, pool_pages=5)          # single-device twin
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, 200, size=6).astype(np.int32)
               for _ in range(3)]
    budgets = [14, 14, 8]
    rep = Scheduler(eng).serve([Request(p, max_new_tokens=b)
                                for p, b in zip(prompts, budgets)])
    assert rep["preemptions"] >= 1, "workload was meant to force eviction"
    for res, p, b in zip(rep["results"], prompts, budgets):
        np.testing.assert_array_equal(
            res["tokens"], solo_tokens(ref, p, b),
            err_msg=f"{family}@mesh{shard}: rid {res['rid']} diverged from "
                    "the single-device stream")
    assert_pool_drained(eng)


def test_preemption_with_eos_still_lossless():
    """EOS inside a preempted request's stream: trimming happens at the same
    token as in the solo run, and the early finish frees pages cleanly."""
    eng = get_engine("dense", pool_pages=5)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(1, 200, size=6).astype(np.int32)
               for _ in range(3)]
    budgets = [14, 14, 8]
    ref = Scheduler(eng).serve([Request(p, max_new_tokens=b)
                                for p, b in zip(prompts, budgets)])
    eos = int(ref["results"][1]["tokens"][5])
    rep = Scheduler(eng, eos_id=eos).serve([Request(p, max_new_tokens=b)
                                            for p, b in zip(prompts, budgets)])
    for res, refres in zip(rep["results"], ref["results"]):
        full = refres["tokens"].tolist()
        want = full[:full.index(eos) + 1] if eos in full else full
        assert res["tokens"].tolist() == want
    assert_pool_drained(eng)


def test_stall_without_preemption_still_lossless():
    """preempt=False: a slot that cannot grow stalls (frozen on device, no
    dropped KV writes) until a neighbor frees pages, then resumes exactly."""
    eng = get_engine("dense", pool_pages=5)
    rng = np.random.default_rng(5)
    pa, pb = (rng.integers(1, 200, size=6).astype(np.int32) for _ in range(2))
    rep = Scheduler(eng, preempt=False).serve(
        [Request(pa, max_new_tokens=4), Request(pb, max_new_tokens=14)])
    assert rep["preemptions"] == 0
    np.testing.assert_array_equal(rep["results"][0]["tokens"],
                                  solo_tokens(eng, pa, 4))
    np.testing.assert_array_equal(rep["results"][1]["tokens"],
                                  solo_tokens(eng, pb, 14))
    assert_pool_drained(eng)


@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_sampled_preempted_stream_equals_uninterrupted(family):
    """Seeded-sampling replay invariant through the full churn cycle: a
    preempted-and-resumed SAMPLED request (temperature > 0, per-request
    seed) emits bitwise the token sequence of an uninterrupted run — the
    resume prefill rebuilds the eviction's step-boundary state and the
    per-step fold_in(seed, position) keys re-derive identically over the
    recomputed prefix. Pre-redesign this workload raised ValueError
    (preemption was greedy-only); now it must just work, per family."""
    eng = get_engine(family, pool_pages=5)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, 200, size=6).astype(np.int32)
               for _ in range(3)]
    budgets = [14, 14, 8]
    sps = [SamplingParams(temperature=0.8, seed=100 + i) for i in range(3)]
    rep = Scheduler(eng).serve(
        [Request(p, max_new_tokens=b, sampling=sp)
         for p, b, sp in zip(prompts, budgets, sps)])
    assert rep["preemptions"] >= 1, "workload was meant to force eviction"
    for res, p, b, sp in zip(rep["results"], prompts, budgets, sps):
        solo = Scheduler(eng).serve(
            [Request(p, max_new_tokens=b, sampling=sp)])
        np.testing.assert_array_equal(
            res["tokens"], solo["results"][0]["tokens"],
            err_msg=f"{family}: sampled rid {res['rid']} diverged "
                    "after preemption")
    assert_pool_drained(eng)


def test_mixed_policy_churn_preempt_lossless():
    """A batch mixing greedy and seeded sampled requests through a tight
    pool: evictions and resumes leave EVERY stream — both policies — equal
    to its uninterrupted solo run, and the pool drains."""
    eng = get_engine("dense", pool_pages=5)
    rng = np.random.default_rng(6)
    prompts = [rng.integers(1, 200, size=6).astype(np.int32)
               for _ in range(4)]
    budgets = [12, 14, 8, 6]
    sps = [SamplingParams.greedy(), SamplingParams(temperature=0.9, seed=4),
           SamplingParams(temperature=0.6, top_p=0.9, seed=5), None]
    rep = Scheduler(eng).serve(
        [Request(p, max_new_tokens=b, sampling=sp)
         for p, b, sp in zip(prompts, budgets, sps)])
    for res, p, b, sp in zip(rep["results"], prompts, budgets, sps):
        solo = Scheduler(eng).serve(
            [Request(p, max_new_tokens=b, sampling=sp)])
        np.testing.assert_array_equal(
            res["tokens"], solo["results"][0]["tokens"],
            err_msg=f"mixed churn: rid {res['rid']} diverged")
    assert_pool_drained(eng)


# ---------------------------------------------------------------------------
# churn properties: random arrival/length/budget workloads
# ---------------------------------------------------------------------------

@settings(max_examples=4, deadline=None)
@given(n=st.integers(1, 6), seed=st.integers(0, 2**31 - 1))
def test_no_admission_before_arrival(n, seed):
    eng = get_engine("dense", pool_pages=5)
    reqs = churn_workload(seed, n)
    rep = Scheduler(eng).serve(reqs)
    assert rep["n_requests"] == n
    for res in rep["results"]:
        assert res["wait_vt"] >= -1e-9, \
            f"rid {res['rid']} admitted before arrival"
        assert res["latency_vt"] >= res["wait_vt"]
    assert_pool_drained(eng)


@settings(max_examples=4, deadline=None)
@given(n=st.integers(2, 6), seed=st.integers(0, 2**31 - 1))
def test_fifo_fairness_among_eligible(n, seed):
    """First admissions happen in (arrival_time, submission) priority order:
    the scheduler only ever admits the head of the priority queue, so a
    later arrival can never jump an earlier one that is still waiting."""
    eng = get_engine("dense", pool_pages=5)
    reqs = churn_workload(seed, n)
    rep = Scheduler(eng).serve(reqs)
    order = {r.rid: i for i, r in enumerate(reqs)}
    admits = sorted(((res["arrival_time"] + res["wait_vt"],
                      (res["arrival_time"], order[res["rid"]]))
                     for res in rep["results"]))
    prios = [p for _, p in admits]
    assert prios == sorted(prios), f"admission jumped the queue: {admits}"


@settings(max_examples=3, deadline=None)
@given(n=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
def test_churn_allocator_hygiene_and_losslessness(n, seed):
    """The full churn cycle — grow, preempt, free, resume — leaks and
    aliases nothing (allocator raises loudly mid-run; pool drains after),
    budgets are met exactly, and every stream matches its solo run."""
    eng = get_engine("dense", pool_pages=6)
    reqs = churn_workload(seed, n, max_budget=6)
    want = [(r.prompt.copy(), r.max_new_tokens) for r in reqs]
    rep = Scheduler(eng).serve(reqs)
    assert_pool_drained(eng)
    assert eng.allocator.peak_used <= eng.pool_pages
    for res, (p, b) in zip(rep["results"], want):
        assert res["n_new"] == b                # no EOS id ⇒ exact budget
        np.testing.assert_array_equal(res["tokens"], solo_tokens(eng, p, b))
    assert_pool_drained(eng)


@lru_cache(maxsize=None)
def get_cache_engine(pool_pages=0):
    """Dense paged engine with the prefix cache enabled (dense is the only
    family the sharing fast path serves; see serving/prefix_cache.py)."""
    tcfg, dcfg, tparams, dparams = _setup("dense")
    return Engine(tcfg, dcfg, tparams, dparams,
                  EngineConfig(K=2, max_new_tokens=16,
                               drafter_mode="parallel", max_len=64,
                               kv_layout="paged", page_size=8,
                               pool_pages=pool_pages,
                               kv_growth="incremental",
                               prefix_cache=True), 2)


@settings(max_examples=3, deadline=None)
@given(n=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
def test_cached_churn_refcounts_never_leak_or_alias(n, seed):
    """The churn property suite's invariants, with the prefix cache in the
    loop: random arrival/length/budget workloads over a tight pool — now
    with admissions hitting cached pages, free-time inserts, LRU evictions
    under growth pressure, and preemption decrefs interleaved — must leave
    every stream equal to a solo run on a cache-OFF engine, and must leave
    every pool page either free or cache-held at refcount exactly 1 (slots
    all drained). Flushing the cache then fully drains the pool."""
    eng = get_cache_engine(pool_pages=6)
    ref = get_engine("dense", pool_pages=6)
    reqs = churn_workload(seed, n, max_budget=6)
    want = [(r.prompt.copy(), r.max_new_tokens) for r in reqs]
    rep = Scheduler(eng).serve(reqs)
    for res, (p, b) in zip(rep["results"], want):
        np.testing.assert_array_equal(
            res["tokens"], solo_tokens(ref, p, b),
            err_msg=f"cached churn: rid {res['rid']} diverged")
    # post-drain accounting: live pages == cache-held pages, each at
    # refcount exactly 1 (any slot ref surviving the drain is a leak; any
    # page indexed twice is aliasing)
    alloc, cache = eng.allocator, eng.prefix_cache
    assert all(not ps for ps in eng._slot_pages), "slot still holds pages"
    held = cache.pages()
    assert len(held) == len(set(held)), "cache aliases a page"
    assert alloc.n_used == len(held), "page neither free nor cache-held"
    assert all(alloc.refcount(p) == 1 for p in held), "leaked refcount"
    assert alloc.peak_used <= eng.pool_pages
    cache.flush(alloc)
    assert_pool_drained(eng)


def test_cached_preemption_stream_equals_uninterrupted():
    """Preemption composes with the cache: an evicted request's free-time
    insert leaves its own pages warm, so its recompute-prefill resume can
    hit them — and the stream must still be token-for-token the solo run."""
    eng = get_cache_engine(pool_pages=5)
    ref = get_engine("dense", pool_pages=5)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, 200, size=6).astype(np.int32)
               for _ in range(3)]
    budgets = [14, 14, 8]
    rep = Scheduler(eng).serve([Request(p, max_new_tokens=b)
                                for p, b in zip(prompts, budgets)])
    assert rep["preemptions"] >= 1, "workload was meant to force eviction"
    hit_resumes = sum(r["cached_tokens"] > 0 for r in rep["results"])
    for res, p, b in zip(rep["results"], prompts, budgets):
        np.testing.assert_array_equal(
            res["tokens"], solo_tokens(ref, p, b),
            err_msg=f"cached: rid {res['rid']} diverged after preemption")
    assert hit_resumes > 0, \
        "a resume was expected to hit the eviction's own inserted pages"
    eng.prefix_cache.flush(eng.allocator)
    assert_pool_drained(eng)


def test_virtual_clock_deterministic():
    """Identical workloads replay identical virtual-time traces: admissions,
    preemptions, finishes, and every latency metric — bit-equal."""
    eng = get_engine("dense", pool_pages=5)
    runs = []
    for _ in range(2):
        reqs = churn_workload(7, 5)
        rep = Scheduler(eng).serve(reqs)
        # rids are a global counter; normalize to submission index so the
        # two runs' event traces are comparable
        idx = {r.rid: i for i, r in enumerate(reqs)}
        rep["events"] = [(t, kind, idx[rid]) for t, kind, rid in rep["events"]]
        runs.append(rep)
    a, b = runs
    assert a["events"] == b["events"]
    assert a["preemptions"] == b["preemptions"]
    assert a["makespan_vt"] == b["makespan_vt"]
    for ra, rb in zip(a["results"], b["results"]):
        assert (ra["wait_vt"], ra["latency_vt"]) == \
            (rb["wait_vt"], rb["latency_vt"])
        np.testing.assert_array_equal(ra["tokens"], rb["tokens"])


def test_event_trace_monotonic_and_wall_stamps_ordered():
    """The event trace is non-decreasing in time even when an arrival's
    stamp lands in the past (the idle clock — or a long-running decode —
    has already advanced beyond ``arrival_time`` when the arrival is
    recorded; the scheduler insorts it instead of appending). Regression:
    the trace used to interleave e.g. admit@3.0, arrive@2.5. Wall stamps
    must be ordered per request too: submit <= admit <= finish, with both
    taken after device commits."""
    eng = get_engine("dense", pool_pages=5)
    # staggered arrivals that land mid-decode of earlier requests, plus one
    # far-future arrival the idle clock jumps over
    reqs = churn_workload(21, 5, max_arrival=6.0)
    reqs.append(Request(np.arange(1, 5, dtype=np.int32), max_new_tokens=2,
                        arrival_time=500.0))
    rep = Scheduler(eng).serve(reqs)
    times = [t for t, _, _ in rep["events"]]
    assert times == sorted(times), f"event trace not time-sorted: {rep['events']}"
    kinds = {k for _, k, _ in rep["events"]}
    assert "arrive" in kinds and "admit" in kinds and "finish" in kinds
    # every request arrives exactly once, at its true arrival_time
    arrivals = {rid: t for t, k, rid in rep["events"] if k == "arrive"}
    for r in reqs:
        assert arrivals[r.rid] == r.arrival_time
    for res in rep["results"]:
        assert res["wait_s"] >= 0.0
        assert res["latency_s"] >= res["wait_s"]
    assert_pool_drained(eng)


def test_stop_token_in_early_committed_region_trims_at_first():
    """Long-stream stop-trim regression: the incremental scan must finish a
    request at the FIRST occurrence of a stop token, including one landing
    in the prompt-adjacent committed region (the prefill-committed token
    itself), and must behave identically to a full rescan when the id
    recurs later in the stream."""
    eng = get_engine("dense", pool_pages=0)
    rng = np.random.default_rng(31)
    p = rng.integers(1, 200, size=5).astype(np.int32)
    ref = Scheduler(eng).serve([Request(p, max_new_tokens=16)])
    full = ref["results"][0]["tokens"].tolist()
    # the most prompt-adjacent stop possible: the prefill-committed token
    eos = int(full[0])
    rep = Scheduler(eng, eos_id=eos).serve([Request(p, max_new_tokens=16)])
    got = rep["results"][0]["tokens"].tolist()
    assert got == full[:full.index(eos) + 1]
    assert len(got) == 1
    # a stop mid-stream that recurs afterwards still trims at the first hit
    counts = {t: full.count(t) for t in full}
    recur = [t for t in full if counts[t] > 1]
    eos2 = recur[0] if recur else int(full[3])
    rep2 = Scheduler(eng, eos_id=eos2).serve([Request(p, max_new_tokens=16)])
    got2 = rep2["results"][0]["tokens"].tolist()
    assert got2 == full[:full.index(eos2) + 1]
    assert_pool_drained(eng)


def test_sampled_resume_exact_pool_no_deadlock_no_overreserve():
    """Regression for the sampled-resume probe/claim mismatch: a no-commit
    recompute-prefill (resume=True) needs coverage to one position LESS
    than a fresh admission of the same stream, so when the stream length
    lands exactly on that page boundary the old gate+claim priced one page
    too many — can_admit said no (head-of-line deadlock on a nearly-full
    pool) and the claim over-reserved when the pool did have slack. Pin:
    with the pool sized exactly to the resume's true need, the gate says
    yes, the prefill claims exactly that many pages (consuming the whole
    pool), and the resumed stream still replays the solo run."""
    eng = get_engine("dense", pool_pages=0)
    sp = SamplingParams(temperature=0.8, seed=13)
    rng = np.random.default_rng(41)
    p = rng.integers(1, 200, size=6).astype(np.int32)
    solo = Scheduler(eng).serve([Request(p, max_new_tokens=12, sampling=sp)])
    toks = solo["results"][0]["tokens"].tolist()
    assert_pool_drained(eng)
    ps, off, K = eng.ecfg.page_size, eng.pos_offset, eng.ecfg.K
    # cut the committed stream where a resume's coverage (stream + offset
    # + K positions) lands exactly on a page boundary — the fresh pricing
    # (one more position) would cross into an extra page right here
    L = next(n for n in range(len(p) + 1, len(p) + len(toks))
             if (n + off + K) % ps == 0)
    stream = np.concatenate([p, np.asarray(toks, np.int32)])[:L]
    want = eng.pages_for(L + off + K)
    tight = get_engine("dense", pool_pages=want)      # exactly-full pool
    assert tight.can_admit(L, 12 - (L - len(p)), tokens=stream, resume=True), \
        "resume gate must accept a pool sized to its true need"
    state = tight.serve_state()
    state, first, last = tight.prefill_into_slot(
        state, stream, 0, sampling=sp, max_new=12 - (L - len(p)),
        resume=True)
    assert first is None and last == L - 1 + off
    assert len(tight._slot_pages[0]) == want, "resume over-reserved a page"
    assert tight.allocator.n_free == 0
    state = tight.free_slot(state, 0)
    assert_pool_drained(tight)
    # and end-to-end: the scheduler path (preempt → resume) on a tight pool
    # still replays the solo stream bitwise (sampled-resume flag threaded
    # through _head_admissible → can_admit → prefill_into_slot)
    eng2 = get_engine("dense", pool_pages=5)
    rep = Scheduler(eng2).serve(
        [Request(p, max_new_tokens=12, sampling=sp),
         Request(rng.integers(1, 200, size=6).astype(np.int32),
                 max_new_tokens=14,
                 sampling=SamplingParams(temperature=0.8, seed=14))])
    res = rep["results"][0]
    np.testing.assert_array_equal(res["tokens"], np.asarray(toks, np.int32))
    assert_pool_drained(eng2)


def test_idle_clock_jumps_to_next_arrival():
    """With nothing live the clock jumps to the next arrival instead of
    spinning: a lone late request is admitted exactly at its arrival."""
    eng = get_engine("dense", pool_pages=0)
    rng = np.random.default_rng(9)
    p = rng.integers(1, 200, size=4).astype(np.int32)
    rep = Scheduler(eng).serve(
        [Request(p, max_new_tokens=3, arrival_time=41.5)])
    res = rep["results"][0]
    assert res["wait_vt"] == 0.0              # admitted the moment it arrived
    assert res["arrival_time"] == 41.5
    assert rep["makespan_vt"] > 41.5
