import os
import sys

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benchmarks must see the real single CPU device. Multi-device tests
# shell out to subprocesses (tests/test_dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# hypothesis import-or-skip shim: when the real library is unavailable the
# property tests run against _hypothesis_stub's fixed seeded examples instead
# of erroring at collection (tier-1 must collect green either way).
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub
    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)


def require_devices(n: int):
    """Skip unless jax sees >= n devices — shared by the model-sharded
    serving tests, which run for real in CI's tier1-multidevice lane."""
    import pytest
    if jax.device_count() < n:
        pytest.skip(
            f"needs {n} devices, jax sees {jax.device_count()} (run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n})")
