"""Verification correctness: greedy prefix acceptance, lossless rejection
sampling (statistical), and the end-to-end losslessness property — greedy
speculative decoding must reproduce vanilla greedy decoding token-for-token
across model families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DrafterConfig, get_config
from repro.core import drafter as D
from repro.core import spec_decode as SD
from repro.models import get_model, make_extras
from repro.serving import Engine, EngineConfig

KEY = jax.random.PRNGKey(42)


def test_greedy_verify_prefix():
    logits = jnp.zeros((2, 4, 8))
    t_star = jnp.array([[1, 2, 3, 4], [5, 6, 7, 0]])
    logits = logits.at[jnp.arange(2)[:, None], jnp.arange(4)[None],
                       t_star].set(10.0)
    acc, ts = SD.greedy_verify(jnp.array([[1, 2, 9], [5, 6, 7]]), logits)
    assert acc.tolist() == [2, 3]
    assert (ts == t_star).all()


def test_greedy_verify_none_and_all():
    logits = jnp.zeros((1, 3, 8)).at[0, :, 4].set(9.0)
    acc, _ = SD.greedy_verify(jnp.array([[0, 0]]), logits)
    assert acc.tolist() == [0]
    acc, _ = SD.greedy_verify(jnp.array([[4, 4]]), logits)
    assert acc.tolist() == [2]


def test_rejection_verify_lossless_distribution():
    """The first committed token's empirical distribution must match the
    target distribution regardless of the drafter distribution."""
    V, K, N = 8, 1, 30_000
    key = jax.random.PRNGKey(0)
    p = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (V,)))
    q = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 2), (V,)))

    def one(k):
        kd, kv = jax.random.split(k)
        d = jax.random.categorical(kd, jnp.log(q))[None]
        acc, committed = SD.rejection_verify(
            kv, d[None], q[None, None], jnp.stack([p, p])[None])
        return committed[0, 0]

    toks = jax.vmap(one)(jax.random.split(key, N))
    emp = np.bincount(np.asarray(toks), minlength=V) / N
    np.testing.assert_allclose(emp, np.asarray(p), atol=0.015)


@pytest.mark.parametrize("arch", [
    "qwen2-1.5b",
    pytest.param("mamba2-780m", marks=pytest.mark.slow),
    pytest.param("recurrentgemma-2b", marks=pytest.mark.slow),
    pytest.param("whisper-base", marks=pytest.mark.slow)])
@pytest.mark.parametrize("mode", ["parallel", "ar"])
def test_end_to_end_lossless(arch, mode):
    tcfg = get_config(arch).reduced()
    dcfg = DrafterConfig(n_layers=1, k_infer=4).resolve(tcfg)
    m = get_model(tcfg)
    tparams = m.init(KEY)
    dparams = D.init_params(dcfg, tcfg, jax.random.fold_in(KEY, 1))
    B, P, NEW = 2, 8, 16
    prompts = jax.random.randint(KEY, (B, P), 0, tcfg.vocab_size - 2)
    extras = make_extras(tcfg, B, "prefill", KEY)
    base = Engine(tcfg, None, tparams, None,
                  EngineConfig(K=4, max_new_tokens=NEW, drafter_mode="none",
                               max_len=96), B).run(prompts, extras)
    spec = Engine(tcfg, dcfg, tparams, dparams,
                  EngineConfig(K=4, max_new_tokens=NEW, drafter_mode=mode,
                               max_len=96), B).run(prompts, extras)
    off = tcfg.vision_tokens if tcfg.family == "vlm" else 0
    a = base["tokens"][:, off + P:off + P + NEW]
    b = spec["tokens"][:, off + P:off + P + NEW]
    assert np.array_equal(a, b), f"{arch}/{mode} diverged"


def test_acceptance_stats():
    s = {}
    s = SD.update_acceptance_stats(s, jnp.array([2, 0, 4]))
    assert SD.acceptance_length(s) == pytest.approx((3 + 1 + 5) / 3)
