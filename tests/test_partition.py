"""Algorithm 1 (sequence partitioning) invariants + the key semantic
guarantee: within-sequence gradient accumulation reproduces the
unpartitioned gradient."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import DrafterConfig, get_config
from repro.core import cod, drafter as D, losses, partition


@settings(max_examples=40, deadline=None)
@given(st.integers(16, 64), st.integers(2, 6), st.floats(0.4, 0.9),
       st.integers(2, 5), st.integers(0, 2**31 - 1))
def test_partition_invariants(n, K, r, S, seed):
    rng = np.random.default_rng(seed)
    pos, depth = cod.sample_cod(rng, n, K, r)
    segs = partition.build_segments(pos, depth, n, S)
    # 1. every expanded position is a query in exactly one segment
    allq = sorted(sum([list(zip(s.q_depth.tolist(), s.q_pos.tolist()))
                       for s in segs], []))
    assert allq == sorted(zip(depth.tolist(), pos.tolist()))
    # 2. dependency preservation (the paper's §3.2 requirement)
    assert partition.check_dependencies_preserved(segs, pos, depth)
    # 3. q_in_kv indexes the right entries
    for sg in segs:
        assert (sg.kv_pos[sg.q_in_kv] == sg.q_pos).all()
        assert (sg.kv_depth[sg.q_in_kv] == sg.q_depth).all()


def test_phase2_inheritance_matches_paper_example():
    """Positions at depth>=2 land with their chain, not their raw index."""
    n, S = 16, 2
    # depth-2 position 8 depends on depth-1 position 7 (paper Fig. 4)
    pos = np.array([*range(16), 7, 8])
    depth = np.array([0] * 16 + [1, 2])
    order = np.argsort(pos * 4 + depth, kind="stable")
    pos, depth = pos[order], depth[order]
    A = partition.assign_segments(pos, depth, n, S)
    i_d1 = next(i for i in range(len(pos)) if depth[i] == 1 and pos[i] == 7)
    i_d2 = next(i for i in range(len(pos)) if depth[i] == 2 and pos[i] == 8)
    assert A[i_d2] == A[i_d1]        # chain stays together
    assert A[i_d1] == 0              # position 7 -> segment 0 (bound 8)


@pytest.mark.slow
def test_segmented_grads_match_full():
    """Sum of per-segment gradients == unpartitioned gradient (each query
    appears in exactly one segment with its full attention context)."""
    tcfg = get_config("qwen2-1.5b").reduced()
    dcfg = DrafterConfig(n_layers=1, k_train=3).resolve(tcfg)
    key = jax.random.PRNGKey(0)
    params = D.init_params(dcfg, tcfg, key)
    B, n = 2, 24
    tokens = jax.random.randint(key, (B, n), 0, tcfg.vocab_size)
    taps = 0.1 * jax.random.normal(key, (B, n, 3 * tcfg.d_model))
    rng = np.random.default_rng(3)
    pos, depth = cod.sample_cod(rng, n, 3, 0.7)

    def labels_of(p):
        tgt = np.asarray(p) + 2
        lab = np.where((tgt < n) & (np.asarray(p) >= 0),
                       np.asarray(tokens)[:, np.clip(tgt, 0, n - 1)], -1)
        return jnp.asarray(lab)

    def loss_sum(dp, pv, dv, lab):
        logits, _ = D.mtp_forward(dcfg, tcfg, dp, tokens, taps,
                                  jnp.asarray(pv), jnp.asarray(dv))
        ce = losses.cross_entropy(logits, lab)
        return ce.sum()   # SUM so segment losses add exactly

    full_grads = jax.grad(loss_sum)(params, pos, depth, labels_of(pos))

    segs = partition.build_segments(pos, depth, n, 3)
    acc = jax.tree.map(jnp.zeros_like, params)
    for sg in segs:
        lab_full = labels_of(sg.kv_pos)
        # loss only on the segment's own queries
        mask = np.zeros(len(sg.kv_pos), bool)
        mask[sg.q_in_kv] = True
        lab = jnp.where(jnp.asarray(mask)[None, :], lab_full, -1)
        g = jax.grad(loss_sum)(params, sg.kv_pos, sg.kv_depth, lab)
        acc = jax.tree.map(lambda a, b: a + b, acc, g)

    flat_a = jax.tree.leaves(acc)
    flat_f = jax.tree.leaves(full_grads)
    for a, f in zip(flat_a, flat_f):
        np.testing.assert_allclose(np.asarray(a), np.asarray(f),
                                   rtol=2e-4, atol=2e-5)
