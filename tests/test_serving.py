"""Serving engine: acceptance bookkeeping, cache commit (attention
invalidation + recurrent snapshot selection), max_new_tokens freezing, and
cross-layout losslessness — the paged (block-table) engine with bucketed
admission must emit token-for-token what the contiguous engine with
exact-length prefills emits, for dense, SSM, and hybrid targets.

The cross-layout suite is additionally parametrized over ``shard_model``
mesh sizes (0 = single device, 4, 8): a model-sharded engine (storage-
sharded weights + KV pools, sharding/rules.serve_state_specs) must emit the
exact same tokens as the single-device reference, including through
incremental page growth. Sharded cases run in CI's tier1-multidevice lane
(XLA_FLAGS=--xla_force_host_platform_device_count=8) and skip on a real
single-device run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DrafterConfig, get_config
from repro.core import drafter as D
from repro.models import get_model
from repro.serving import Engine, EngineConfig, Request, Scheduler, cache_ops
from repro.sharding.utils import serving_mesh

KEY = jax.random.PRNGKey(7)


from conftest import require_devices  # noqa: E402  (tests dir on sys.path)


def mesh_or_skip(n_devices: int):
    """Serving mesh over ``n_devices``, or None for 0; skips when jax does
    not see enough devices (the tier1-multidevice CI lane forces 8)."""
    if not n_devices:
        return None
    require_devices(n_devices)
    return serving_mesh(n_devices)


def test_commit_invalidates_stale_positions():
    cache = {"blocks": {"positions": jnp.array([[[0, 1, 2, 3, -1]]]),
                        "ring": jnp.array([False])}}
    out = cache_ops.commit(cache, None, jnp.array([1]), jnp.array([0]))
    assert out["blocks"]["positions"].tolist() == [[[0, 1, -1, -1, -1]]]


def test_commit_selects_recurrent_snapshot():
    B, T, H, P, N = 2, 3, 2, 2, 2
    cache = {"blocks": {"state": jnp.zeros((4, B, H, P, N))}}
    snaps = {"blocks": {"state": jnp.arange(4 * B * T * H * P * N,
                                            dtype=jnp.float32).reshape(
        4, B, T, H, P, N)}}
    idx = jnp.array([0, 2])
    out = cache_ops.commit(cache, snaps, jnp.zeros(B, jnp.int32), idx)
    expect0 = np.asarray(snaps["blocks"]["state"])[:, 0, 0]
    expect1 = np.asarray(snaps["blocks"]["state"])[:, 1, 2]
    np.testing.assert_array_equal(np.asarray(out["blocks"]["state"])[:, 0],
                                  expect0)
    np.testing.assert_array_equal(np.asarray(out["blocks"]["state"])[:, 1],
                                  expect1)


def test_max_new_tokens_freezes_rows():
    tcfg = get_config("qwen2-1.5b").reduced()
    m = get_model(tcfg)
    tparams = m.init(KEY)
    eng = Engine(tcfg, None, tparams, None,
                 EngineConfig(K=0, max_new_tokens=5, drafter_mode="none",
                              max_len=64), 2)
    prompts = jax.random.randint(KEY, (2, 4), 0, tcfg.vocab_size)
    r = eng.run(prompts)
    assert (np.asarray(r["state"]["new_count"]) == 5).all()
    # no tokens written beyond the budget
    assert r["tokens"].shape[1] == 64


@pytest.mark.parametrize("mode", ["parallel", "ar"])
def test_engine_losslessness_greedy(mode):
    """The engine docstring's core promise, asserted end-to-end: greedy
    speculative decoding (either drafter mode, even an untrained drafter)
    emits token-for-token what vanilla AR decoding emits."""
    tcfg = get_config("qwen2-1.5b").reduced()
    m = get_model(tcfg)
    tparams = m.init(KEY)
    dcfg = DrafterConfig(n_layers=1, k_infer=4).resolve(tcfg)
    dparams = D.init_params(dcfg, tcfg, jax.random.fold_in(KEY, 3))
    prompts = jax.random.randint(jax.random.fold_in(KEY, 4), (2, 5), 1,
                                 tcfg.vocab_size - 2)
    P, max_new = prompts.shape[1], 12

    ref = Engine(tcfg, None, tparams, None,
                 EngineConfig(K=0, max_new_tokens=max_new,
                              drafter_mode="none", max_len=64), 2).run(prompts)
    spec = Engine(tcfg, dcfg, tparams, dparams,
                  EngineConfig(K=4, max_new_tokens=max_new,
                               drafter_mode=mode, max_len=64), 2).run(prompts)
    # spec commits whole accepted blocks and may overshoot the budget;
    # the first max_new generated tokens must match exactly
    np.testing.assert_array_equal(ref["tokens"][:, P:P + max_new],
                                  spec["tokens"][:, P:P + max_new])
    assert (np.asarray(ref["state"]["new_count"]) == max_new).all()
    assert (np.asarray(spec["state"]["new_count"]) >= max_new).all()


@pytest.mark.parametrize("shard", [0, 4, 8])
@pytest.mark.parametrize("arch", ["qwen2-1.5b", "mamba2-780m",
                                  "recurrentgemma-2b"])
def test_cross_layout_losslessness(arch, shard):
    """Greedy decode through the paged engine (page-pool KV, block tables,
    power-of-two-bucketed admission prefills) equals the contiguous engine
    with exact-length prefills token-for-token, across prompt lengths that
    hit the pad path, the chunk path, and partial pages — for a dense, an
    SSM, and a hybrid (RG-LRU + local attention) target.

    ``shard`` > 0 runs the engine under test model-sharded over that many
    forced host devices (weights + KV pools storage-sharded, both layouts)
    while the reference stays single-device-layout: the sharded engine must
    reproduce it exactly, incremental page growth included."""
    mesh = mesh_or_skip(shard)
    tcfg = get_config(arch).reduced()
    m = get_model(tcfg)
    tparams = m.init(KEY)
    dcfg = DrafterConfig(n_layers=1, k_infer=2).resolve(tcfg)
    dparams = D.init_params(dcfg, tcfg, jax.random.fold_in(KEY, 3))

    def make(layout, bucket, sharded=False):
        return Engine(tcfg, dcfg, tparams, dparams,
                      EngineConfig(K=2, max_new_tokens=6,
                                   drafter_mode="parallel", max_len=64,
                                   kv_layout=layout, page_size=8,
                                   bucket_prefill=bucket,
                                   shard_model=sharded and mesh is not None,
                                   mesh=mesh if sharded else None), 2)

    rng = np.random.default_rng(23)
    lengths = [4, 5, 7, 3, 9]            # pow2, pow2±1, multi-chunk
    prompts = [rng.integers(1, tcfg.vocab_size - 2,
                            size=n).astype(np.int32) for n in lengths]
    budgets = [6, 3, 5, 4, 6]
    reqs = lambda: [Request(p, max_new_tokens=b)          # noqa: E731
                    for p, b in zip(prompts, budgets)]
    ref = Scheduler(make("contiguous", False)).serve(reqs())
    paged_eng = make("paged", True, sharded=True)
    got = Scheduler(paged_eng).serve(reqs())
    for r, g in zip(ref["results"], got["results"]):
        np.testing.assert_array_equal(
            r["tokens"], g["tokens"],
            err_msg=f"{arch}: request {r['rid']} diverged across layouts"
                    f" (shard={shard})")
    # paged bookkeeping drained cleanly
    assert paged_eng.allocator.n_free == paged_eng.pool_pages
    if shard:
        # not vacuous: at least the drafter KV pools genuinely sharded
        assert any(not s.is_fully_replicated
                   for s in jax.tree.leaves(paged_eng.paged_state_shardings))
        # the sharded *contiguous* engine must match the reference too
        got_c = Scheduler(make("contiguous", False, sharded=True)).serve(
            reqs())
        for r, g in zip(ref["results"], got_c["results"]):
            np.testing.assert_array_equal(
                r["tokens"], g["tokens"],
                err_msg=f"{arch}: contiguous sharded diverged (shard={shard})")


def test_bucketed_prefill_ring_window_safe():
    """Right-padding must never wrap a ring (sliding-window) cache: a pad
    written past the window would evict live prompt KV (slot = pos % W), so
    targets with ring layers take the chunking path instead. gemma2 reduced
    at max_len 128 has 64-window local layers; a length-65 prompt pads to a
    128 bucket — over the window — and must still decode token-exactly."""
    tcfg = get_config("gemma2-27b").reduced()
    m = get_model(tcfg)
    tparams = m.init(KEY)

    def make(bucket):
        return Engine(tcfg, None, tparams, None,
                      EngineConfig(K=0, max_new_tokens=4,
                                   drafter_mode="none", max_len=128,
                                   bucket_prefill=bucket), 2)

    eng = make(True)
    assert eng._chunk_only()      # ring KV detected → chunk, never pad
    rng = np.random.default_rng(31)
    prompts = [rng.integers(1, tcfg.vocab_size - 2,
                            size=n).astype(np.int32) for n in (65, 33)]
    ref = Scheduler(make(False)).serve([Request(p, max_new_tokens=4)
                                        for p in prompts])
    got = Scheduler(eng).serve([Request(p, max_new_tokens=4)
                                for p in prompts])
    for r, g in zip(ref["results"], got["results"]):
        np.testing.assert_array_equal(r["tokens"], g["tokens"])


def test_paged_decode_kernel_sharded_pool_pin():
    """kernels/ops.paged_decode_attention(mesh=...) — the TPU-path twin of
    the engine's gather boundary: a storage-sharded K/V pool passed to the
    SPMD-opaque pallas call must be gathered *at the pin*, and the result
    must be bitwise what the replicated call computes."""
    require_devices(4)
    from repro.kernels import ops
    from repro.sharding.rules import serve_state_specs
    from jax.sharding import NamedSharding

    mesh = serving_mesh(4)
    B, T, H, KV, hd, NP, page, nb = 2, 3, 4, 2, 64, 8, 4, 3
    k = jax.random.PRNGKey(11)
    q = jax.random.normal(k, (B, T, H, hd), jnp.float32)
    kp = jax.random.normal(jax.random.fold_in(k, 1), (NP, page, KV, hd))
    vp = jax.random.normal(jax.random.fold_in(k, 2), (NP, page, KV, hd))
    table = jnp.asarray([[0, 2, -1], [5, -1, -1]], jnp.int32)
    pos_pool = jnp.full((NP, page), -1, jnp.int32)
    pos_pool = pos_pool.at[0].set(jnp.arange(page))
    pos_pool = pos_pool.at[2, :2].set(page + jnp.arange(2))
    pos_pool = pos_pool.at[5, :3].set(jnp.arange(3))
    qpos = jnp.asarray([[5, 6, 7], [2, 3, 4]], jnp.int32)

    ref = ops.paged_decode_attention(q, kp, vp, pos_pool, table, qpos,
                                     scale=hd ** -0.5)
    # shard the pools at rest exactly as the serving profile would
    specs = serve_state_specs({"k": kp, "v": vp}, mesh)
    assert not NamedSharding(mesh, specs["k"]).is_fully_replicated
    kp_s = jax.device_put(kp, NamedSharding(mesh, specs["k"]))
    vp_s = jax.device_put(vp, NamedSharding(mesh, specs["v"]))
    got = ops.paged_decode_attention(q, kp_s, vp_s, pos_pool, table, qpos,
                                     scale=hd ** -0.5, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_acceptance_length_accounting():
    """With a drafter that IS the target (perfect drafts), AL == K+1."""
    tcfg = get_config("qwen2-1.5b").reduced()
    m = get_model(tcfg)
    tparams = m.init(KEY)

    # train-free perfect-drafter trick: use the engine in 'none' mode to get
    # reference output; then check a parallel engine with an UNTRAINED
    # drafter still produces consistent bookkeeping: committed ==
    # sum(new_count) - B and AL in [1, K+1].
    dcfg = DrafterConfig(n_layers=1, k_infer=3).resolve(tcfg)
    dparams = D.init_params(dcfg, tcfg, jax.random.fold_in(KEY, 2))
    eng = Engine(tcfg, dcfg, tparams, dparams,
                 EngineConfig(K=3, max_new_tokens=9, drafter_mode="parallel",
                              max_len=64), 2)
    prompts = jax.random.randint(KEY, (2, 4), 0, tcfg.vocab_size)
    r = eng.run(prompts)
    st = r["state"]
    assert int(st["committed"]) == int(np.sum(np.asarray(st["new_count"]))) - 2
    assert 1.0 <= r["acceptance_length"] <= 4.0
