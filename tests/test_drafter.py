"""Drafter-specific behaviour: variant plumbing, inference/training
consistency (the parallel draft block computes the same distribution the
MTP training forward assigns to a single chain), and embedding freezing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DrafterConfig, get_config
from repro.core import drafter as D

KEY = jax.random.PRNGKey(0)
TCFG = get_config("qwen2-1.5b").reduced()


@pytest.mark.parametrize("variant", [
    "shared",                       # the paper's winner stays in the fast set
    pytest.param("depth_encoding", marks=pytest.mark.slow),
    pytest.param("ntp_hidden", marks=pytest.mark.slow),
    pytest.param("ntp_hidden_depth", marks=pytest.mark.slow),
    pytest.param("regularized", marks=pytest.mark.slow)])
def test_variants_forward(variant):
    dcfg = DrafterConfig(n_layers=1, k_train=3,
                         hidden_state_variant=variant).resolve(TCFG)
    params = D.init_params(dcfg, TCFG, KEY)
    B, n, M = 2, 16, 24
    tokens = jax.random.randint(KEY, (B, n), 0, TCFG.vocab_size)
    taps = 0.1 * jax.random.normal(KEY, (B, n, 3 * TCFG.d_model))
    pos = jnp.concatenate([jnp.arange(16), jnp.arange(8) + 1])
    depth = jnp.concatenate([jnp.zeros(16, jnp.int32),
                             jnp.ones(8, jnp.int32)]).astype(jnp.int32)
    pos = pos.astype(jnp.int32)
    logits, hidden = D.mtp_forward(dcfg, TCFG, params, tokens, taps, pos,
                                   depth, rng=KEY)
    assert logits.shape == (B, 24, TCFG.vocab_size)
    assert not bool(jnp.isnan(logits).any())


def test_regularized_has_alpha():
    dcfg = DrafterConfig(hidden_state_variant="regularized").resolve(TCFG)
    params = D.init_params(dcfg, TCFG, KEY)
    assert float(params["alpha"]) == pytest.approx(0.1)


@pytest.mark.slow
def test_freeze_embeddings_stops_gradient():
    from repro.core import losses
    for freeze in (True, False):
        dcfg = DrafterConfig(n_layers=1, k_train=2,
                             freeze_embeddings=freeze).resolve(TCFG)
        params = D.init_params(dcfg, TCFG, KEY)
        B, n = 2, 12
        tokens = jax.random.randint(KEY, (B, n), 0, TCFG.vocab_size)
        taps = 0.1 * jax.random.normal(KEY, (B, n, 3 * TCFG.d_model))
        pos = jnp.arange(n, dtype=jnp.int32)
        depth = jnp.zeros(n, jnp.int32)
        labels = jnp.concatenate([tokens[:, 2:],
                                  jnp.full((B, 2), -1, tokens.dtype)], 1)

        def loss(p):
            lg, _ = D.mtp_forward(dcfg, TCFG, p, tokens, taps, pos, depth)
            return losses.mtp_loss(lg, labels, depth)[0]

        g = jax.grad(loss)(params)
        gn = float(jnp.abs(g["embed"]).sum())
        if freeze:
            assert gn == 0.0
        else:
            assert gn > 0.0


def test_parallel_draft_matches_training_semantics():
    """Train a no-op check: the draft block (slot 0 NTP + MTP slots) scores
    the same chain the training mask builds for equal anchors — verify by
    comparing draft_parallel logits against mtp_forward on an equivalent
    single-chain layout with empty context handled by the cache."""
    dcfg = DrafterConfig(n_layers=1, k_train=4, k_infer=4).resolve(TCFG)
    params = D.init_params(dcfg, TCFG, KEY)
    B, n, K = 1, 8, 4
    tokens = jax.random.randint(KEY, (B, n), 0, TCFG.vocab_size)
    taps = 0.1 * jax.random.normal(KEY, (B, n, 3 * TCFG.d_model))

    # training layout: depth-0 chain over all n positions + one MTP chain
    # anchored at position a = n-1... the NTP slot of the draft equals the
    # depth-0 position at a, MTP slot g equals (g, a+g).
    a = n - 2
    pos = jnp.concatenate([jnp.arange(n),
                           a + 1 + jnp.arange(K - 1)]).astype(jnp.int32)
    depth = jnp.concatenate([jnp.zeros(n, jnp.int32),
                             1 + jnp.arange(K - 1)]).astype(jnp.int32)
    logits_train, _ = D.mtp_forward(dcfg, TCFG, params, tokens, taps, pos,
                                    depth)

    # inference layout: extend cache over positions 0..a-1, then draft at
    # anchor a with token t_{a+1} and taps[a].
    cache = D.make_cache(dcfg, B, n + K, dtype=jnp.float32)
    if a >= 1:
        posx = jnp.broadcast_to(jnp.arange(a, dtype=jnp.int32)[None], (B, a))
        cache = D.extend(dcfg, TCFG, params, cache, tokens[:, 1:a + 1],
                         taps[:, :a], posx)
    toks_d, logits_draft, _ = D.draft_parallel(
        dcfg, TCFG, params, cache, tokens[:, a + 1], taps[:, a],
        jnp.full((B,), a, jnp.int32), K)

    # slot 0 of the draft == training depth-0 position a
    np.testing.assert_allclose(np.asarray(logits_draft[:, 0]),
                               np.asarray(logits_train[:, a]),
                               atol=2e-4, rtol=2e-3)
    # MTP slots g == training positions (g, a+g)
    for g in range(1, K):
        np.testing.assert_allclose(
            np.asarray(logits_draft[:, g]),
            np.asarray(logits_train[:, n + g - 1]),
            atol=2e-4, rtol=2e-3, err_msg=f"slot {g}")


def test_mask_token_uses_reserved_id():
    assert D.mask_token_id(TCFG) == TCFG.vocab_size - 1
