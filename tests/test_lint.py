"""repro-lint self-tests: every rule family catches a purpose-built bad
fixture and passes its good twin; suppression comments and the baseline
add/expire semantics behave; the state-surgery checker fails when a real
surgery surface loses a leaf handler; and the live tree is clean modulo
the checked-in baseline.

Pure stdlib (ast + the linter itself) — no jax imports, so this file is
cheap enough to run in tier-1 even though CI also runs the linter
directly in its ``lint`` job.
"""
import os
import shutil
import sys
import textwrap

import pytest

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
if REPO_ROOT not in sys.path:
    sys.path.insert(0, REPO_ROOT)

from tools.lint import surgery  # noqa: E402
from tools.lint.core import (RefusedPath, collect_files, lint_file,  # noqa: E402
                             lint_source, load_baseline, match_baseline,
                             write_baseline)

SERVING = "src/repro/serving/fixture.py"


def rules_of(findings):
    return [f.rule for f in findings]


def lint(src, relpath="src/repro/fixture.py", rules=None):
    return lint_source(textwrap.dedent(src), relpath, rules)


# ---------------------------------------------------------------------------
# PRNG
# ---------------------------------------------------------------------------

def test_prng01_flags_split_and_carry():
    out = lint("""
        import jax

        def draw(key):
            key, sub = jax.random.split(key)
            return sub
    """)
    assert rules_of(out) == ["PRNG01"]


def test_prng01_flags_attribute_carry_and_aliased_import():
    out = lint("""
        from jax import random as jr

        class T:
            def advance(self):
                self.rng, sub = jr.split(self.rng)
                return sub
    """)
    assert rules_of(out) == ["PRNG01"]


def test_prng01_good_fold_in_counter_stream():
    out = lint("""
        import jax

        def draw(base, i):
            sub = jax.random.split(jax.random.fold_in(base, i), 2)
            return sub
    """)
    assert "PRNG01" not in rules_of(out)


def test_prng02_flags_key_passed_to_two_draws():
    out = lint("""
        import jax

        def draw(key, logits):
            a = jax.random.categorical(key, logits)
            b = jax.random.uniform(key, (4,))
            return a, b
    """)
    assert rules_of(out) == ["PRNG02"]


def test_prng02_good_distinct_fold_ins():
    out = lint("""
        import jax

        def draw(key, logits):
            a = jax.random.categorical(jax.random.fold_in(key, 0), logits)
            b = jax.random.uniform(jax.random.fold_in(key, 1), (4,))
            return a, b
    """)
    assert "PRNG02" not in rules_of(out)


def test_prng03_flags_unsalted_serving_stream():
    out = lint("""
        import jax

        def proposals(samp, pos):
            base = step_keys(samp, pos)
            ks = jax.random.split(base, 4)
            return ks
    """, relpath=SERVING)
    assert rules_of(out) == ["PRNG03"]


def test_prng03_good_salted_stream_and_vmap_idiom():
    # both forms of the sampling.py draft_keys idiom must pass: direct
    # fold_in, and fold_in inside a vmapped lambda over the base stream
    out = lint("""
        import jax

        DRAFT_SALT = 0x5EED

        def draft_keys(samp, pos, k):
            base = jax.random.fold_in(step_keys(samp, pos), DRAFT_SALT)
            direct = jax.random.split(base, k)
            mapped = jax.vmap(
                lambda b: jax.random.split(
                    jax.random.fold_in(b, DRAFT_SALT), k)
            )(step_keys(samp, pos))
            return direct, mapped
    """, relpath=SERVING)
    assert "PRNG03" not in rules_of(out)


def test_prng03_scoped_to_serving():
    out = lint("""
        import jax

        def proposals(samp, pos):
            return jax.random.split(step_keys(samp, pos), 4)
    """, relpath="src/repro/training/fixture.py")
    assert "PRNG03" not in rules_of(out)


# ---------------------------------------------------------------------------
# TRACE
# ---------------------------------------------------------------------------

def test_trace01_flags_unmarked_bool_arg():
    out = lint("""
        import jax

        @jax.jit
        def step(state, greedy=False):
            return state
    """)
    assert rules_of(out) == ["TRACE01"]


def test_trace01_good_static_argnames_and_partial_binding():
    out = lint("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("greedy",))
        def step(state, greedy=False):
            return state

        def bound_impl(state, greedy=False):
            return state

        twins = {g: jax.jit(functools.partial(bound_impl, greedy=g))
                 for g in (False, True)}
    """)
    assert "TRACE01" not in rules_of(out)


def test_trace01_sees_through_jit_wrapper_helpers():
    # _greedy_twins binds greedy_only via partial INSIDE the helper; the
    # module-wide partial-bound name set must exempt the impl's parameter
    out = lint("""
        import functools
        import jax

        def _greedy_twins(fn, **kw):
            return {g: jax.jit(functools.partial(fn, greedy_only=g), **kw)
                    for g in (False, True)}

        def _step_impl(state, greedy_only=False):
            return state

        step = _greedy_twins(_step_impl)
    """)
    assert "TRACE01" not in rules_of(out)


def test_trace02_flags_host_materialization_in_jitted_body():
    out = lint("""
        import jax
        import numpy as np

        @jax.jit
        def step(state, x):
            n = int(x)
            v = x.item()
            arr = np.asarray(state)
            msg = f"value={x}"
            return n, v, arr, msg
    """)
    assert rules_of(out) == ["TRACE02"] * 4


def test_trace02_good_shape_arithmetic_and_unjitted_host_code():
    out = lint("""
        import jax
        import numpy as np

        @jax.jit
        def step(x):
            n = int(x.shape[0] * x.ndim)
            m = f"batch={x.shape[0]}"
            return n, m

        def host_harness(x):
            return int(x), np.asarray(x)
    """)
    assert "TRACE02" not in rules_of(out)


def test_trace02_covers_impl_naming_convention():
    out = lint("""
        def _step_impl(state, x):
            return x.item()
    """)
    assert rules_of(out) == ["TRACE02"]


# ---------------------------------------------------------------------------
# SYNC
# ---------------------------------------------------------------------------

def test_sync01_flags_state_readback_outside_harvest():
    out = lint("""
        import numpy as np

        def poll(state):
            return np.asarray(state["new_count"])
    """, relpath=SERVING)
    assert rules_of(out) == ["SYNC01"]


def test_sync01_ignores_non_state_and_non_serving():
    clean = lint("""
        import numpy as np

        def encode(prompts):
            return np.asarray(prompts)
    """, relpath=SERVING)
    assert "SYNC01" not in rules_of(clean)
    elsewhere = lint("""
        import numpy as np

        def poll(state):
            return np.asarray(state["new_count"])
    """, relpath="src/repro/training/fixture.py")
    assert "SYNC01" not in rules_of(elsewhere)


# ---------------------------------------------------------------------------
# SHARD
# ---------------------------------------------------------------------------

def test_shard01_flags_bare_jit_in_mesh_module():
    out = lint("""
        import jax

        def build(self, fn, mesh):
            return jax.jit(fn)
    """, relpath=SERVING)
    assert rules_of(out) == ["SHARD01"]


def test_shard01_good_shardings_kwargs_forward_and_mesh_none_branch():
    out = lint("""
        import jax

        def build(self, fn, shd, jit_kwargs):
            if self.mesh is None:
                return jax.jit(fn)
            a = jax.jit(fn, in_shardings=shd)
            b = jax.jit(fn, **jit_kwargs)
            return a, b
    """, relpath=SERVING)
    assert "SHARD01" not in rules_of(out)


def test_shard01_silent_in_meshless_module():
    out = lint("""
        import jax

        def build(fn):
            return jax.jit(fn)
    """, relpath=SERVING)
    assert "SHARD01" not in rules_of(out)


# ---------------------------------------------------------------------------
# ALLOC
# ---------------------------------------------------------------------------

def test_alloc01_flags_allocator_internals_outside_class():
    out = lint("""
        def steal(alloc):
            page = alloc._free.pop()
            alloc._ref[page] = 1
            return page
    """)
    assert rules_of(out) == ["ALLOC01", "ALLOC01"]


def test_alloc01_good_inside_owner_and_unrelated_attrs():
    out = lint("""
        class BlockAllocator:
            def alloc(self):
                return self._free.pop()

        class Engine:
            def __init__(self):
                self._free = None    # jitted free fn, not the allocator
    """)
    assert "ALLOC01" not in rules_of(out)


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def test_suppression_same_line_and_previous_line():
    src = """
        import jax

        def draw(key, other):
            key, a = jax.random.split(key)  # repro-lint: disable=PRNG01
            # repro-lint: disable=PRNG01
            other, b = jax.random.split(other)
            return a, b
    """
    assert lint(src) == []


def test_suppression_is_rule_specific():
    out = lint("""
        import jax

        def draw(key):
            key, a = jax.random.split(key)  # repro-lint: disable=PRNG02
            return a
    """)
    assert rules_of(out) == ["PRNG01"]


def test_file_level_suppression():
    out = lint("""
        # repro-lint: disable-file=PRNG01
        import jax

        def draw(key, other):
            key, a = jax.random.split(key)
            other, b = jax.random.split(other)
            return a, b
    """)
    assert "PRNG01" not in rules_of(out)


# ---------------------------------------------------------------------------
# baseline semantics
# ---------------------------------------------------------------------------

def test_baseline_absorbs_then_expires(tmp_path):
    findings = lint("""
        import jax

        def draw(key):
            key, a = jax.random.split(key)
            return a
    """)
    assert rules_of(findings) == ["PRNG01"]
    bl = tmp_path / "baseline.txt"
    write_baseline(str(bl), findings)
    entries = load_baseline(str(bl))
    assert len(entries) == 1

    new, stale = match_baseline(findings, entries)
    assert new == [] and stale == []
    # fixing the finding makes the entry STALE — the run must not pass
    new, stale = match_baseline([], entries)
    assert new == [] and stale == entries
    # an unrelated new finding is NEW even with a populated baseline
    other = lint("""
        import jax

        def other(k):
            k, b = jax.random.split(k)
            return b
    """)
    new, stale = match_baseline(other, entries)
    assert rules_of(new) == ["PRNG01"] and stale == entries


def test_baseline_rejects_malformed_lines(tmp_path):
    bl = tmp_path / "baseline.txt"
    bl.write_text("# comment ok\nPRNG01\tonly-two-fields\n")
    with pytest.raises(ValueError):
        load_baseline(str(bl))


# ---------------------------------------------------------------------------
# file collection hygiene
# ---------------------------------------------------------------------------

def test_collect_files_refuses_compiled_artifacts(tmp_path):
    pyc_dir = tmp_path / "pkg" / "__pycache__"
    pyc_dir.mkdir(parents=True)
    (pyc_dir / "mod.cpython-311.pyc").write_bytes(b"\x00")
    (tmp_path / "pkg" / "mod.py").write_text("x = 1\n")
    with pytest.raises(RefusedPath):
        collect_files([str(pyc_dir)], str(tmp_path))
    with pytest.raises(RefusedPath):
        collect_files([str(pyc_dir / "mod.cpython-311.pyc")], str(tmp_path))
    # walking the parent silently SKIPS the cache dir instead
    files = collect_files(["pkg"], str(tmp_path))
    assert [os.path.basename(f) for f in files] == ["mod.py"]


# ---------------------------------------------------------------------------
# SURG01: state-surgery completeness against the real tree
# ---------------------------------------------------------------------------

SURGERY_FILES = [surgery.ENGINE, surgery.SCHEDULER, surgery.CACHE_OPS,
                 surgery.RULES, surgery.STEPS]


def _copy_tree(tmp_path):
    for rel in SURGERY_FILES:
        dst = tmp_path / rel
        dst.parent.mkdir(parents=True, exist_ok=True)
        shutil.copyfile(os.path.join(REPO_ROOT, rel), dst)
    return str(tmp_path)


def _mutate(root, rel, old, new):
    full = os.path.join(root, rel)
    with open(full, "r", encoding="utf-8") as f:
        src = f.read()
    assert old in src, f"mutation anchor not found in {rel}: {old!r}"
    with open(full, "w", encoding="utf-8") as f:
        f.write(src.replace(old, new))


def test_surg01_clean_on_real_tree(tmp_path):
    assert surgery.check_repo(_copy_tree(tmp_path)) == []


def test_surg01_detects_dropped_swap_reset(tmp_path):
    root = _copy_tree(tmp_path)
    _mutate(root, surgery.ENGINE,
            'snap["slot_iters"] = np.zeros_like(snap["slot_iters"])', "pass")
    out = surgery.check_repo(root)
    assert any(f.rule == "SURG01" and f.qualname == "swap_out_slot"
               and "slot_iters" in f.message for f in out)


def test_surg01_detects_dropped_kv_sharding_handler(tmp_path):
    root = _copy_tree(tmp_path)
    # deleting the k/v handler from _serve_state_leaf must fail the check
    _mutate(root, surgery.RULES,
            'if name in ("k", "v") and leaf.ndim >= 4:',
            'if name in ("positions",) and leaf.ndim >= 4:')
    out = surgery.check_repo(root)
    assert any(f.rule == "SURG01" and f.path == surgery.RULES for f in out)


def test_surg01_detects_leaf_dropped_from_step_rebuild(tmp_path):
    root = _copy_tree(tmp_path)
    _mutate(root, surgery.ENGINE,
            "slot_iters=state[\"slot_iters\"] + active.astype(jnp.int32),",
            "")
    out = surgery.check_repo(root)
    assert any(f.qualname == "speculative_step"
               and "slot_iters" in f.message for f in out)


def test_surg01_detects_leaf_missing_from_launch_template(tmp_path):
    root = _copy_tree(tmp_path)
    _mutate(root, surgery.STEPS, '"new_count": spec_for((GB,), bsp[0]),', "")
    out = surgery.check_repo(root)
    assert any(f.path == surgery.STEPS and "new_count" in f.message
               for f in out)


def test_surg01_detects_harvest_dropping_a_leaf(tmp_path):
    root = _copy_tree(tmp_path)
    _mutate(root, surgery.SCHEDULER,
            'logprobs = np.asarray(state["logprobs"])', "logprobs = None")
    out = surgery.check_repo(root)
    assert any(f.qualname == "Scheduler._harvest"
               and "logprobs" in f.message for f in out)


def test_surg01_new_state_leaf_flags_stale_surfaces(tmp_path):
    # the forward direction: ADD a leaf to make_decode_state and every
    # surface that wasn't updated must light up
    root = _copy_tree(tmp_path)
    _mutate(root, surgery.ENGINE,
            '"slot_iters": jnp.zeros((batch,), jnp.int32),',
            '"slot_iters": jnp.zeros((batch,), jnp.int32),\n'
            '        "new_leaf": jnp.zeros((batch,), jnp.int32),')
    out = surgery.check_repo(root)
    stale_surfaces = {f.path for f in out if "new_leaf" in f.message}
    assert surgery.ENGINE in stale_surfaces   # speculative_step rebuild
    assert surgery.STEPS in stale_surfaces    # launch state_specs template


# ---------------------------------------------------------------------------
# live-tree self-check: the committed tree is clean modulo the baseline
# ---------------------------------------------------------------------------

def test_live_tree_clean_modulo_baseline():
    files = collect_files(["src", "tools"], REPO_ROOT)
    findings = []
    for path in files:
        findings.extend(lint_file(path, REPO_ROOT))
    findings.extend(surgery.check_repo(REPO_ROOT))
    entries = load_baseline(
        os.path.join(REPO_ROOT, "tools", "lint", "baseline.txt"))
    new, stale = match_baseline(findings, entries)
    assert new == [], "new lint findings:\n" + "\n".join(
        f.render() for f in new)
    assert stale == [], "stale baseline entries:\n" + "\n".join(
        "\t".join(e) for e in stale)
