"""Streaming front-end property suite (serving/streaming.AsyncEngine +
launch/serve_stream NDJSON server).

The acceptance pin is driver-twin equivalence: a wall-clock streamed run
yields token-for-token (and logprob-for-logprob) exactly what the
deterministic virtual-clock ``Scheduler.serve`` produces for the same
(prompt, SamplingParams) workload — for greedy AND seeded-sampled
requests, under churn, random aborts, preemption pressure, and
backpressure. Plus the streaming-only invariants:

- a yielded token is never retracted: an aborted stream's received prefix
  is a prefix of the twin's full stream;
- aborts free pages immediately — the pool drains after every session and
  aborted slots are reused by later admissions (survivors still finish);
- ``max_pending`` backpressure bounds in-flight requests without
  deadlocking, and a rejected submit returns its admission ticket;
- the NDJSON socket front-end round-trips generate/abort/health ops.

Async plumbing note: everything runs through ``asyncio.run`` inside sync
tests with a hard ``wait_for`` so a livelocked dispatch loop fails the
test instead of hanging CI (the workflow additionally wraps this file in
a process-level timeout).
"""
import asyncio
import json
from functools import lru_cache

import jax
import numpy as np
import pytest

from repro.configs import DrafterConfig, get_config
from repro.core import drafter as D
from repro.launch.serve_stream import start_stream_server
from repro.models import get_model
from repro.serving import (AsyncEngine, Engine, EngineConfig,
                           SamplingParams, virtual_twin_report)

KEY = jax.random.PRNGKey(23)


@lru_cache(maxsize=None)
def _setup():
    tcfg = get_config("qwen2-1.5b").reduced()
    m = get_model(tcfg)
    tparams = m.init(KEY)
    dcfg = DrafterConfig(n_layers=1, k_infer=2).resolve(tcfg)
    dparams = D.init_params(dcfg, tcfg, jax.random.fold_in(KEY, 1))
    return tcfg, dcfg, tparams, dparams


@lru_cache(maxsize=None)
def get_engine(pool_pages=0, batch=2):
    tcfg, dcfg, tparams, dparams = _setup()
    return Engine(tcfg, dcfg, tparams, dparams,
                  EngineConfig(K=2, max_new_tokens=16,
                               drafter_mode="parallel", max_len=64,
                               kv_layout="paged", page_size=8,
                               pool_pages=pool_pages,
                               kv_growth="incremental"), batch)


def run(coro, timeout=600):
    return asyncio.run(asyncio.wait_for(coro, timeout))


def assert_pool_drained(eng):
    assert eng.allocator.n_free == eng.pool_pages, "leaked pages"
    assert all(not ps for ps in eng._slot_pages), "slot still holds pages"


def make_workload(seed, n, max_budget=8):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        p = rng.integers(1, 200,
                         size=int(rng.integers(2, 9))).astype(np.int32)
        sp = (None if i % 2 == 0
              else SamplingParams(temperature=0.8, seed=50 + i))
        out.append((p, sp, int(rng.integers(2, max_budget + 1))))
    return out


# ---------------------------------------------------------------------------
# driver-twin equivalence (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["greedy", "sampled"])
def test_streamed_equals_virtual_twin(policy):
    """Concurrent generate() streams — arriving in wall-clock order the
    virtual twin never saw — yield exactly the twin's per-request token
    and logprob sequences, both policies."""
    eng = get_engine()
    rng = np.random.default_rng(1 if policy == "greedy" else 2)
    workload = [(rng.integers(1, 200, size=int(rng.integers(2, 9))
                              ).astype(np.int32),
                 None if policy == "greedy"
                 else SamplingParams(temperature=0.7, top_p=0.9, seed=7 + i),
                 int(rng.integers(3, 9)))
                for i in range(5)]
    twin = virtual_twin_report(eng, workload)
    assert_pool_drained(eng)

    async def go():
        aeng = AsyncEngine(eng)

        async def one(p, sp, b):
            out = []
            async for tok, lp in aeng.generate(p, sp, max_new_tokens=b):
                out.append((tok, lp))
            return out

        streams = await asyncio.gather(*(one(*w) for w in workload))
        return streams, await aeng.close()

    streams, rep = run(go())
    assert rep["aborted"] == 0 and rep["n_requests"] == len(workload)
    for got, ref in zip(streams, twin["results"]):
        assert [t for t, _ in got] == ref["tokens"].tolist()
        np.testing.assert_allclose(
            np.asarray([lp for _, lp in got], np.float32),
            ref["logprobs"], rtol=1e-5)
    assert_pool_drained(eng)


def test_streamed_tokens_never_exceed_stop_or_budget():
    """The emit path flushes only stop/budget-trimmed FINAL tokens: with a
    stop id planted mid-stream, the streamed sequence ends exactly at its
    first occurrence — never a token after it."""
    eng = get_engine()
    rng = np.random.default_rng(3)
    p = rng.integers(1, 200, size=5).astype(np.int32)
    ref = virtual_twin_report(eng, [(p, None, 12)])["results"][0]
    stop = int(ref["tokens"][4])
    want = ref["tokens"].tolist()
    want = want[:want.index(stop) + 1]

    async def go():
        aeng = AsyncEngine(eng, eos_id=stop)
        out = [t async for t, _ in aeng.generate(p, max_new_tokens=12)]
        await aeng.close()
        return out

    assert run(go()) == want
    assert_pool_drained(eng)


# ---------------------------------------------------------------------------
# churn + aborts under pool pressure
# ---------------------------------------------------------------------------

def test_churn_random_aborts_no_leaks_survivors_exact():
    """Concurrent streams over a deliberately tight pool with random
    mid-stream aborts: no page leaks, aborted slots get reused (survivors
    all finish), every survivor matches the virtual twin token-for-token,
    and every aborted stream's received prefix is a prefix of its twin
    stream (nothing yielded was ever wrong)."""
    eng = get_engine(pool_pages=6)
    workload = make_workload(seed=4, n=8)
    twin = virtual_twin_report(eng, workload)
    assert_pool_drained(eng)
    rng = np.random.default_rng(5)
    # abort roughly half the requests after 1..3 received tokens
    abort_after = {i: int(rng.integers(1, 4))
                   for i in range(len(workload)) if rng.random() < 0.5}

    async def go():
        aeng = AsyncEngine(eng, max_pending=4)

        async def one(i, p, sp, b):
            out, handle = [], await aeng.submit(p, sp, max_new_tokens=b)
            async for tok, _ in handle:
                out.append(tok)
                if len(out) == abort_after.get(i):
                    handle.abort()
            return out, handle.aborted

        res = await asyncio.gather(*(one(i, *w)
                                     for i, w in enumerate(workload)))
        return res, await aeng.close()

    res, rep = run(go())
    n_aborted = sum(ab for _, ab in res)
    assert rep["aborted"] == n_aborted
    for (got, ab), ref in zip(res, twin["results"]):
        full = ref["tokens"].tolist()
        if ab:
            assert got == full[:len(got)], "aborted stream retracted a token"
        else:
            assert got == full, "survivor diverged from the virtual twin"
    # the tight pool forces slot turnover, so if aborted pages leaked the
    # survivors could not all have finished; verify the books directly too
    assert_pool_drained(eng)


def test_abort_waiting_request_before_admission():
    """Aborting a still-queued request removes it without a slot ever being
    claimed; co-submitted requests are untouched."""
    eng = get_engine()
    workload = make_workload(seed=6, n=2)
    twin = virtual_twin_report(eng, workload)
    assert_pool_drained(eng)
    rng = np.random.default_rng(7)
    extra = rng.integers(1, 200, size=4).astype(np.int32)

    async def go():
        aeng = AsyncEngine(eng, max_pending=8)
        handles = [await aeng.submit(p, sp, max_new_tokens=b)
                   for p, sp, b in workload]
        victim = await aeng.submit(extra, max_new_tokens=8)
        assert victim.abort()
        assert not victim.abort(), "abort must be idempotent"
        streams = []
        for h in handles:
            streams.append([t async for t, _ in h])
        vic = [t async for t, _ in victim]
        return streams, vic, await aeng.close()

    streams, vic, rep = run(go())
    assert rep["aborted"] == 1
    aborted_row = [r for r in rep["results"] if r["aborted"]]
    assert len(aborted_row) == 1 and aborted_row[0]["n_new"] == 0
    assert vic == []
    for got, ref in zip(streams, twin["results"]):
        assert got == ref["tokens"].tolist()
    assert_pool_drained(eng)


def test_close_without_drain_aborts_inflight():
    eng = get_engine()
    rng = np.random.default_rng(8)
    p = rng.integers(1, 200, size=6).astype(np.int32)

    async def go():
        aeng = AsyncEngine(eng)
        handle = await aeng.submit(p, max_new_tokens=16)
        rep = await aeng.close(drain=False)
        return handle.aborted, rep

    aborted, rep = run(go())
    assert aborted and rep["aborted"] == 1
    assert_pool_drained(eng)


# ---------------------------------------------------------------------------
# backpressure + health
# ---------------------------------------------------------------------------

def test_backpressure_bounds_inflight_without_deadlock():
    """max_pending admission tickets cap queued+running requests; a
    monitor sampling health() between syncs must never observe more, and
    every request still completes (tickets are released on finish)."""
    eng = get_engine()
    workload = make_workload(seed=9, n=6, max_budget=5)
    twin = virtual_twin_report(eng, workload)
    assert_pool_drained(eng)

    async def go():
        aeng = AsyncEngine(eng, max_pending=2)
        await aeng.start()
        seen = []
        stop = asyncio.Event()

        async def monitor():
            while not stop.is_set():
                seen.append(aeng.health()["inflight"])
                await asyncio.sleep(0)

        async def one(p, sp, b):
            return [t async for t, _ in aeng.generate(p, sp,
                                                      max_new_tokens=b)]

        mon = asyncio.get_running_loop().create_task(monitor())
        streams = await asyncio.gather(*(one(*w) for w in workload))
        stop.set()
        await mon
        return streams, seen, await aeng.close()

    streams, seen, rep = run(go())
    assert max(seen) <= 2 and max(seen) >= 1
    assert rep["n_requests"] == len(workload) and rep["aborted"] == 0
    for got, ref in zip(streams, twin["results"]):
        assert got == ref["tokens"].tolist()
    assert_pool_drained(eng)


def test_rejected_submit_returns_ticket():
    """A submit that fails validation (budget can never fit max_len) must
    not consume an admission ticket: with max_pending=1 a follow-up valid
    request still goes through."""
    eng = get_engine()
    rng = np.random.default_rng(10)
    p = rng.integers(1, 200, size=4).astype(np.int32)

    async def go():
        aeng = AsyncEngine(eng, max_pending=1)
        with pytest.raises(ValueError):
            await aeng.submit(p, max_new_tokens=10_000)
        out = [t async for t, _ in aeng.generate(p, max_new_tokens=3)]
        return out, await aeng.close()

    out, rep = run(go())
    assert len(out) == 3 and rep["n_requests"] == 1
    assert_pool_drained(eng)


def test_health_snapshot_shape():
    eng = get_engine()
    rng = np.random.default_rng(11)
    p = rng.integers(1, 200, size=4).astype(np.int32)

    async def go():
        aeng = AsyncEngine(eng)
        out = [t async for t, _ in aeng.generate(p, max_new_tokens=4)]
        h = aeng.health()
        rep = await aeng.close()
        return out, h, rep

    out, h, rep = run(go())
    assert len(out) == 4
    for k in ("queue_depth", "running", "slots", "inflight", "max_pending",
              "pool_pages", "pool_free", "pool_occupancy", "finished",
              "aborted", "preemptions", "p50_wait_s", "p99_wait_s",
              "uptime_s"):
        assert k in h, k
    assert h["finished"] == 1 and h["queue_depth"] == 0
    assert h["slots"] == eng.batch and h["pool_pages"] == eng.pool_pages
    assert 0.0 <= h["pool_occupancy"] <= 1.0
    assert h["p99_wait_s"] >= h["p50_wait_s"] >= 0.0
    assert rep["results"][0]["wait_s"] >= 0.0
    assert rep["results"][0]["latency_s"] >= rep["results"][0]["wait_s"]


# ---------------------------------------------------------------------------
# NDJSON socket front-end
# ---------------------------------------------------------------------------

def test_ndjson_socket_roundtrip():
    """generate (greedy + sampled) / abort / health / unknown-op over a real
    socket: streamed tokens match the virtual twin, the aborted stream
    terminates with an aborted done event, bad ops get error events."""
    eng = get_engine()
    rng = np.random.default_rng(12)
    p0 = rng.integers(1, 200, size=5).astype(np.int32)
    p1 = rng.integers(1, 200, size=7).astype(np.int32)
    sp1 = SamplingParams(temperature=0.8, seed=3)
    twin = virtual_twin_report(eng, [(p0, None, 5), (p1, sp1, 6)])
    assert_pool_drained(eng)

    async def go():
        aeng = AsyncEngine(eng)
        server = await start_stream_server(aeng, port=0)
        port = server.sockets[0].getsockname()[1]
        reader, writer = await asyncio.open_connection("127.0.0.1", port)

        def send(obj):
            writer.write((json.dumps(obj) + "\n").encode())

        send({"op": "generate", "id": "g", "prompt": p0.tolist(),
              "max_new_tokens": 5})
        send({"op": "generate", "id": "s", "prompt": p1.tolist(),
              "max_new_tokens": 6, "temperature": 0.8, "seed": 3})
        send({"op": "generate", "id": "a", "prompt": p0.tolist(),
              "max_new_tokens": 16})
        send({"op": "abort", "id": "a"})
        send({"op": "health"})
        send({"op": "nonsense"})
        await writer.drain()
        toks, lps, done, health, errors = {}, {}, {}, None, []
        while len(done) < 3 or health is None or not errors:
            msg = json.loads(await reader.readline())
            ev = msg.get("event")
            if ev == "tokens":
                toks.setdefault(msg["id"], []).extend(msg["tokens"])
                lps.setdefault(msg["id"], []).extend(msg["logprobs"])
            elif ev == "done":
                done[msg["id"]] = msg
            elif ev == "health":
                health = msg
            elif ev == "error":
                errors.append(msg)
        writer.close()
        server.close()
        await server.wait_closed()
        await aeng.close()
        return toks, lps, done, health, errors

    toks, lps, done, health, errors = run(go())
    assert toks["g"] == twin["results"][0]["tokens"].tolist()
    assert toks["s"] == twin["results"][1]["tokens"].tolist()
    np.testing.assert_allclose(np.asarray(lps["s"], np.float32),
                               twin["results"][1]["logprobs"], rtol=1e-5)
    assert not done["g"]["aborted"] and done["g"]["n_new"] == 5
    assert not done["s"]["aborted"] and done["s"]["n_new"] == 6
    assert done["a"]["aborted"] and done["a"]["n_new"] < 16
    assert toks.get("a", []) == twin["results"][0]["tokens"].tolist(
        )[:len(toks.get("a", []))]
    assert health["slots"] == eng.batch
    assert any("unknown op" in e["message"] for e in errors)
    assert_pool_drained(eng)


def test_socket_disconnect_aborts_inflight():
    """Dropping the connection mid-stream must abort its requests so pages
    return to the pool (a vanished client cannot pin slots).

    A single request can win the race and finish its whole budget before
    the server notices the reset, so the pin uses a batch=1 engine with a
    SECOND, queued request: the queued one cannot complete before the
    disconnect lands, making the abort deterministic."""
    eng = get_engine(0, 1)
    rng = np.random.default_rng(13)
    p = rng.integers(1, 200, size=6).astype(np.int32)
    q = rng.integers(1, 200, size=5).astype(np.int32)

    async def go():
        aeng = AsyncEngine(eng)
        server = await start_stream_server(aeng, port=0)
        port = server.sockets[0].getsockname()[1]
        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        for cid, prompt in (("x", p), ("y", q)):
            writer.write((json.dumps(
                {"op": "generate", "id": cid, "prompt": prompt.tolist(),
                 "max_new_tokens": 16}) + "\n").encode())
        await writer.drain()
        await reader.readline()              # first tokens event: running
        writer.close()                       # vanish mid-stream
        # the abort lands on the server loop; wait for the session to go idle
        for _ in range(2000):
            h = aeng.health()
            if h["inflight"] == 0:
                break
            await asyncio.sleep(0.01)
        server.close()
        await server.wait_closed()
        rep = await aeng.close()
        return rep

    rep = run(go())
    assert rep["aborted"] >= 1
    assert_pool_drained(eng)
