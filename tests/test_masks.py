"""Mask-implementation equivalence: PARD-style per-example construction,
the paper's amortized precompute+slice, and the closed-form predicate must
agree bit-for-bit (including on padding and non-chain-closed sets)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cod, masks


@pytest.mark.parametrize("n,K,r", [(16, 4, 0.7), (32, 8, 0.8), (8, 3, 0.5),
                                   (64, 5, 0.9), (12, 2, 0.3)])
def test_three_implementations_agree(n, K, r):
    rng = np.random.default_rng(0)
    pos, depth = cod.sample_cod(rng, n, K, r)
    full = masks.precompute_full_mask(n, K)
    m_paper = masks.extract_mask(full, pos, depth, K)
    m_pard = masks.pard_style_mask(pos, depth)
    m_closed = masks.mtp_mask_predicate(depth, pos, depth, pos)
    assert (m_paper == m_pard).all()
    assert (m_paper == m_closed).all()


@settings(max_examples=50, deadline=None)
@given(st.integers(8, 48), st.integers(2, 6), st.floats(0.3, 0.95),
       st.integers(0, 2**31 - 1))
def test_equivalence_property(n, K, r, seed):
    rng = np.random.default_rng(seed)
    pos, depth = cod.sample_cod(rng, n, K, r)
    full = masks.precompute_full_mask(n, K)
    assert (masks.extract_mask(full, pos, depth, K)
            == masks.pard_style_mask(pos, depth)).all()


@settings(max_examples=30, deadline=None)
@given(st.integers(4, 32), st.integers(2, 5), st.integers(0, 2**31 - 1))
def test_arbitrary_subsets_agree(n, K, seed):
    """Equivalence must hold even for NON-chain-closed subsets."""
    rng = np.random.default_rng(seed)
    grid = [(p, g) for p in range(n) for g in range(min(K, p + 1))]
    take = rng.choice(len(grid), size=max(1, len(grid) // 2), replace=False)
    sel = sorted((grid[i][0] * K + grid[i][1]) for i in take)
    pos = np.array([s // K for s in sel], np.int64)
    depth = np.array([s % K for s in sel], np.int64)
    full = masks.precompute_full_mask(n, K)
    assert (masks.extract_mask(full, pos, depth, K)
            == masks.pard_style_mask(pos, depth)).all()


def test_top_left_submatrix_property():
    """Fig. 3: the mask for a shorter sequence is exactly the top-left
    submatrix of a longer sequence's mask (position invariance)."""
    K = 4
    small = masks.precompute_full_mask(16, K)
    big = masks.precompute_full_mask(64, K)
    assert (big[: 16 * K, : 16 * K] == small).all()


def test_depth0_is_plain_causal():
    n, K = 24, 3
    pos = np.arange(n)
    depth = np.zeros(n, np.int64)
    m = masks.mtp_mask_predicate(depth, pos, depth, pos)
    assert (m == np.tril(np.ones((n, n), bool))).all()


def test_padding_attends_nothing():
    pos = np.array([0, 1, 2, -1])
    depth = np.array([0, 0, 1, -1])
    m = masks.mtp_mask_predicate(depth, pos, depth, pos)
    assert not m[3].any() and not m[:, 3].any()


def test_chain_sees_own_anchor_context_only():
    """A depth-g position must not see real tokens after its anchor."""
    pos = np.array([0, 1, 2, 3, 3])
    depth = np.array([0, 0, 0, 0, 2])          # (2,3): anchor 1
    m = masks.mtp_mask_predicate(depth, pos, depth, pos)
    row = m[4]
    assert row[0] and row[1]                    # ctx <= anchor 1
    assert not row[2] and not row[3]            # ctx beyond anchor hidden
