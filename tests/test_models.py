"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each family (2 layers, d_model<=256, <=4 experts) runs one forward and
one train step on CPU with correct output shapes and no NaNs, plus
prefill+decode == full-forward consistency (cache correctness)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, DrafterConfig, get_config

# big/multi-modal reduced configs still cost 5-17 s of jit each on CPU;
# one representative per family stays in the default (fast) selection
HEAVY_ARCHS = {"llama4-maverick-400b-a17b", "whisper-base", "gemma-7b",
               "gemma2-27b", "internvl2-1b", "dbrx-132b",
               "recurrentgemma-2b"}
ARCH_PARAMS = [pytest.param(a, marks=pytest.mark.slow)
               if a in HEAVY_ARCHS else a for a in ARCH_IDS]
from repro.models import get_model, make_extras

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch).reduced()
            m = get_model(cfg)
            cache[arch] = (cfg, m, m.init(KEY))
        return cache[arch]
    return get


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_smoke_forward(arch, built):
    cfg, m, params = built(arch)
    B, S = 2, 16
    tl = m.text_len(S, "train")
    toks = jax.random.randint(KEY, (B, tl), 0, cfg.vocab_size)
    extras = make_extras(cfg, B, "train", KEY)
    out = m.forward(params, toks, mode="train", **extras)
    assert out.logits.shape == (B, S, cfg.vocab_size)
    assert out.taps.shape == (B, S, 3 * cfg.d_model)
    assert not bool(jnp.isnan(out.logits).any())
    assert not bool(jnp.isnan(out.taps).any())


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_smoke_train_step(arch, built):
    """One drafter train step on the reduced target: loss is finite and the
    drafter parameters change."""
    from repro.training import TrainConfig, make_train_step
    from repro.core import drafter as D, cod
    from repro.optim import adamw_init

    cfg, m, tparams = built(arch)
    dcfg = DrafterConfig(n_layers=1, k_train=3).resolve(cfg)
    dparams = D.init_params(dcfg, cfg, jax.random.fold_in(KEY, 1))
    opt = adamw_init(dparams)
    step = make_train_step(cfg, dcfg, TrainConfig(total_steps=10))

    B, S = 2, 16
    tl = m.text_len(S, "train")
    toks = jax.random.randint(KEY, (B, tl), 0, cfg.vocab_size)
    rng = np.random.default_rng(0)
    pos, depth = cod.sample_cod(rng, tl, 3, 0.7)
    tgt = pos + 2
    labels = np.where(tgt < tl, np.asarray(toks)[:, np.clip(tgt, 0, tl - 1)], -1)
    extras = make_extras(cfg, B, "train", KEY)
    new_dp, new_opt, metrics = step(
        tparams, dparams, opt, toks, jnp.asarray(pos), jnp.asarray(depth),
        jnp.asarray(labels), KEY, **extras)
    assert np.isfinite(float(metrics["loss"]))
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(dparams), jax.tree.leaves(new_dp)))
    assert moved


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_prefill_decode_consistency(arch, built):
    cfg, m, params = built(arch)
    B, S, T = 2, 12, 4
    toks = jax.random.randint(KEY, (B, S + T), 0, cfg.vocab_size)
    extras = make_extras(cfg, B, "prefill", KEY)
    full = m.forward(params, toks, mode="train", **extras)
    off = cfg.vision_tokens if cfg.family == "vlm" else 0
    cache = m.make_cache(B, off + S + T, dtype=jnp.float32)
    pre = m.forward(params, toks[:, :S], mode="prefill", cache=cache,
                    **extras)
    pos = jnp.broadcast_to(
        jnp.arange(off + S, off + S + T, dtype=jnp.int32)[None], (B, T))
    dec = m.forward(params, toks[:, S:], mode="decode", cache=pre.cache,
                    positions=pos)
    a = np.asarray(full.logits[:, off + S:off + S + T])
    b = np.asarray(dec.logits)
    np.testing.assert_allclose(a, b, atol=5e-4, rtol=5e-3)


def test_sliding_window_ring_cache_matches_local_attention():
    """Decode past the window with a ring cache must equal a full local-
    attention forward (the long_500k mechanism at test scale)."""
    cfg = get_config("qwen2-1.5b").reduced().replace(
        attn_pattern=("local",), window_size=8)
    m = get_model(cfg)
    params = m.init(KEY)
    B, S, T = 2, 20, 4
    toks = jax.random.randint(KEY, (B, S + T), 0, cfg.vocab_size)
    full = m.forward(params, toks, mode="train")
    cache = m.make_cache(B, S + T, dtype=jnp.float32)   # ring: W=8 < 24
    pre = m.forward(params, toks[:, :S], mode="prefill", cache=cache)
    pos = jnp.broadcast_to(jnp.arange(S, S + T, dtype=jnp.int32)[None],
                           (B, T))
    dec = m.forward(params, toks[:, S:], mode="decode", cache=pre.cache,
                    positions=pos)
    np.testing.assert_allclose(np.asarray(full.logits[:, S:]),
                               np.asarray(dec.logits), atol=5e-4, rtol=5e-3)
    # ring buffers really are bounded
    k_shape = jax.tree.leaves(pre.cache)[0].shape
    assert any(s == 8 for leaf in jax.tree.leaves(pre.cache)
               for s in leaf.shape)


@pytest.mark.parametrize("arch", ["gemma2-27b", "llama4-maverick-400b-a17b"])
def test_alternating_pattern_layers(arch):
    cfg = get_config(arch)
    kinds = [cfg.attn_kind(i) for i in range(4)]
    assert "local" in kinds and "global" in kinds


def test_moe_aux_losses_present():
    cfg = get_config("dbrx-132b").reduced()
    m = get_model(cfg)
    params = m.init(KEY)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    out = m.forward(params, toks, mode="train")
    assert float(out.aux["lb_loss"]) > 0.0
    assert float(out.aux["z_loss"]) > 0.0
