"""Prefix-cache suite: hash-cons index semantics, and the serving-level
losslessness bar — a cache hit must be token-for-token identical to a cold
prefill (ROADMAP), across model families, KV layouts, and mesh sizes.

Structure:

- **index unit tests** (no engine): the token-prefix chain walk, the
  lookahead-token full/partial key split, full-key dedup, LRU refresh
  rules, eviction pinning (refcount > 1 pages are skipped), and flush
  draining the cache's allocator refs;
- **`test_cache_hit_losslessness`** — the acceptance pin: a shared-preamble
  workload served on a prefix-cache engine emits bit-identical streams to a
  cache-off paged engine AND (dense, single-device) the contiguous-layout
  engine, for dense (real hits), SSM, and hybrid (structurally idle cache —
  recurrent drafter state is not positions-exact per page, so the fast path
  is dense-gated and the cache must be a no-op) at mesh sizes 1/4/8;
- **copy-on-write**: divergence exactly at a page's lookahead token serves
  the page via CoW — copy, recompute only the final drafter entry — and
  stays lossless while the shared original survives byte-stable;
- **eviction under pressure**: a pool too small to index every stream still
  serves losslessly, evicting LRU cache-only pages; pool accounting stays
  exact (live = cache-held after drain; flush empties the pool).

Sharded cases run in CI's tier1-multidevice lane
(XLA_FLAGS=--xla_force_host_platform_device_count=8).
"""
from functools import lru_cache

import jax
import numpy as np
import pytest

from repro.configs import DrafterConfig, get_config
from repro.core import drafter as D
from repro.models import get_model
from repro.serving import (Engine, EngineConfig, PrefixCache, Request,
                           Scheduler, cache_ops)
from repro.sharding.utils import serving_mesh

KEY = jax.random.PRNGKey(23)

FAMILY_ARCHS = {
    "dense": "qwen2-1.5b",
    "ssm": "mamba2-780m",
    "hybrid": "recurrentgemma-2b",
}
PS = 8          # page_size everywhere below


from conftest import require_devices  # noqa: E402  (tests dir on sys.path)


@lru_cache(maxsize=None)
def _setup(family):
    tcfg = get_config(FAMILY_ARCHS[family]).reduced()
    m = get_model(tcfg)
    tparams = m.init(KEY)
    dcfg = DrafterConfig(n_layers=1, k_infer=2).resolve(tcfg)
    dparams = D.init_params(dcfg, tcfg, jax.random.fold_in(KEY, 1))
    return tcfg, dcfg, tparams, dparams


@lru_cache(maxsize=None)
def get_engine(family="dense", prefix_cache=False, pool_pages=0,
               kv_layout="paged", shard=0):
    if shard:
        require_devices(shard)
    tcfg, dcfg, tparams, dparams = _setup(family)
    return Engine(tcfg, dcfg, tparams, dparams,
                  EngineConfig(K=2, max_new_tokens=8,
                               drafter_mode="parallel", max_len=64,
                               kv_layout=kv_layout, page_size=PS,
                               pool_pages=pool_pages,
                               prefix_cache=prefix_cache,
                               shard_model=shard > 0,
                               mesh=serving_mesh(shard) if shard else None),
                  batch=2)


def shared_preamble_workload(pre_len, tails, seed=0):
    """Prompts sharing a ``pre_len``-token preamble with distinct random
    tails (the canonical serving-scale shape: system prompt + user turn)."""
    rng = np.random.default_rng(seed)
    pre = rng.integers(1, 200, pre_len).astype(np.int32)
    return [np.concatenate([pre, rng.integers(1, 200, t).astype(np.int32)])
            for t in tails]


def serve_tokens(eng, prompts, budget=8):
    rep = Scheduler(eng).serve([Request(p, max_new_tokens=budget)
                                for p in prompts])
    return [r["tokens"] for r in rep["results"]], rep


def assert_cache_consistent(eng):
    """Post-drain pool accounting: every live page is cache-held at
    refcount exactly 1, and flushing leaves the pool empty."""
    cache, alloc = eng.prefix_cache, eng.allocator
    pages = cache.pages()
    assert len(pages) == len(set(pages)), "cache indexes a page twice"
    assert alloc.n_used == len(pages), "pages live outside cache + slots"
    assert all(alloc.refcount(p) == 1 for p in pages)
    cache.flush(alloc)
    assert alloc.n_used == 0 and alloc.n_free == eng.pool_pages
    assert all(not ps for ps in eng._slot_pages)


# ---------------------------------------------------------------------------
# index unit tests (host-side, no engine)
# ---------------------------------------------------------------------------

def toks(*xs):
    return np.asarray(xs, np.int32)


def test_index_chain_walk_and_lookahead():
    """A page is shareable only through its full key — chain plus the
    lookahead token the drafter entry fused; same page bytes with a
    different next token must NOT full-hit (but is the CoW source)."""
    c = PrefixCache(4)
    a = cache_ops.BlockAllocator(8)
    stream = np.arange(1, 14, dtype=np.int32)       # 13 tokens, 3 pages
    pages = a.alloc(3)
    # pages 0..1 have their lookahead in-stream ((m+1)*4+1 <= 13); page 2
    # covers 8..11 and token 12 is its lookahead -> also insertable? no:
    # (2+1)*4+1 = 13 <= 13 -> yes, all three
    assert c.insert_stream(stream, pages, a) == 3
    assert all(a.refcount(p) == 2 for p in pages)   # slot ref + cache ref

    shared, cow = c.match(stream)
    assert shared == pages and cow is None
    # divergence in page 1's BYTES: only page 0 full-hits, no CoW source
    div = stream.copy()
    div[5] = 99
    shared, cow = c.match(div)
    assert shared == pages[:1] and cow is None
    # divergence exactly at page 1's LOOKAHEAD (token 8): pages 0 and...
    # page 1's bytes (4..7) match but full key (tokens 0..8) differs ->
    # shared stops at page 1? page 1 key = chain(pages 0,1) + token[8]
    div2 = stream.copy()
    div2[8] = 99
    shared, cow = c.match(div2)
    assert shared == pages[:1]
    assert cow == pages[1], "byte-equal page with new lookahead must CoW"
    # too-short stream: page 1 not probed for CoW without its full bytes
    assert c.match(stream[:7])[0] == pages[:1]
    assert c.match(stream[:7])[1] is None
    for p in pages:
        a.free([p])
    c.flush(a)
    assert a.n_free == 8


def test_index_dedup_first_page_wins():
    c = PrefixCache(4)
    a = cache_ops.BlockAllocator(8)
    stream = np.arange(1, 10, dtype=np.int32)       # 2 insertable pages
    p1 = a.alloc(2)
    p2 = a.alloc(2)
    assert c.insert_stream(stream, p1, a) == 2
    assert c.insert_stream(stream, p2, a) == 0      # dup keys: no new refs
    assert c.match(stream)[0] == p1, "first physical page must win"
    assert a.refcount(p2[0]) == 1 and a.refcount(p1[0]) == 2
    a.free(p1 + p2)
    c.flush(a)


def test_match_len_is_read_only():
    """Admission gating probes (can_admit) must not refresh LRU order —
    probing is not reuse, and eviction order must reflect actual hits."""
    c = PrefixCache(4)
    a = cache_ops.BlockAllocator(8)
    s1 = np.arange(1, 6, dtype=np.int32)            # 1 page
    s2 = np.arange(50, 55, dtype=np.int32)          # 1 page, distinct chain
    c.insert_stream(s1, a.alloc(1), a)
    c.insert_stream(s2, a.alloc(1), a)
    assert c.match_len(s1) == 1 and c.match_len(s2) == 1
    a.free(c.pages())             # cache-only now (refcount 1, evictable)
    c.match_len(s1)               # probe must NOT make s1 recently-used
    assert c.evict(1, a) == 1
    assert c.match_len(s1) == 0, "eviction should have taken the LRU page s1"
    assert c.match_len(s2) == 1
    c.flush(a)
    assert a.n_free == 8


def test_evict_skips_pinned_pages():
    c = PrefixCache(4)
    a = cache_ops.BlockAllocator(8)
    s1 = np.arange(1, 6, dtype=np.int32)
    s2 = np.arange(50, 55, dtype=np.int32)
    p1 = a.alloc(1)
    p2 = a.alloc(1)
    c.insert_stream(s1, p1, a)
    c.insert_stream(s2, p2, a)
    a.free(p2)                    # s2's page: cache-only; s1's: still held
    assert c.evictable(a) == 1
    assert c.evict(2, a) == 1, "must skip the pinned page, not stall"
    assert c.match_len(s1) == 1 and c.match_len(s2) == 0
    a.free(p1)
    c.flush(a)
    assert a.n_free == 8


# ---------------------------------------------------------------------------
# the acceptance pin: cache hit == cold prefill, token for token
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family,shard", [
    ("dense", 0), ("ssm", 0), ("hybrid", 0),
    ("dense", 4), ("ssm", 4), ("hybrid", 4), ("dense", 8),
])
def test_cache_hit_losslessness(family, shard):
    """Shared-preamble workload on a prefix-cache engine vs a cache-off
    paged engine: every request's stream bit-equal; a second serve of the
    same workload (pages now warm from the first, including free-time
    inserts of generated tokens) also bit-equal. Dense must actually hit;
    SSM/hybrid must be structurally idle (recurrent page content is not a
    pure function of the page's own token span, so sharing is dense-only)
    yet identical. Single-device dense additionally pins the hit streams
    against the contiguous-layout engine (cross-layout bar)."""
    warm = get_engine(family, prefix_cache=True, shard=shard)
    cold = get_engine(family, shard=shard)
    prompts = shared_preamble_workload(20, (3, 5, 7, 4))
    cold_toks, _ = serve_tokens(cold, prompts)

    for serve_pass in (1, 2):
        warm_toks, rep = serve_tokens(warm, prompts)
        for i, (c, w) in enumerate(zip(cold_toks, warm_toks)):
            np.testing.assert_array_equal(
                w, c, err_msg=f"{family}@mesh{shard}: request {i} diverged "
                              f"on a cache hit (pass {serve_pass})")
        if family == "dense":
            assert rep["cache_hit_requests"] >= (2 if serve_pass == 1 else 4)
            assert rep["cache_hit_tokens"] > 0
        else:
            assert rep["cache_hit_tokens"] == 0, \
                "recurrent families must not take the sharing fast path"
            assert len(warm.prefix_cache) == 0

    if family == "dense" and shard == 0:
        contig = get_engine(family, kv_layout="contiguous")
        contig_toks, _ = serve_tokens(contig, prompts)
        for c, w in zip(contig_toks, warm_toks):
            np.testing.assert_array_equal(w, c)
    assert_cache_consistent(warm)


def test_cow_divergence_lossless():
    """Preamble a multiple of page_size: the first divergent token IS a
    cached page's lookahead, so admission must CoW that page — copy it,
    recompute only its final drafter entry — and still match cold output."""
    warm = get_engine("dense", prefix_cache=True)
    cold = get_engine("dense")
    prompts = shared_preamble_workload(3 * PS, (4, 4, 6), seed=1)
    assert len({int(p[3 * PS]) for p in prompts}) > 1   # lookaheads differ
    cold_toks, _ = serve_tokens(cold, prompts)
    warm_toks, rep = serve_tokens(warm, prompts)
    for c, w in zip(cold_toks, warm_toks):
        np.testing.assert_array_equal(w, c)
    assert warm.prefix_cache.stats["cow_hits"] >= 2
    # the divergent requests still share the preamble's full pages
    assert rep["cache_hit_tokens"] >= 2 * (3 * PS - 1)
    assert_cache_consistent(warm)


def test_eviction_under_pressure_lossless():
    """A pool too small to index every served stream: LRU cache-only pages
    are reclaimed to admit new work, streams stay bit-equal to a cache-off
    engine, and accounting never drifts (no page both free and cached)."""
    warm = get_engine("dense", prefix_cache=True, pool_pages=8)
    cold = get_engine("dense", pool_pages=8)
    prompts = shared_preamble_workload(16, (6, 6, 6, 6), seed=2)
    cold_toks, _ = serve_tokens(cold, prompts, budget=4)
    warm_toks, rep = serve_tokens(warm, prompts, budget=4)
    for c, w in zip(cold_toks, warm_toks):
        np.testing.assert_array_equal(w, c)
    assert warm.prefix_cache.stats["evictions"] > 0, \
        "pool was sized to force eviction"
    assert rep["cache_hit_requests"] > 0, "eviction must not kill all hits"
    assert warm.allocator.peak_used <= 8
    assert_cache_consistent(warm)


def test_cache_off_by_default_and_layout_guard():
    eng = get_engine("dense")
    assert eng.prefix_cache is None
    tcfg, dcfg, tparams, dparams = _setup("dense")
    with pytest.raises(ValueError, match="paged"):
        Engine(tcfg, dcfg, tparams, dparams,
               EngineConfig(K=2, max_new_tokens=8, max_len=64,
                            kv_layout="contiguous", prefix_cache=True),
               batch=2)


def test_report_plumbs_per_request_hit_stats():
    eng = get_engine("dense", prefix_cache=True, pool_pages=16)
    prompts = shared_preamble_workload(16, (3, 4), seed=3)
    _, rep = serve_tokens(eng, prompts, budget=3)
    cached = [r["cached_tokens"] for r in rep["results"]]
    assert cached[0] == 0, "first admission is necessarily cold"
    assert cached[1] > 0, "second request shares two full pages"
    assert rep["cache_hit_tokens"] == sum(cached)
    assert rep["cache_hit_requests"] == 1
    assert_cache_consistent(eng)
