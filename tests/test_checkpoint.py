"""Checkpoint round-trip including NamedTuple optimizer state."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, load_pytree, save_pytree
from repro.optim import adamw_init


def test_roundtrip(tmp_path):
    tree = {"a": {"b": jnp.arange(6).reshape(2, 3).astype(jnp.float32)},
            "c": jnp.ones((4,), jnp.bfloat16)}
    save_pytree(tree, str(tmp_path), "params", step=3, metadata={"x": 1})
    out = load_pytree(jax.tree.map(lambda x: x, tree), str(tmp_path),
                      "params")
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_optimizer_state_roundtrip(tmp_path):
    params = {"w": jnp.ones((3, 2))}
    opt = adamw_init(params)
    save_pytree(opt, str(tmp_path), "opt", step=1)
    out = load_pytree(opt, str(tmp_path), "opt", step=1)
    assert int(out.step) == 0
    np.testing.assert_array_equal(np.asarray(out.m["w"]),
                                  np.asarray(opt.m["w"]))


def test_latest_step(tmp_path):
    params = {"w": jnp.ones(2)}
    for s in (1, 5, 3):
        save_pytree(params, str(tmp_path), "p", step=s)
    assert latest_step(str(tmp_path)) == 5
